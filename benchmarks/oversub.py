"""Device-memory oversubscription (paper §1/§4.4 claim): PGAbB processes
graphs whose dense representation exceeds accelerator memory because a
task only ever needs the blocks of ONE block-list resident.

Emulation on this container: sweep a per-task "device memory" budget
(tile_dim² bytes × blocks-per-list) and show the hybrid plan still
completes with bounded resident tile bytes while dense-only with an
unbounded budget would need the full dense matrix (n² >> budget)."""
from __future__ import annotations

import numpy as np

from repro.core import build_block_store, compile_plan
from repro.algorithms import tc_algorithm
from repro.algorithms.tc import orient_dag
from repro.data import benchmark_suite

from .common import csv_row, time_median


def run(scale: str = "small", repeats: int = 3, backend: str = "xla") -> list[str]:
    rows = []
    g = benchmark_suite(scale)["social"]
    dag = orient_dag(g)
    n = dag.n
    full_dense_bytes = n * n * 4
    for tile_dim, p in [(128, 16), (256, 8), (512, 4)]:
        store = build_block_store(dag, p)
        plan = compile_plan(tc_algorithm(), store, mode="hybrid",
                            tile_dim=tile_dim, dense_density=0.001,
                            backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        resident = 3 * tile_dim * tile_dim * 4  # one block-list (3 tiles)
        rows.append(csv_row(
            f"oversub/tc/tile_{tile_dim}_p{p}", t,
            f"task_resident_bytes={resident};full_dense_bytes={full_dense_bytes};"
            f"oversubscription={full_dense_bytes / resident:.0f}x",
        ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
