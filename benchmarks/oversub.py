"""Device-memory oversubscription (paper §1/§4.4 claim): PGAbB processes
graphs whose dense representation exceeds accelerator memory because a
task only ever needs the blocks of ONE block-list resident.

Two measurements on this container:

* the original tile sweep — hybrid TC completes with bounded resident
  tile bytes while unbounded dense-only would need the full n² matrix;
* the streaming executor — ``--memory-budget`` runs PageRank under an
  explicit budget through ``compile_plan(..., memory_budget=...)`` and
  reports wave count, bytes staged per wave, and the measured
  copy/compute overlap efficiency from ``schedule_stats["streaming"]``.

CLI: ``python -m benchmarks.oversub [--memory-budget 256KB]``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import build_block_store, compile_plan
from repro.algorithms import pagerank_algorithm, tc_algorithm
from repro.algorithms.tc import orient_dag
from repro.data import benchmark_suite

from .common import csv_row, time_median


def run(scale: str = "small", repeats: int = 3, backend: str = "xla",
        memory_budget: str | None = None) -> list[str]:
    rows = []
    g = benchmark_suite(scale)["social"]
    dag = orient_dag(g)
    n = dag.n
    full_dense_bytes = n * n * 4
    for tile_dim, p in [(128, 16), (256, 8), (512, 4)]:
        store = build_block_store(dag, p)
        plan = compile_plan(tc_algorithm(), store, mode="hybrid",
                            tile_dim=tile_dim, dense_density=0.001,
                            backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        resident = 3 * tile_dim * tile_dim * 4  # one block-list (3 tiles)
        rows.append(csv_row(
            f"oversub/tc/tile_{tile_dim}_p{p}", t,
            f"task_resident_bytes={resident};full_dense_bytes={full_dense_bytes};"
            f"oversubscription={full_dense_bytes / resident:.0f}x",
        ))
    rows.extend(run_streaming(g, repeats=repeats, backend=backend,
                              memory_budget=memory_budget))
    return rows


def run_streaming(g, *, repeats: int = 3, backend: str = "xla",
                  memory_budget: str | None = None) -> list[str]:
    """PageRank under an explicit device-memory budget (streamed waves)."""
    budgets = [memory_budget] if memory_budget else ["256KB", "64KB"]
    rows = []
    store = build_block_store(g, 8)
    for budget in budgets:
        try:
            plan = compile_plan(pagerank_algorithm(), store,
                                mode="sparse_only", backend=backend,
                                memory_budget=budget)
        except ValueError as e:
            rows.append(csv_row(f"oversub/stream/pr/{budget}", 0.0,
                                f"error={e}"))
            continue
        last: dict = {}

        def timed(plan=plan, last=last):
            last["res"] = plan.run()

        t = time_median(timed, repeats=repeats)
        st = last["res"].schedule_stats["streaming"]
        rows.append(csv_row(
            f"oversub/stream/pr/{budget}", t,
            f"waves={st['num_waves']};budget_bytes={st['budget_bytes']};"
            f"max_wave_bytes={max(st['bytes_per_wave'], default=0)};"
            f"bytes_staged_total={st['bytes_staged_total']};"
            f"resident_bytes={st['resident_bytes']};"
            f"overlap_efficiency={st['overlap_efficiency']:.2f}",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--backend", default="xla",
                    choices=["reference", "xla", "pallas"])
    ap.add_argument(
        "--memory-budget", default=None,
        help="stream PageRank under this device-memory budget "
             "(bytes or e.g. 256KB) and report waves/bytes/overlap",
    )
    a = ap.parse_args()
    print("\n".join(run(scale=a.scale, repeats=a.repeats, backend=a.backend,
                        memory_budget=a.memory_budget)))
