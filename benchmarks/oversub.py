"""Device-memory oversubscription (paper §1/§4.4 claim): PGAbB processes
graphs whose dense representation exceeds accelerator memory because a
task only ever needs the blocks of ONE block-list resident.

Three measurements on this container:

* the original tile sweep — hybrid TC completes with bounded resident
  tile bytes while unbounded dense-only would need the full n² matrix;
* the streaming executor — ``--memory-budget`` runs PageRank (csr=none:
  COO waves only) and TC (csr=slice: per-wave conformal CSR staging)
  under an explicit budget through ``compile_plan(..., memory_budget=...)``
  with budget-aware partitioning (``choose_p``) and tail-wave
  rebalancing enabled, and reports wave count, bytes staged per wave
  (CSR broken out), and the measured copy/compute overlap efficiency
  from ``schedule_stats["streaming"]``;
* mesh-cooperative streaming — ``--mesh-devices N`` forces an N-device
  host-platform mesh (XLA_FLAGS, set before jax initializes — which is
  why this module imports repro lazily) and runs the same budgeted
  waves through ``shard_map``, reporting per-device staged bytes,
  collective bytes, and overlap efficiency next to the single-device
  streaming baseline at the same per-device budget;
* the staging pipeline — ``--smoke`` (the CI perf-smoke gate) compares
  the three-stage pipelined executor (``pipeline_depth=2``) against
  the synchronous baseline (``pipeline_depth=0``) on a ≥4-wave R-MAT
  run with a per-phase wall-clock breakdown (assemble / prepare /
  device_put / compute / collective), checks TC's ``trace_count`` does
  NOT grow with the wave count, gates ``overlap_efficiency`` against
  ``REPRO_SMOKE_OVERLAP_FLOOR``, and writes everything to
  ``BENCH_stream.json`` (the build artifact).

CLI: ``python -m benchmarks.oversub [--memory-budget 256KB]
[--mesh-devices 8] [--smoke]``.
"""
from __future__ import annotations

import argparse
import json
import sys

from .common import best_of, csv_row, env_float, time_median

# Recorded floor for the CI perf-smoke gate on the *pipelined*
# executor's best-of-repeats overlap_efficiency.  Best-of-repeats on
# the 2-core CI container lands 0.65–1.0 (a background staging thread
# contends with the XLA compute pool, so single runs swing); the floor
# is set well below that band so only a structural regression — e.g.
# the pipeline silently running synchronously so the serial baseline
# equals the overlapped wall — can cross it, while still being a live
# gate (overlap_efficiency is clamped to [0, 1], so a 0.0 floor could
# never fail).  Override with ``REPRO_SMOKE_OVERLAP_FLOOR`` (default
# 0.10); raise it when benchmarking hardware with cores to spare.
#
# The gate knobs (this and the wall-ratio gates below) are read inside
# the smoke functions, not at import — env_float validates through
# repro.core.knobs, which pulls in jax, and the ``--mesh-devices``
# entrypoint must set XLA_FLAGS first.
SMOKE_OVERLAP_FLOOR_DEFAULT = 0.10

# CI hetero-smoke gate: the heterogeneous (host co-scheduled) run's
# best-of-repeats wall clock may be at most this multiple of the
# device-only baseline on the same warm plan shape.  Override with
# ``REPRO_HETERO_WALL_RATIO`` (default 1.05).
HETERO_WALL_RATIO_DEFAULT = 1.05

# CI direction-smoke gate: the direction-optimizing (auto) run's
# best-of-repeats wall clock may be at most this multiple of the
# fixed-push baseline on the same warm plan shape (both variants are
# compiled up front, so auto only pays the per-iteration host decision).
# Override with ``REPRO_DIRECTION_WALL_RATIO`` (default 1.05).
DIRECTION_WALL_RATIO_DEFAULT = 1.05

# CI chaos-smoke gate: the faulted (recovering) streamed run's
# best-of-repeats wall clock may be at most this multiple of the
# fault-free baseline on the same warm plan shape.  Override with
# ``REPRO_CHAOS_WALL_RATIO`` (default 1.10).
CHAOS_WALL_RATIO_DEFAULT = 1.10


def run(scale: str = "small", repeats: int = 3, backend: str = "xla",
        memory_budget: str | None = None,
        mesh_devices: int = 1) -> list[str]:
    from repro.core import build_block_store, compile_plan
    from repro.algorithms import tc_algorithm
    from repro.algorithms.tc import orient_dag
    from repro.data import benchmark_suite

    rows = []
    g = benchmark_suite(scale)["social"]
    dag = orient_dag(g)
    n = dag.n
    full_dense_bytes = n * n * 4
    for tile_dim, p in [(128, 16), (256, 8), (512, 4)]:
        store = build_block_store(dag, p)
        plan = compile_plan(tc_algorithm(), store, mode="hybrid",
                            tile_dim=tile_dim, dense_density=0.001,
                            backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        resident = 3 * tile_dim * tile_dim * 4  # one block-list (3 tiles)
        rows.append(csv_row(
            f"oversub/tc/tile_{tile_dim}_p{p}", t,
            f"task_resident_bytes={resident};full_dense_bytes={full_dense_bytes};"
            f"oversubscription={full_dense_bytes / resident:.0f}x",
        ))
    rows.extend(run_streaming(g, repeats=repeats, backend=backend,
                              memory_budget=memory_budget))
    if mesh_devices > 1:
        rows.extend(run_mesh_streaming(
            g, repeats=repeats, backend=backend,
            memory_budget=memory_budget, mesh_devices=mesh_devices,
        ))
    return rows


def run_streaming(g, *, repeats: int = 3, backend: str = "xla",
                  memory_budget: str | None = None) -> list[str]:
    """PageRank + TC under an explicit device-memory budget.

    PageRank (csr=none) streams pure COO waves; TC (csr=slice) also
    stages each wave's conformal CSR row slices, so ``max_csr_bytes``
    shows the adjacency itself staying under the budget.  Both use the
    budget-aware partition grain and opt in to tail-wave rebalancing.
    """
    from repro.core import build_block_store, choose_p, compile_plan
    from repro.algorithms import pagerank_algorithm, tc_algorithm
    from repro.algorithms.tc import orient_dag

    budgets = [memory_budget] if memory_budget else ["256KB", "64KB"]
    rows = []
    dag = orient_dag(g)
    for budget in budgets:
        jobs = [
            ("pr", pagerank_algorithm(),
             build_block_store(g, max(choose_p(g, budget), 4))),
            # TC tasks are triples (3 blocks) with per-item prepare
            # extras on top — give the grain chooser extra headroom
            ("tc", tc_algorithm(),
             build_block_store(dag, max(choose_p(dag, budget, safety=12), 4))),
        ]
        for name, alg, store in jobs:
            try:
                plan = compile_plan(alg, store,
                                    mode="sparse_only", backend=backend,
                                    memory_budget=budget,
                                    rebalance_threshold=1.5)
            except ValueError as e:
                rows.append(csv_row(f"oversub/stream/{name}/{budget}", 0.0,
                                    f"error={e}"))
                continue
            last: dict = {}

            def timed(plan=plan, last=last):
                last["res"] = plan.run()

            t = time_median(timed, repeats=repeats)
            st = last["res"].schedule_stats["streaming"]
            skew = st["rebalance_skew"]
            phases = ";".join(
                f"{k}_s={v:.4f}" for k, v in st["phase_seconds"].items()
            )
            rows.append(csv_row(
                f"oversub/stream/{name}/{budget}", t,
                f"waves={st['num_waves']};budget_bytes={st['budget_bytes']};"
                f"max_wave_bytes={max(st['bytes_per_wave'], default=0)};"
                f"max_csr_bytes={max(st['csr_bytes_per_wave'], default=0)};"
                f"full_csr_bytes={store.indices.nbytes};"
                f"csr_mode={st['csr_mode']};"
                f"bytes_staged_total={st['bytes_staged_total']};"
                f"resident_bytes={st['resident_bytes']};"
                f"arena_bytes={st['arena_bytes']};"
                f"trace_count={st['trace_count']};"
                f"rebalanced={st['rebalanced']};"
                f"rebalance_skew={skew if skew is None else round(skew, 2)};"
                f"{phases};"
                f"host_stage_overlap={st['host_stage_overlap']:.2f};"
                f"overlap_efficiency={st['overlap_efficiency']:.2f}",
            ))
    return rows


def _stream_once(alg, store, *, budget, depth, backend="xla"):
    """One streamed run; returns (RunResult, streaming stats)."""
    from repro.core import compile_plan

    plan = compile_plan(alg, store, mode="sparse_only", backend=backend,
                        share=False, memory_budget=budget,
                        pipeline_depth=depth, rebalance_threshold=None)
    res = plan.run()
    return res, res.schedule_stats["streaming"]


def run_smoke(out_path: str = "BENCH_stream.json", *, repeats: int = 3,
              backend: str = "xla") -> bool:
    """The CI perf-smoke gate (and its ``BENCH_stream.json`` artifact).

    Two checks on a small R-MAT:

    * **Trace stability** (hard, deterministic): TC streamed under a
      coarse and a fine budget — the fine run has several times the
      waves, and ``trace_count`` must NOT grow with them (one jit trace
      per distinct bucket shape; the pre-BucketPlan executor retraced
      once per wave).
    * **Overlap floor**: the pipelined executor's best-of-``repeats``
      ``overlap_efficiency`` on a ≥4-wave PageRank run must not regress
      below ``REPRO_SMOKE_OVERLAP_FLOOR`` (measured against the
      synchronous per-wave calibration baseline).

    The artifact records both executors' per-phase wall-clock breakdown
    (assemble / prepare / device_put / compute / collective) so a
    pipeline win — or regression — is attributable to a phase, not just
    an aggregate number.  Returns True when every check passed.
    """
    from repro.core import build_block_store, rmat
    from repro.algorithms import pagerank_algorithm, tc_algorithm
    from repro.algorithms.tc import orient_dag

    overlap_floor = env_float("REPRO_SMOKE_OVERLAP_FLOOR",
                              SMOKE_OVERLAP_FLOOR_DEFAULT)
    g = rmat(12, 16, seed=5)
    budget = "256KB"
    modes: dict = {}
    for label, depth in (("pipelined", 2), ("synchronous", 0)):

        def _attempt(depth=depth):
            res, st = _stream_once(pagerank_algorithm(),
                                   build_block_store(g, 8),
                                   budget=budget, depth=depth,
                                   backend=backend)
            return dict(
                pipeline_depth=depth,
                waves=st["num_waves"],
                overlap_efficiency=round(st["overlap_efficiency"], 4),
                host_stage_overlap=round(st["host_stage_overlap"], 4),
                phase_seconds={k: round(v, 5)
                               for k, v in st["phase_seconds"].items()},
                arena_bytes=st["arena_bytes"],
                arena_reuses=st["arena_reuses"],
                trace_count=st["trace_count"],
                seconds=round(res.seconds, 4),
            )

        modes[label], _ = best_of(
            _attempt, attempts=repeats,
            score=lambda c: c["overlap_efficiency"])
    dag = orient_dag(rmat(10, 8, seed=5))
    tc: dict = {}
    for label, b in (("coarse", "512KB"), ("fine", "128KB")):
        _, st = _stream_once(tc_algorithm(), build_block_store(dag, 8),
                             budget=b, depth=2, backend=backend)
        tc[label] = dict(budget=b, waves=st["num_waves"],
                         trace_count=st["trace_count"])
    checks = dict(
        multi_wave=modes["pipelined"]["waves"] >= 4,
        # the fine run multiplies the wave count…
        tc_waves_grew=tc["fine"]["waves"] >= 2 * tc["coarse"]["waves"],
        # …while the trace count stays put (one per distinct shape)
        tc_traces_stable=(
            tc["fine"]["trace_count"] <= tc["coarse"]["trace_count"] + 2
            and tc["fine"]["trace_count"] < tc["fine"]["waves"]
        ),
        overlap_floor=(
            modes["pipelined"]["overlap_efficiency"] >= overlap_floor
        ),
    )
    from repro import obs

    payload = obs.export.run_report("stream_smoke", dict(
        graph="rmat(12, 16, seed=5)", budget=budget,
        floors=dict(overlap_efficiency=overlap_floor),
        **modes,
        tc_trace_stability=tc,
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


def run_hetero_smoke(out_path: str = "BENCH_hetero.json", *,
                     repeats: int = 3, backend: str = "xla",
                     host_fraction: "float | str" = "auto") -> bool:
    """The CI hetero-smoke gate (and its ``BENCH_hetero.json`` artifact).

    On a ≥4-wave skewed R-MAT run of Shiloach–Vishkin (integer labels —
    checksum-exact under any host/device fold order):

    * **host lane engaged**: ``host_fraction="auto"`` with the
      calibration noise floor lowered (``REPRO_HETERO_NOISE_FLOOR_S``)
      so the probe fires on small CI waves — the plan must report
      ``host_tasks_executed > 0`` in ``schedule_stats["hetero"]``;
    * **no slowdown**: the heterogeneous best-of-``repeats`` wall must
      stay within ``REPRO_HETERO_WALL_RATIO`` of the device-only baseline
      on the same warm plan (the auto split hides host work behind the
      device or stays at zero — either way the wall must not regress);
    * **checksum-exact**: the component-label checksum equals the
      device-only run's, bit-for-bit.
    """
    import os
    import time

    # make the auto probe fire on CI-sized waves (wave walls here sit
    # well under the production 10 ms noise floor); an explicit CI env
    # setting still wins
    os.environ.setdefault("REPRO_HETERO_NOISE_FLOOR_S", "0.00001")

    import numpy as np

    from repro import obs
    from repro.core import build_block_store, compile_plan, rmat
    from repro.algorithms import sv_algorithm

    wall_gate = env_float("REPRO_HETERO_WALL_RATIO",
                          HETERO_WALL_RATIO_DEFAULT)
    g = rmat(12, 16, seed=5)
    budget = "256KB"

    def compiled(hf):
        return compile_plan(sv_algorithm(), build_block_store(g, 8),
                            mode="sparse_only", backend=backend, share=False,
                            memory_budget=budget, rebalance_threshold=None,
                            host_fraction=hf)

    def timed_run(plan):
        t0 = time.perf_counter()
        res = plan.run()
        return res, time.perf_counter() - t0

    base_plan, het_plan = compiled(None), compiled(host_fraction)
    base_res = base_plan.run()     # warm: compile outside the timings
    het_res = het_plan.run()       # warm + auto calibration/probe

    (base_res, base_s), _ = best_of(
        lambda: timed_run(base_plan), attempts=repeats,
        score=lambda rs: -rs[1])
    (het_res, het_s), _ = best_of(
        lambda: timed_run(het_plan), attempts=repeats,
        score=lambda rs: -rs[1],
        good_enough=lambda rs: rs[1] <= wall_gate * base_s)

    het = het_res.schedule_stats["hetero"]
    waves = het_res.schedule_stats["streaming"]["num_waves"]
    checksum = int(np.asarray(het_res.result, dtype=np.int64).sum())
    base_checksum = int(np.asarray(base_res.result, dtype=np.int64).sum())
    wall_ratio = het_s / base_s if base_s > 0 else float("inf")
    checks = dict(
        multi_wave=waves >= 4,
        host_engaged=het["host_tasks_executed"] > 0,
        wall=wall_ratio <= wall_gate,
        checksum_exact=checksum == base_checksum,
    )
    payload = obs.export.run_report("hetero_smoke", dict(
        graph="rmat(12, 16, seed=5)", budget=budget,
        host_fraction=str(host_fraction), waves=waves,
        floors=dict(wall_ratio=wall_gate),
        noise_floor_s=env_float("REPRO_HETERO_NOISE_FLOOR_S", 0.01),
        device_only_s=round(base_s, 5), hetero_s=round(het_s, 5),
        wall_ratio=round(wall_ratio, 4),
        checksum=checksum, device_checksum=base_checksum,
        hetero=het,
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


def run_direction_smoke(out_path: str = "BENCH_direction.json", *,
                        repeats: int = 3, backend: str = "xla",
                        direction: str = "auto") -> bool:
    """The CI direction-smoke gate (and its ``BENCH_direction.json``
    artifact).

    BFS on a skewed R-MAT under ``direction="auto"``:

    * **pull engaged**: the hysteresis controller must run ≥ 1
      bottom-up (pull) iteration — visible in
      ``schedule_stats["direction"]["pull_iterations"]``;
    * **checksum-exact**: parent/dist checksums equal the fixed-push
      run's, bit-for-bit (the direction contract);
    * **no slowdown**: the auto best-of-``repeats`` wall must stay
      within ``REPRO_DIRECTION_WALL_RATIO`` of the fixed-push baseline on
      the same warm plan shape — both variants are pre-compiled, so
      flipping direction costs one host-side density read per
      iteration.
    """
    import time

    import numpy as np

    from repro import obs
    from repro.core import build_block_store, compile_plan, rmat
    from repro.algorithms import bfs_algorithm

    wall_gate = env_float("REPRO_DIRECTION_WALL_RATIO",
                          DIRECTION_WALL_RATIO_DEFAULT)
    g = rmat(12, 16, seed=5)      # skewed: hub-heavy Kronecker
    store = build_block_store(g, 8)

    def compiled(d):
        return compile_plan(bfs_algorithm(0), store, mode="sparse_only",
                            backend=backend, share=False, direction=d)

    def timed_run(plan):
        t0 = time.perf_counter()
        res = plan.run()
        return res, time.perf_counter() - t0

    push_plan, auto_plan = compiled("push"), compiled(direction)
    push_plan.run()               # warm: compile outside the timings
    auto_plan.run()

    (push_res, push_s), _ = best_of(
        lambda: timed_run(push_plan), attempts=repeats,
        score=lambda rs: -rs[1])
    (auto_res, auto_s), _ = best_of(
        lambda: timed_run(auto_plan), attempts=repeats,
        score=lambda rs: -rs[1],
        good_enough=lambda rs: rs[1] <= wall_gate * push_s)

    def checksum(res):
        return {k: int(np.asarray(v, dtype=np.int64).sum())
                for k, v in res.result.items()}

    dstats = auto_res.schedule_stats["direction"]
    cs, push_cs = checksum(auto_res), checksum(push_res)
    wall_ratio = auto_s / push_s if push_s > 0 else float("inf")
    checks = dict(
        pull_engaged=dstats["pull_iterations"] >= 1,
        checksum_exact=cs == push_cs,
        wall=wall_ratio <= wall_gate,
    )
    payload = obs.export.run_report("direction_smoke", dict(
        graph="rmat(12, 16, seed=5)", direction=direction,
        floors=dict(wall_ratio=wall_gate),
        push_s=round(push_s, 5), auto_s=round(auto_s, 5),
        wall_ratio=round(wall_ratio, 4),
        iterations=auto_res.iterations,
        decisions=dstats["decisions"],
        densities=[round(d, 4) for d in dstats["densities"]],
        switches=dstats["switches"],
        pull_iterations=dstats["pull_iterations"],
        beta=dstats["beta"], hysteresis=dstats["hysteresis"],
        checksum=cs, push_checksum=push_cs,
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


def run_chaos_smoke(out_path: str = "BENCH_resilience.json", *,
                    repeats: int = 3, backend: str = "xla") -> bool:
    """The CI chaos-smoke gate (and its ``BENCH_resilience.json``
    artifact).

    Seeded fault injection on a ≥4-wave streamed run, one leg per
    executor seam (``repro.core.faults``):

    * **checksum-exact recovery**: every raise-type leg (assemble,
      device_put, compute, plus a delay stall) must finish bit-identical
      to the fault-free PageRank run — retries replay from
      iteration-start state over the SAME wave partition, so even float
      attributes match exactly;
    * **OOM degradation**: an injected device OOM on integer-label
      Shiloach–Vishkin must shrink-repack (``oom_repacks >= 1``) and
      still land the exact label checksum;
    * **bounded overhead**: each recovered leg's best-of-``repeats``
      wall must stay within ``REPRO_CHAOS_WALL_RATIO`` of the fault-free
      baseline's MEDIAN wall on the same warm plan (one replayed
      iteration out of the whole run; the median denominator keeps the
      gate about recovery cost, not the CI container's run-to-run wall
      noise).

    All legs run synchronously (``pipeline_depth=0``) so a seeded
    assembly fault exercises the retry ladder, not the worker-death
    failover — that path is covered deterministically in the test
    suite.  Returns True when every check passed.
    """
    import time

    import numpy as np

    from repro import obs
    from repro.core import build_block_store, compile_plan, rmat
    from repro.algorithms import pagerank_algorithm, sv_algorithm

    wall_gate = env_float("REPRO_CHAOS_WALL_RATIO", CHAOS_WALL_RATIO_DEFAULT)
    g = rmat(12, 16, seed=5)
    store = build_block_store(g, 8)
    budget = "256KB"

    def plan(factory, **kw):
        return compile_plan(factory(), store, mode="sparse_only",
                            backend=backend, share=False,
                            memory_budget=budget, pipeline_depth=0,
                            rebalance_threshold=None, **kw)

    def timed(p):
        t0 = time.perf_counter()
        res = p.run()
        return res, time.perf_counter() - t0

    base_plan = plan(pagerank_algorithm)
    base_plan.run()                     # warm: compile + calibration
    base_runs = [timed(base_plan) for _ in range(max(repeats, 3))]
    base_res = base_runs[0][0]
    base_s = float(np.median([s for _, s in base_runs]))
    base_arr = np.asarray(base_res.result)
    waves = base_res.schedule_stats["streaming"]["num_waves"]

    SPECS = dict(
        assemble="stage.assemble:raise:at(1)",
        device_put="stage.device_put:raise:at(1)",
        compute="wave.compute:raise:at(1)",
        stall="stage.device_put:delay(0.005):once",
    )
    legs: dict = {}
    for name, spec in SPECS.items():
        p = plan(pagerank_algorithm, faults=spec)
        p.run()                         # warm (injects + recovers once)

        def _attempt(p=p):
            # single-shot rules re-arm so EVERY timed attempt pays one
            # full recovery, not just the first
            p._faults.reset()
            return timed(p)

        (res, wall), _ = best_of(
            _attempt, attempts=repeats, score=lambda rs: -rs[1],
            good_enough=lambda rs: rs[1] <= wall_gate * base_s)
        r = res.schedule_stats["resilience"]
        legs[name] = dict(
            spec=spec,
            injected=r["injected"], retries=r["retries"],
            seconds=round(wall, 4),
            wall_ratio=round(wall / base_s, 4) if base_s > 0 else None,
            exact=bool(np.array_equal(np.asarray(res.result), base_arr)),
        )

    sv_base = np.asarray(plan(sv_algorithm).run().result)
    oom_res = plan(sv_algorithm, faults="wave.compute:oom:at(1)").run()
    oom_r = oom_res.schedule_stats["resilience"]
    oom_labels = np.asarray(oom_res.result)
    oom = dict(
        spec="wave.compute:oom:at(1)",
        injected=oom_r["injected"], oom_repacks=oom_r["oom_repacks"],
        demotions=oom_r["demotions"],
        # labels compare elementwise — the checksum alone is degenerate
        # on a connected graph (every label collapses to vertex 0)
        exact=bool(np.array_equal(oom_labels, sv_base)),
        checksum=int(oom_labels.astype(np.int64).sum()),
        components=int(np.unique(oom_labels).size),
    )

    checks = dict(
        multi_wave=waves >= 4,
        all_sites_injected=all(c["injected"] >= 1 for c in legs.values()),
        recovered_exact=all(c["exact"] for c in legs.values()),
        wall=all(c["wall_ratio"] is not None and c["wall_ratio"] <= wall_gate
                 for c in legs.values()),
        oom_repacked=oom["oom_repacks"] >= 1,
        oom_exact=oom["exact"],
    )
    payload = obs.export.run_report("chaos_smoke", dict(
        graph="rmat(12, 16, seed=5)", budget=budget, waves=waves,
        floors=dict(wall_ratio=wall_gate),
        baseline_s=round(base_s, 4),
        legs=legs, oom=oom,
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


def run_mesh_streaming(g, *, repeats: int = 3, backend: str = "xla",
                       memory_budget: str | None = None,
                       mesh_devices: int = 8) -> list[str]:
    """Budgeted waves through ``shard_map`` vs the single-device
    streaming baseline at the same *per-device* budget.

    Per pair of rows: ``mesh1`` is the baseline (1 device stages and
    computes every wave alone), ``meshN`` runs each wave cooperatively
    over the N-device mesh — N× the wave capacity, per-device staged
    bytes ≤ the budget, plus the collective payload the combine ops
    (psum/pmin/pmax) moved.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import build_block_store, choose_p, compile_plan
    from repro.algorithms import pagerank_algorithm, tc_algorithm
    from repro.algorithms.tc import orient_dag
    from repro.kernels.registry import workspace_bytes

    avail = len(jax.devices())
    d = min(mesh_devices, avail)
    mesh = Mesh(np.array(jax.devices()[:d]), ("blocks",))
    budgets = [memory_budget] if memory_budget else ["64KB"]
    rows = []
    dag = orient_dag(g)
    for budget in budgets:
        jobs = [
            ("pr", pagerank_algorithm,
             build_block_store(g, max(choose_p(g, budget, devices=d), 4))),
            ("tc", tc_algorithm,
             build_block_store(
                 dag, max(choose_p(dag, budget, safety=12, devices=d), 4))),
        ]
        for name, alg_f, store in jobs:
            for label, use_mesh in ((f"mesh{d}", mesh), ("mesh1", None)):
                try:
                    plan = compile_plan(alg_f(), store, mode="sparse_only",
                                        backend=backend, share=False,
                                        memory_budget=budget, mesh=use_mesh)
                except ValueError as e:
                    rows.append(csv_row(
                        f"oversub/mesh/{name}/{budget}/{label}", 0.0,
                        f"error={e}"))
                    continue
                last: dict = {}

                def timed(plan=plan, last=last):
                    last["res"] = plan.run()

                t = time_median(timed, repeats=repeats)
                st = last["res"].schedule_stats["streaming"]
                # worst-device scratch estimate at this wave spread (the
                # registry's per-device pricing hint)
                ws = workspace_bytes("csr_bucket_search", items=store.m,
                                     depth=8, devices=st["mesh_devices"])
                phases = ";".join(
                    f"{k}_s={v:.4f}"
                    for k, v in st["phase_seconds"].items()
                )
                rows.append(csv_row(
                    f"oversub/mesh/{name}/{budget}/{label}", t,
                    f"devices={st['mesh_devices']};"
                    f"waves={st['num_waves']};"
                    f"budget_bytes={st['budget_bytes']};"
                    f"max_per_device_bytes={max(st['per_device_bytes'], default=0)};"
                    f"collective_bytes={st['collective_bytes']};"
                    f"per_device_scratch_est={ws};"
                    f"bytes_staged_total={st['bytes_staged_total']};"
                    f"{phases};"
                    f"host_stage_overlap={st['host_stage_overlap']:.2f};"
                    f"overlap_efficiency={st['overlap_efficiency']:.2f}",
                ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--backend", default="xla",
                    choices=["reference", "xla", "pallas"])
    ap.add_argument(
        "--memory-budget", default=None,
        help="stream PageRank under this device-memory budget "
             "(bytes or e.g. 256KB) and report waves/bytes/overlap",
    )
    ap.add_argument(
        "--mesh-devices", type=int, default=1,
        help="also run mesh-cooperative streaming over an N-device "
             "host-platform mesh (forces XLA host devices before jax "
             "initializes) and report per-device staged bytes, "
             "collective bytes, and overlap vs the 1-device baseline",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI perf-smoke gate: pipelined vs synchronous staging with "
             "a per-phase breakdown, TC trace-count stability across "
             "wave counts, and the recorded overlap floor — writes "
             "BENCH_stream.json and exits non-zero on regression.  "
             "Combined with --host-fraction it runs the hetero-smoke "
             "gate instead: host lane engaged, wall within the "
             "REPRO_HETERO_WALL_RATIO of device-only, checksum-exact — "
             "writes BENCH_hetero.json",
    )
    ap.add_argument("--smoke-out", default="BENCH_stream.json")
    ap.add_argument(
        "--host-fraction", default=None,
        help="heterogeneous co-scheduling: 'auto' or a float in [0, 1] "
             "forwarded as compile_plan(..., host_fraction=...)",
    )
    ap.add_argument("--hetero-out", default="BENCH_hetero.json")
    ap.add_argument(
        "--direction", default=None, choices=["push", "pull", "auto"],
        help="with --smoke: run the direction-smoke gate instead — "
             "direction-optimizing BFS on a skewed R-MAT must take ≥1 "
             "pull iteration, stay checksum-exact vs fixed push, and "
             "stay within REPRO_DIRECTION_WALL_RATIO of its wall — "
             "writes BENCH_direction.json",
    )
    ap.add_argument("--direction-out", default="BENCH_direction.json")
    ap.add_argument(
        "--chaos", action="store_true",
        help="with --smoke: run the chaos-smoke gate instead — seeded "
             "fault injection per executor seam must recover "
             "checksum-exact within REPRO_CHAOS_WALL_RATIO of the "
             "fault-free wall, and an injected OOM must shrink-repack — "
             "writes BENCH_resilience.json",
    )
    ap.add_argument("--chaos-out", default="BENCH_resilience.json")
    a = ap.parse_args()
    if a.chaos and a.smoke:
        sys.exit(0 if run_chaos_smoke(a.chaos_out, repeats=a.repeats,
                                      backend=a.backend) else 1)
    if a.direction is not None and a.smoke:
        sys.exit(0 if run_direction_smoke(a.direction_out,
                                          repeats=a.repeats,
                                          backend=a.backend,
                                          direction=a.direction) else 1)
    if a.host_fraction is not None:
        hf: "float | str" = (a.host_fraction if a.host_fraction == "auto"
                             else float(a.host_fraction))
        if a.smoke:
            sys.exit(0 if run_hetero_smoke(a.hetero_out, repeats=a.repeats,
                                           backend=a.backend,
                                           host_fraction=hf) else 1)
    if a.smoke:
        sys.exit(0 if run_smoke(a.smoke_out, repeats=a.repeats,
                                backend=a.backend) else 1)
    if a.mesh_devices > 1:
        # must happen before the first jax import (repro imports lazily
        # for exactly this reason): XLA locks the device count at init
        import os

        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{a.mesh_devices}"
            ).strip()
    print("\n".join(run(scale=a.scale, repeats=a.repeats, backend=a.backend,
                        memory_budget=a.memory_budget,
                        mesh_devices=a.mesh_devices)))
