"""Table 1 analog: the five algorithms across the graph-class suite.

The paper reports per-(algorithm × graph) speedups over GAPBS; GAPBS is
not available here, so the table reports PGAbB-JAX hybrid absolute time
per cell with the hybrid/sparse-only speedup as the derived column (the
paper's PGAbB vs PGAbB-CPU-path comparison).
"""
from __future__ import annotations

import numpy as np

from repro.core import build_block_store, compile_plan
from repro.algorithms import (
    afforest_algorithm, bfs_algorithm, pagerank_algorithm, sv_algorithm,
    tc_algorithm,
)
from repro.algorithms.tc import orient_dag
from repro.data import benchmark_suite

from .common import csv_row, time_median

ALGOS = {
    "pr": pagerank_algorithm,
    "sv": sv_algorithm,
    "cc": afforest_algorithm,
    "bfs": lambda: bfs_algorithm(0),
    "tc": tc_algorithm,
}


def _plan_for(algo: str, g, mode: str, p: int = 4, backend: str = "xla"):
    if algo == "tc":
        store = build_block_store(orient_dag(g), p)
    else:
        store = build_block_store(g, p)
    alg = ALGOS[algo]()
    return compile_plan(alg, store, mode=mode, dense_density=0.001,
                        tile_dim=512, backend=backend)


def run(scale: str = "small", repeats: int = 3, backend: str = "xla") -> list[str]:
    rows = []
    graphs = benchmark_suite(scale)
    for gname, g in graphs.items():
        for algo in ALGOS:
            plan_h = _plan_for(algo, g, "hybrid", backend=backend)
            t_h = time_median(lambda: plan_h.run(), repeats=repeats)
            plan_s = _plan_for(algo, g, "sparse_only", backend=backend)
            t_s = time_median(lambda: plan_s.run(), repeats=repeats)
            rows.append(
                csv_row(
                    f"table1/{algo}/{gname}", t_h,
                    f"hybrid_speedup_vs_sparse={t_s / max(t_h, 1e-12):.2f}",
                )
            )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
