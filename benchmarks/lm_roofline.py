"""LM-substrate roofline table: reads the dry-run JSONs — baselines from
runs/dryrun/ (paper-faithful) and hillclimb variants from runs/hillclimb/
(§Perf optimized, keyed by their --tag) — one row per cell with the three
roofline terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS."""
from __future__ import annotations

import glob
import json
import os

from .common import csv_row

RUNS_DIR = os.environ.get("DRYRUN_DIR", "runs/dryrun")
OPT_DIR = os.environ.get("HILLCLIMB_DIR", "runs/hillclimb")


def _rows_from(dirname: str, prefix: str) -> list[str]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(fn))
        tag = os.path.basename(fn).rsplit("__", 1)[-1].removesuffix(".json")
        suffix = f"/{tag}" if prefix == "lm_opt" else ""
        name = f"{prefix}/{d['arch']}/{d['shape']}/{d['mesh']}{suffix}"
        if d["status"] != "ok":
            rows.append(csv_row(name, 0.0, d["status"]))
            continue
        r = d["roofline"]
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / bound if bound else 0.0
        ratio = d.get("useful_flops_ratio")
        rows.append(csv_row(
            name, bound,
            f"dominant={r['dominant']};roofline_frac={frac:.3f};"
            f"useful_flops_ratio={(ratio or 0):.3f}",
        ))
    return rows


def run(scale: str = "small", repeats: int = 1) -> list[str]:
    rows = _rows_from(RUNS_DIR, "lm")
    if not rows:
        rows = [csv_row("lm_roofline/missing", 0.0,
                        f"no dry-run JSONs under {RUNS_DIR}")]
    if os.path.isdir(OPT_DIR):
        rows += _rows_from(OPT_DIR, "lm_opt")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
