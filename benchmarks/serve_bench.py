"""Graph-serving benchmark: cross-query batching vs sequential serving.

The serving claim to measure: N compatible queries batched along the
leading query axis run as ONE device step per iteration, so total
device steps shrink toward ``max(iters)`` instead of ``sum(iters)``
— and batching is semantics-preserving (results identical to serving
each query alone).

Two entry points:

* the sweep — ``run()`` serves the same multi-seed PageRank workload
  through :class:`~repro.serve.graphserve.GraphServer` at
  ``max_batch`` ∈ {1, 2, 4, 8} and reports device steps, batch
  occupancy, and latency percentiles per point;
* the gate — ``--smoke`` (the CI serve-smoke job) compares batch-8
  against sequential (batch-1) serving of 8 seeded PageRank queries,
  checks the step-count reduction meets :data:`SMOKE_STEP_REDUCTION`
  (≥2×), checks batched results are identical to the sequential runs,
  records p50/p95/p99 latency for both modes, and writes everything to
  ``BENCH_serve.json`` (the build artifact).

CLI: ``python -m benchmarks.serve_bench [--smoke] [--smoke-out F]``.
"""
from __future__ import annotations

import argparse
import json
import sys

from .common import csv_row

# Recorded floor for the CI serve-smoke gate: serving 8 compatible
# seeded-PageRank queries at max_batch=8 must execute at most half the
# device steps the sequential (max_batch=1) server does.  The ideal
# reduction is ~8x (one fused step per iteration instead of eight);
# freeze-on-convergence makes the batched run pay max(iters) rather
# than sum(iters), so only a structural regression — batching silently
# degrading to per-query execution — can cross a 2x floor.
SMOKE_STEP_REDUCTION = 2.0


def _workload(n_queries: int = 8):
    """A registered server factory plus the query list (seeded PR)."""
    from repro.core import build_block_store, rmat

    g = rmat(10, 8, seed=7)
    store = build_block_store(g, 4)

    def make_server(max_batch: int):
        from repro.serve import GraphServer

        srv = GraphServer(max_batch=max_batch)
        srv.register_graph("g", store)
        return srv

    queries = [("pagerank", dict(seeds=[17 * i + 3]))
               for i in range(n_queries)]
    return make_server, queries


def _serve(make_server, queries, *, max_batch: int):
    """Drain the workload once; returns (stats block, results by uid)."""
    from repro.serve import Query

    srv = make_server(max_batch)
    uids = [srv.submit(Query("g", kind, dict(params)))
            for kind, params in queries]
    done = srv.drain()
    return srv.stats(), [done[u].result for u in uids]


def run(repeats: int = 1) -> list[str]:
    import numpy as np

    make_server, queries = _workload()
    rows = []
    for mb in (1, 2, 4, 8):
        st, _ = _serve(make_server, queries, max_batch=mb)
        lat = st["latency_s"] or {}
        rows.append(csv_row(
            f"serve/pr_multiseed/batch_{mb}",
            float(np.mean([v for v in lat.values()]) if lat else 0.0),
            f"steps={st['steps_executed']};batches={st['batches']};"
            f"occupancy={st['batch_occupancy']};"
            f"p50_s={lat.get('p50')};p95_s={lat.get('p95')};"
            f"p99_s={lat.get('p99')}",
        ))
    return rows


def run_smoke(out_path: str = "BENCH_serve.json") -> bool:
    """The CI serve-smoke gate (and its ``BENCH_serve.json`` artifact).

    Serves 8 seeded-PageRank queries sequentially (max_batch=1) and
    batched (max_batch=8); gates the device step-count reduction at
    :data:`SMOKE_STEP_REDUCTION` and requires batched results to be
    identical to the sequential ones.  Returns True when every check
    passed.
    """
    import numpy as np

    make_server, queries = _workload()
    modes: dict = {}
    results: dict = {}
    for label, mb in (("sequential", 1), ("batched", 8)):
        st, res = _serve(make_server, queries, max_batch=mb)
        results[label] = res
        modes[label] = dict(
            max_batch=mb,
            steps_executed=st["steps_executed"],
            batches=st["batches"],
            batch_occupancy=st["batch_occupancy"],
            admitted=st["admitted"],
            completed=st["completed"],
            latency_s=st["latency_s"],
        )
    reduction = (modes["sequential"]["steps_executed"]
                 / max(modes["batched"]["steps_executed"], 1))
    # int/bool query attributes are bit-identical under batching (the
    # tier-1 tests assert that for BFS); PageRank ranks are float, where
    # XLA may fuse the batched SpMV's summation differently — gate at a
    # tight tolerance and record the worst deviation
    max_abs_diff = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(results["sequential"], results["batched"])
    )
    same = max_abs_diff <= 1e-7
    checks = dict(
        all_completed=(modes["batched"]["completed"] == len(queries)
                       and modes["sequential"]["completed"] == len(queries)),
        full_occupancy=modes["batched"]["batch_occupancy"] == 1.0,
        step_reduction=reduction >= SMOKE_STEP_REDUCTION,
        results_match=same,
        percentiles_recorded=all(
            modes[m]["latency_s"] is not None
            and all(k in modes[m]["latency_s"] for k in ("p50", "p95", "p99"))
            for m in modes
        ),
    )
    from repro import obs

    payload = obs.export.run_report("serve_smoke", dict(
        workload="8x pagerank(seeds=[...]) on rmat(10, 8, seed=7)",
        floors=dict(step_reduction=SMOKE_STEP_REDUCTION),
        **modes,
        step_reduction=round(reduction, 2),
        max_abs_result_diff=max_abs_diff,
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI serve-smoke gate: batched vs sequential multi-seed "
             "PageRank step-count reduction and latency percentiles — "
             "writes BENCH_serve.json and exits non-zero on regression",
    )
    ap.add_argument("--smoke-out", default="BENCH_serve.json")
    a = ap.parse_args()
    if a.smoke:
        sys.exit(0 if run_smoke(a.smoke_out) else 1)
    print("\n".join(run()))
