"""Scheduling-mode ablation (paper §5.2–5.4 PGAbB vs PGAbB-GPU columns):
sparse-only vs dense-only vs hybrid per algorithm, plus the scheduler's
knobs (dense_frac cut-off sweep) and LPT makespan quality."""
from __future__ import annotations

import numpy as np

from repro.core import build_block_store, build_schedule, compile_plan
from repro.algorithms import pagerank_algorithm, tc_algorithm, bfs_algorithm
from repro.algorithms.tc import orient_dag
from repro.data import benchmark_suite

from .common import csv_row, time_median

MODES = ["sparse_only", "dense_only", "hybrid"]


def run(scale: str = "small", repeats: int = 3, backend: str = "xla") -> list[str]:
    rows = []
    g = benchmark_suite(scale)["kron"]
    dag = orient_dag(g)

    # mode ablation on TC (the paper's most mode-sensitive kernel)
    for mode in MODES:
        store = build_block_store(dag, 4)
        plan = compile_plan(tc_algorithm(), store, mode=mode, tile_dim=512,
                            dense_density=0.001, backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        st = plan.schedule.stats
        rows.append(csv_row(
            f"sched/tc/{mode}", t,
            f"dense_tasks={st['dense_tasks']};makespan={st['makespan_ratio']:.2f}",
        ))

    # PageRank mode ablation
    for mode in MODES[:1] + MODES[2:]:
        store = build_block_store(g, 4)
        plan = compile_plan(pagerank_algorithm(), store, mode=mode,
                            dense_density=0.001, backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        rows.append(csv_row(f"sched/pr/{mode}", t))

    # cut-off (dense_frac) sweep — the paper's GPU cut-off knob
    for frac in (0.1, 0.3, 0.5, 0.8):
        store = build_block_store(dag, 4)
        plan = compile_plan(tc_algorithm(), store, mode="hybrid",
                            dense_frac=frac, dense_density=0.001,
                            tile_dim=512, backend=backend)
        t = time_median(lambda: plan.run(), repeats=repeats)
        rows.append(csv_row(
            f"sched/tc/cutoff_{frac}", t,
            f"dense_weight_frac={plan.schedule.stats['dense_weight_frac']:.2f}",
        ))

    # LPT packing quality across device counts (straggler headroom)
    store = build_block_store(g, 8)
    for d in (2, 4, 8, 16):
        sched = build_schedule(pagerank_algorithm(), store, num_devices=d,
                               mode="sparse_only")
        rows.append(csv_row(
            f"sched/lpt_devices_{d}", 0.0,
            f"makespan_ratio={sched.makespan_ratio():.3f}",
        ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
