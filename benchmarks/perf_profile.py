"""Performance profiles (paper Fig. 3, Dolan–Moré): fraction of
(algorithm × graph) instances each scheduling mode solves within factor
τ of the per-instance best.

Timing is span-driven: every measured run executes under a
``repro.obs`` span (``profile.run`` with mode/instance/repeat
attributes), and the per-instance medians are derived from the recorded
span durations — the tracer is the single timing source, replacing the
module's old private stopwatch shims.  The same buffer is exported as
``perf_profile.perfetto.json``, so a profile sweep leaves behind a
loadable timeline (one ``profile.run`` span per measured repeat, with
the executors' own iteration/phase spans nested inside).
"""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.core import build_block_store, compile_plan
from repro.algorithms import (
    afforest_algorithm, bfs_algorithm, pagerank_algorithm, sv_algorithm,
    tc_algorithm,
)
from repro.algorithms.tc import orient_dag
from repro.data import benchmark_suite

from .common import csv_row

MODES = ["sparse_only", "dense_only", "hybrid"]
TAUS = [1.0, 1.1, 1.25, 1.5, 2.0, 4.0]

#: Timeline artifact the sweep leaves behind (Chrome-trace JSON).
TRACE_PATH = "perf_profile.perfetto.json"


def _median_span_s(tr: obs.Tracer, **attrs) -> float:
    """Median duration (seconds) of the ``profile.run`` spans matching
    ``attrs`` — the span buffer is the timing record."""
    durs = [ev.dur_ns / 1e9 for ev in tr.spans("profile.run", **attrs)]
    return float(np.median(durs)) if durs else float("inf")


def run(scale: str = "small", repeats: int = 3, backend: str = "xla",
        trace_path: str | None = TRACE_PATH) -> list[str]:
    graphs = benchmark_suite(scale)
    algos = {
        "pr": pagerank_algorithm, "sv": sv_algorithm, "cc": afforest_algorithm,
        "bfs": lambda: bfs_algorithm(0), "tc": tc_algorithm,
    }
    times: dict[str, dict[str, float]] = {m: {} for m in MODES}
    # a dedicated tracer: the sweep records (and exports) its own
    # timeline without clobbering whatever REPRO_TRACE set up
    with obs.tracing(capacity=1 << 18) as tr:
        for gname, g in graphs.items():
            for aname, afac in algos.items():
                inst = f"{aname}/{gname}"
                for mode in MODES:
                    base = orient_dag(g) if aname == "tc" else g
                    store = build_block_store(base, 4)
                    try:
                        plan = compile_plan(afac(), store, mode=mode,
                                            tile_dim=512, dense_density=0.001,
                                            backend=backend)
                        plan.run()      # warm-up: compile outside the spans
                        for rep in range(repeats):
                            with obs.span("profile.run", lane="main",
                                          mode=mode, inst=inst, rep=rep):
                                plan.run()
                        times[mode][inst] = _median_span_s(
                            tr, mode=mode, inst=inst)
                    except Exception:
                        times[mode][inst] = float("inf")
        if trace_path:
            obs.export.write_chrome_trace(trace_path, tr.events())

    instances = sorted(times[MODES[0]])
    best = {
        i: min(times[m][i] for m in MODES) for i in instances
    }
    rows = []
    for mode in MODES:
        for tau in TAUS:
            frac = np.mean([
                times[mode][i] <= tau * best[i] for i in instances
            ])
            rows.append(csv_row(
                f"profile/{mode}/tau_{tau}", 0.0, f"fraction={frac:.3f}"
            ))
    # paper-style headline: in how many instances is hybrid best?
    wins = np.mean([times["hybrid"][i] <= best[i] * 1.0001 for i in instances])
    rows.append(csv_row("profile/hybrid_best_fraction", 0.0,
                        f"fraction={wins:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
