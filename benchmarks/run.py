"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  table1    — 5 algorithms × graph-class suite (paper Table 1)
  sched     — scheduling-mode ablation + cut-off sweep (paper §5.2–5.4)
  profile   — performance profiles (paper Fig. 3)
  oversub   — device-memory oversubscription claim (paper §1/§4.4)
  lm        — LM-substrate roofline cells from the dry-run (assignment)

Usage: PYTHONPATH=src python -m benchmarks.run [--scale small|bench]
                                               [--backend reference|xla|pallas]
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "bench"])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--backend", default="xla", choices=["reference", "xla", "pallas"],
        help="kernel backend for the graph sections (plan registry)",
    )
    ap.add_argument(
        "--only", default=None,
        help="comma list of sections (table1,sched,profile,oversub,lm)",
    )
    args = ap.parse_args(argv)

    from . import lm_roofline, oversub, perf_profile, sched_ablation, table1_graphs

    sections = {
        "table1": table1_graphs.run,
        "sched": sched_ablation.run,
        "profile": perf_profile.run,
        "oversub": oversub.run,
        "lm": lm_roofline.run,
    }
    # the LM section predates the graph-plan API and takes no backend
    graph_sections = {"table1", "sched", "profile", "oversub"}
    chosen = args.only.split(",") if args.only else list(sections)

    print("name,us_per_call,derived")
    for sec in chosen:
        kw = dict(scale=args.scale, repeats=args.repeats)
        if sec in graph_sections:
            kw["backend"] = args.backend
        try:
            for row in sections[sec](**kw):
                print(row)
        except Exception as e:  # noqa: BLE001 — report, continue suite
            print(f"{sec}/ERROR,0.0,{type(e).__name__}: {e}", file=sys.stdout)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
