"""The CI obs-smoke gate: the telemetry layer must be loadable and free.

Two promises the unified telemetry layer makes, checked on a ≥4-wave
streamed PageRank run:

* **The exported timeline is valid.**  A traced run's Chrome-trace JSON
  parses, timestamps are monotonic in file order, every pipeline phase
  (assemble / device_put / compute, plus the per-iteration span) is
  present, and the ``main`` / ``staging`` / per-device lanes all
  appear — i.e. the artifact actually loads in ``ui.perfetto.dev`` and
  shows the three-stage pipeline.

* **Tracing is (near-)free.**  Traced wall time must stay within
  ``REPRO_SMOKE_OVERHEAD_RATIO`` of untraced on the same warm plan —
  ``repeats`` interleaved alternating-order pairs per attempt, ratio
  of means, best of up to three attempts (noise only ever inflates the
  ratio: the tracer adds work, it never removes any), compile and
  calibration excluded — so turning ``REPRO_TRACE`` on in production
  costs nothing measurable.

Writes the unified run-report to ``BENCH_obs.json`` and leaves the
validated timeline at ``obs_smoke.perfetto.json`` (both build
artifacts).  CLI: ``python -m benchmarks.obs_smoke --smoke``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from .common import best_of, env_float

#: Traced wall time may be at most this multiple of untraced.
#: Override with ``REPRO_SMOKE_OVERHEAD_RATIO`` (default 1.05) when a
#: CI runner is noisy enough that the default gate flakes.  Read inside
#: run_smoke (env_float validates through repro.core.knobs → jax; the
#: benchmark entrypoints must stay importable before XLA_FLAGS is set).
SMOKE_OVERHEAD_RATIO_DEFAULT = 1.05

REQUIRED_LANES = ("main", "staging", "device/0")
REQUIRED_PHASES = ("assemble", "device_put", "compute", "iteration")


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run_smoke(out_path: str = "BENCH_obs.json", *,
              trace_path: str = "obs_smoke.perfetto.json",
              repeats: int = 8, backend: str = "xla") -> bool:
    from repro import obs
    from repro.core import build_block_store, compile_plan, rmat
    from repro.algorithms import pagerank_algorithm

    g = rmat(12, 16, seed=5)
    plan = compile_plan(pagerank_algorithm(), build_block_store(g, 8),
                        mode="sparse_only", backend=backend, share=False,
                        memory_budget="256KB", pipeline_depth=2,
                        rebalance_threshold=None)
    # warm: compile + calibration happen here, outside both timings
    res = plan.run()
    waves = res.schedule_stats["streaming"]["num_waves"]

    # One attempt: `repeats` interleaved pairs with alternating order
    # (so load drift on a shared CI runner hits both sides equally),
    # ratio of means.  Noise can only inflate the ratio — the tracer
    # adds work, never removes it — so the gate takes the best of up to
    # three attempts and stops early once one lands under the bar.
    events, dropped = [], 0

    def _run_traced(traced: list) -> None:
        nonlocal events, dropped
        with obs.tracing(capacity=1 << 18) as tr:
            traced.append(_timed(plan.run))
            events, dropped = tr.events(), tr.dropped

    def _attempt() -> tuple[float, float]:
        untraced, traced = [], []
        for i in range(repeats):
            if i % 2:
                _run_traced(traced)
                untraced.append(_timed(plan.run))
            else:
                untraced.append(_timed(plan.run))
                _run_traced(traced)
        return sum(untraced) / len(untraced), sum(traced) / len(traced)

    overhead_gate = env_float("REPRO_SMOKE_OVERHEAD_RATIO",
                              SMOKE_OVERHEAD_RATIO_DEFAULT)
    (untraced_s, traced_s), scores = best_of(
        _attempt, attempts=3,
        score=lambda ut: -(ut[1] / ut[0]),
        good_enough=lambda ut: ut[1] / ut[0] <= overhead_gate,
    )
    attempts = [round(-s, 4) for s in scores]
    trace = obs.export.write_chrome_trace(trace_path, events)

    try:
        summary = obs.export.validate_chrome_trace(
            json.dumps(trace), require_lanes=REQUIRED_LANES,
            require_phases=REQUIRED_PHASES)
        trace_error = None
    except ValueError as e:        # pragma: no cover — the gate's teeth
        summary, trace_error = dict(lanes=[], span_counts={}, events=0), str(e)

    overhead = traced_s / untraced_s if untraced_s > 0 else float("inf")
    checks = dict(
        multi_wave=waves >= 4,
        trace_valid=trace_error is None,
        nothing_dropped=dropped == 0,
        overhead=overhead <= overhead_gate,
    )
    payload = obs.export.run_report("obs_smoke", dict(
        graph="rmat(12, 16, seed=5)", budget="256KB", waves=waves,
        floors=dict(overhead_ratio=overhead_gate),
        untraced_s=round(untraced_s, 5), traced_s=round(traced_s, 5),
        overhead_ratio=round(overhead, 4), overhead_attempts=attempts,
        trace=dict(path=trace_path, lanes=summary["lanes"],
                   span_counts=summary["span_counts"],
                   events=summary["events"], dropped=dropped,
                   error=trace_error),
        checks=checks,
        passed=all(checks.values()),
    ))
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    return payload["passed"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI obs-smoke gate: validate the exported timeline and the "
             "traced-vs-untraced overhead ratio; writes BENCH_obs.json",
    )
    ap.add_argument("--smoke-out", default="BENCH_obs.json")
    ap.add_argument("--trace-out", default="obs_smoke.perfetto.json")
    ap.add_argument("--repeats", type=int, default=8)
    a = ap.parse_args()
    if a.smoke:
        sys.exit(0 if run_smoke(a.smoke_out, trace_path=a.trace_out,
                                repeats=a.repeats) else 1)
    ap.print_help()
