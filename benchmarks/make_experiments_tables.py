"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONs.  Usage:

  PYTHONPATH=src python -m benchmarks.make_experiments_tables [runs/dryrun]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(runs_dir: str):
    cells = []
    for fn in sorted(glob.glob(os.path.join(runs_dir, "*.json"))):
        cells.append(json.load(open(fn)))
    return cells


def dryrun_table(cells) -> str:
    rows = [
        "| arch | shape | mesh | status | params | bytes/device (arg+tmp) | "
        "compile s | collective schedule (per-chip bytes by kind) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        if d["status"] == "ok":
            mem = d["memory"]
            args = fmt_bytes(mem["argument_bytes"])
            tmp = fmt_bytes(mem["temp_bytes"])
            coll = ", ".join(
                f"{k}:{fmt_bytes(v)}"
                for k, v in sorted(d["collectives"]["per_kind"].items())
            ) or "none"
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
                f"{d['params']/1e9:.1f}B | {args} + {tmp} | "
                f"{d['seconds_compile']:.0f} | {coll} |"
            )
        elif d["status"] == "skipped":
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | SKIP | - | - | - | "
                f"{d['reason'].split(';')[0]} |"
            )
        else:
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | **ERROR** | - | - | - | "
                f"{d.get('error','')} |"
            )
    return "\n".join(rows)


def roofline_table(cells, mesh="16x16") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful/HLO | one-line fix for the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        ("compute",): "raise per-chip arithmetic intensity (larger per-device batch, fuse elementwise chains)",
        ("memory",): "cut HBM traffic: fewer remat passes, bf16 loss chunks, fuse norm+matmul, larger loss chunk reuse",
        ("collective",): "reshape the schedule: reduce-scatter grads instead of all-reduce, shrink MoE all-to-all payload, overlap with compute",
    }
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        if d["mesh"] != mesh:
            continue
        if d["status"] != "ok":
            if d["status"] == "skipped":
                rows.append(
                    f"| {d['arch']} | {d['shape']} | - | - | - | skipped | - | - | "
                    f"{d['reason'].split('(')[0].strip()} |"
                )
            continue
        r = d["roofline"]
        ratio = d.get("useful_flops_ratio") or 0.0
        fix = fixes[(r["dominant"],)]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"**{r['dominant']}** | {d['model_flops']:.2e} | {ratio:.3f} | {fix} |"
        )
    return "\n".join(rows)


def pick_hillclimb(cells) -> str:
    ok = [d for d in cells if d["status"] == "ok" and d["mesh"] == "16x16"]

    def frac(d):
        r = d["roofline"]
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        return r["t_compute"] / bound if bound else 1.0

    worst = min(ok, key=frac)
    coll = max(ok, key=lambda d: d["roofline"]["t_collective"])
    lines = [
        f"- worst roofline fraction: {worst['arch']} × {worst['shape']} "
        f"(compute/bound = {frac(worst):.3f}, dominant {worst['roofline']['dominant']})",
        f"- most collective-bound: {coll['arch']} × {coll['shape']} "
        f"(collective term {coll['roofline']['t_collective']:.3f}s)",
    ]
    return "\n".join(lines)


def main():
    runs_dir = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun"
    cells = load(runs_dir)
    print("## §Dry-run\n")
    print(dryrun_table(cells))
    print("\n## §Roofline (single-pod 16×16 = 256 chips)\n")
    print(roofline_table(cells))
    print("\n## hillclimb candidates\n")
    print(pick_hillclimb(cells))


if __name__ == "__main__":
    main()
