"""Shared benchmark utilities: timing protocol mirrors the paper §5 —
multiple runs, median reported, preprocessing (store build) excluded."""
from __future__ import annotations

import time

import numpy as np

__all__ = ["time_median", "csv_row"]


def time_median(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-seconds of fn() over `repeats` runs after `warmup`."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
