"""Shared benchmark utilities: timing protocol mirrors the paper §5 —
multiple runs, median reported, preprocessing (store build) excluded.

The CI smoke gates layer two de-flaking conventions on top:

* every timing threshold is an environment variable with a documented
  default (``env_float``), so a noisy runner can be accommodated in CI
  config instead of by editing source;
* retries go through one shared protocol (``best_of``) — run the
  attempt up to N times, keep the best score, stop early once an
  attempt clears the gate.  Noise on a shared runner only ever
  *degrades* a run (contention adds work, it never removes any), so
  the best attempt is the honest measurement.
"""
from __future__ import annotations

import os
import time

import numpy as np

__all__ = ["time_median", "csv_row", "env_float", "best_of"]


def env_float(name: str, default: float) -> float:
    """Float-valued tuning knob from the environment, with a default.

    Delegates to the validated :mod:`repro.core.knobs` registry (lazily
    — importing this module must not pull in jax before a benchmark's
    ``__main__`` block has set ``XLA_FLAGS``): empty/unset falls back to
    ``default``, a malformed or undeclared knob raises loudly."""
    from repro.core.knobs import env_float as _knob_float

    return _knob_float(name, default)


def best_of(attempt, *, attempts: int = 3, score, good_enough=None):
    """Shared smoke-gate retry protocol: run ``attempt()`` up to
    ``attempts`` times, keep the result with the highest
    ``score(result)``, and stop early once ``good_enough(result)`` (when
    given) returns True.  Returns ``(best_result, scores)`` with one
    score per attempt actually run, in order."""
    best = None
    best_s = -float("inf")
    scores: list[float] = []
    for _ in range(max(int(attempts), 1)):
        r = attempt()
        s = float(score(r))
        scores.append(s)
        if s > best_s:
            best, best_s = r, s
        if good_enough is not None and good_enough(r):
            break
    return best, scores


def time_median(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-seconds of fn() over `repeats` runs after `warmup`."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
