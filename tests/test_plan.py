"""Tests for the typed Context + compiled Plan API and the backend registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    rmat, from_edges, build_block_store, build_schedule, compile_plan,
    BlockAlgorithm, Context, Engine,
)
from repro.core.context import build_context, with_extras
from repro.algorithms import pagerank_algorithm
from repro.kernels import registry


def _permuted_copy(g, seed=0):
    """Same n/m, different labels — a genuinely different graph."""
    perm = np.random.default_rng(seed).permutation(g.n)
    s, d = g.coo()
    return from_edges(perm[s], perm[d], n=g.n)


# ----------------------------------------------------------------- Plan
def test_plan_reuse_across_graphs_compiles_once():
    g1 = rmat(7, 8, seed=3)
    g2 = _permuted_copy(g1)
    assert (g1.n, g1.m) == (g2.n, g2.m)
    s1, s2 = build_block_store(g1, 4), build_block_store(g2, 4)
    plan = compile_plan(pagerank_algorithm(), s1, mode="sparse_only",
                        share=False)
    r1 = plan.run()
    assert plan.compile_count == 1
    r2 = plan.run(s2)
    assert plan.compile_count == 1  # same padded shapes → no retrace
    assert abs(np.asarray(r1.result).sum() - 1.0) < 1e-3
    assert abs(np.asarray(r2.result).sum() - 1.0) < 1e-3


def test_plan_results_match_per_graph_compilation():
    g1 = rmat(7, 8, seed=5)
    g2 = _permuted_copy(g1, seed=1)
    s2a, s2b = build_block_store(g2, 4), build_block_store(g2, 4)
    shared = compile_plan(pagerank_algorithm(), build_block_store(g1, 4),
                          mode="sparse_only", share=False)
    via_reuse = shared.run(s2a).result
    fresh = compile_plan(pagerank_algorithm(), s2b, mode="sparse_only",
                         share=False).run().result
    np.testing.assert_allclose(via_reuse, fresh, atol=1e-7)


def test_cross_plan_step_cache_shared_by_name_and_params():
    g = rmat(6, 6, seed=9)
    s1, s2 = build_block_store(g, 2), build_block_store(g, 2)
    p1 = compile_plan(pagerank_algorithm(), s1, mode="sparse_only")
    p1.run()
    c = p1.compile_count
    p2 = compile_plan(pagerank_algorithm(), s2, mode="sparse_only")
    p2.run()
    assert p2.compile_count == c  # second Plan reused the compiled step
    # different trace-affecting params must NOT share
    p3 = compile_plan(pagerank_algorithm(damping=0.5), s2, mode="sparse_only")
    assert p3._step is not p2._step


def test_plan_iterates_max_iterations_without_after():
    """Regression: the legacy engine silently ran once when after=None."""
    g = rmat(6, 4, seed=0)
    store = build_block_store(g, 2)
    alg = BlockAlgorithm(
        name="count_iters",
        kernel_sparse=lambda ctx, state, it: dict(x=state["x"] + 1),
        init_state=lambda store: dict(x=jnp.asarray(0, jnp.int32)),
        max_iterations=5,
    )
    res = compile_plan(alg, store, mode="sparse_only", share=False).run()
    assert res.iterations == 5
    assert int(res.state["x"]) == 5


def test_bind_respects_explicit_schedule():
    """Regression: a memoized binding must not shadow a caller's schedule."""
    g = rmat(6, 6, seed=4)
    store = build_block_store(g, 2)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False)
    auto = plan.bind(store).schedule
    custom = build_schedule(plan.alg, store, mode="sparse_only", num_devices=2)
    assert custom is not auto
    assert plan.bind(store, custom).schedule is custom
    assert plan.bind(store).schedule is custom  # new binding sticks


def test_binding_cache_is_bounded():
    """Regression: sweeping many graphs through one plan must not retain
    every store's device arrays forever."""
    g = rmat(6, 6, seed=4)
    plan = compile_plan(pagerank_algorithm(), build_block_store(g, 2),
                        mode="sparse_only", share=False)
    stores = [build_block_store(_permuted_copy(g, seed=i), 2)
              for i in range(plan._MAX_BINDINGS + 4)]
    for s in stores:
        plan.run(s)
    assert len(plan._bindings) <= plan._MAX_BINDINGS
    assert any(b is plan._default for b in plan._bindings.values())
    assert plan.compile_count == 1  # eviction never forces a retrace


def test_engine_shim_still_works():
    g = rmat(7, 8, seed=11)
    store = build_block_store(g, 4)
    with pytest.warns(DeprecationWarning):
        eng = Engine(pagerank_algorithm(), store, mode="hybrid",
                     dense_density=0.001)
    res = eng.run()
    assert abs(np.asarray(res.result).sum() - 1.0) < 1e-3
    assert eng.schedule.stats["num_tasks"] == 16


# -------------------------------------------------------------- Context
def _small_context(extras=None):
    g = rmat(6, 4, seed=2)
    store = build_block_store(g, 2)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    return build_context(store, sched, extras=extras or {})


def test_context_roundtrips_through_jit():
    ctx = _small_context(extras={"w": jnp.arange(3.0)})
    out = jax.jit(lambda c: c)(ctx)
    assert isinstance(out, Context)
    np.testing.assert_array_equal(np.asarray(out.src), np.asarray(ctx.src))
    np.testing.assert_array_equal(np.asarray(out.extras["w"]),
                                  np.asarray(ctx.extras["w"]))
    assert out.n == ctx.n and out.backend == ctx.backend
    # flatten/unflatten is an identity on structure
    leaves, treedef = jax.tree_util.tree_flatten(ctx)
    ctx2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert jax.tree_util.tree_structure(ctx2) == treedef


def test_context_extras_preserve_tuples():
    """Regression: the old dict merge rebuilt tuples as lists, silently
    changing the pytree structure between traces."""
    extras = {
        "pair": (jnp.ones(3), jnp.zeros(2)),
        "mixed": (jnp.arange(4), 7, "tag"),
        "nested": {"t": (1, 2, 3), "arrs": [jnp.ones(1), (jnp.ones(2),)]},
        "none": None,
    }
    ctx = _small_context(extras=extras)
    out = jax.jit(lambda c: c)(ctx)
    assert isinstance(out.extras["pair"], tuple)
    assert isinstance(out.extras["mixed"], tuple)
    assert out.extras["mixed"][1] == 7 and out.extras["mixed"][2] == "tag"
    assert out.extras["nested"]["t"] == (1, 2, 3)
    assert isinstance(out.extras["nested"]["arrs"], list)
    assert isinstance(out.extras["nested"]["arrs"][1], tuple)
    assert out.extras["none"] is None
    # identical treedef across two traces of the same structure → one jit entry
    t1 = jax.tree_util.tree_structure(ctx)
    t2 = jax.tree_util.tree_structure(with_extras(ctx, {}))
    assert t1 == t2


def test_context_static_leaves_stay_static_under_jit():
    ctx = _small_context(extras={"steps": 3, "xs": jnp.arange(5.0)})

    @jax.jit
    def f(c):
        # a static int must be usable as a Python shape/loop bound
        acc = jnp.zeros(c.extras["steps"])
        return acc + c.extras["xs"][: c.extras["steps"]]

    np.testing.assert_allclose(np.asarray(f(ctx)), [0.0, 1.0, 2.0])


# ------------------------------------------------------------- registry
def test_registry_resolution_and_fallback(monkeypatch):
    assert registry.resolve_backend("xla") == "xla"
    with pytest.raises(ValueError):
        registry.resolve_backend("cuda")
    monkeypatch.setattr(registry, "_FORCE_PALLAS_AVAILABLE", False)
    assert registry.resolve_backend("pallas") == "xla"
    # kernel lookup walks the fallback chain too
    fn = registry.get_kernel("spmv_tiles", "pallas")
    assert fn is registry.registered("spmv_tiles")["xla"]


def test_compile_plan_pallas_falls_back_cleanly(monkeypatch):
    monkeypatch.setattr(registry, "_FORCE_PALLAS_AVAILABLE", False)
    g = rmat(7, 8, seed=3)
    store = build_block_store(g, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="hybrid",
                        dense_density=0.001, backend="pallas", share=False)
    assert plan.backend == "xla"
    assert abs(np.asarray(plan.run().result).sum() - 1.0) < 1e-3


@pytest.mark.parametrize("backend", ["reference", "xla"])
def test_backends_agree_on_tile_kernels(backend):
    nd, t = 3, 8
    rng = np.random.default_rng(0)
    tiles = jnp.asarray((rng.random((nd, t, t)) < 0.3).astype(np.float32))
    xs = jnp.asarray(rng.random((nd, t)).astype(np.float32))
    want = registry.get_kernel("spmv_tiles", "reference")(tiles, xs)
    got = registry.get_kernel("spmv_tiles", backend)(tiles, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    fcols = jnp.asarray(rng.random((nd, t)) < 0.5)
    want_f = registry.get_kernel("frontier_tiles", "reference")(tiles, fcols)
    got_f = registry.get_kernel("frontier_tiles", backend)(tiles, fcols)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))


def test_no_host_objects_in_context():
    """The typed contract: Context holds no store/schedule, HostCtx does."""
    g = rmat(6, 4, seed=2)
    store = build_block_store(g, 2)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only")
    leaves = jax.tree_util.tree_leaves(plan.context)
    assert all(isinstance(l, (jax.Array, np.ndarray)) for l in leaves)
    assert plan.host.store is store
    assert plan.host.schedule is plan.schedule
