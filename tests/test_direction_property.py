"""Property-based tests (hypothesis) for direction optimization.

Three invariants the differential harness cannot sweep by hand:

* the controller is a pure function of its density trace — replaying a
  trace replays the decisions (and the switch count) exactly;
* an ``auto`` streamed run never stages a wave above its memory
  budget, whatever the budget — the planner prices the max over both
  variants' workspaces, so the mid-run switch cannot blow it;
* pull lands bit-identical to push under randomized graphs *and*
  randomized schedules (partition count, dense split, tile size).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import build_block_store, compile_plan, from_edges
from repro.core.direction import DirectionController
from repro.core.stream import compile_streaming_plan
from repro.algorithms import bfs_algorithm, kcore_algorithm, sv_algorithm

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@st.composite
def random_graph(draw, max_n=64, max_m=160):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(1, max_m))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    # from_edges symmetrizes — the arc-multiset symmetry the pull
    # contract rides on
    return from_edges(np.array(src), np.array(dst), n=n)


density_traces = st.lists(
    st.tuples(st.integers(0, 10_000), st.integers(1, 10_000)),
    min_size=1, max_size=50,
)


@given(density_traces, st.floats(1.0, 64.0), st.floats(0.1, 1.0))
def test_auto_is_deterministic_given_density_trace(trace, beta, hysteresis):
    """Same trace + same knobs ⇒ same decisions, densities, switches."""
    alg = bfs_algorithm(0)

    def replay():
        c = DirectionController(alg, "auto", n=1)
        c.beta, c.hysteresis = beta, hysteresis
        out = []
        for count, pop in trace:
            d = c.decide_density(count, pop)
            c.current = d
            out.append(d)
        return out, c.current

    a = replay()
    b = replay()
    assert a == b


@given(st.lists(st.integers(0, 2000), min_size=1, max_size=50))
def test_switch_count_matches_decision_flips(counts):
    """decide() over a real frontier leaf: switches ≡ adjacent decision
    flips, pull_iterations ≡ pull decisions, one density per call."""
    alg = bfs_algorithm(0)
    c = DirectionController(alg, "auto", n=1000)
    for it, count in enumerate(counts):
        c.decide(dict(nf=np.asarray(count, np.int32)), it)
    s = c.stats()
    flips = sum(1 for a, b in zip(c.decisions, c.decisions[1:]) if a != b)
    assert s["switches"] == flips
    assert s["pull_iterations"] == sum(d == "pull" for d in c.decisions)
    assert len(s["densities"]) == len(counts)


@given(random_graph(), st.sampled_from(["6KB", "12KB", "32KB"]),
       st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_auto_never_exceeds_memory_budget(g, budget, p):
    store = build_block_store(g, p)
    sp = compile_streaming_plan(sv_algorithm(), store, memory_budget=budget,
                                direction="auto")
    rr = sp.run()
    st_ = rr.schedule_stats["streaming"]
    assert all(b <= st_["budget_bytes"] for b in st_["bytes_per_wave"]), st_
    # and the decisions were actually made (one per iteration)
    assert len(rr.schedule_stats["direction"]["decisions"]) == rr.iterations


@given(random_graph(), st.integers(1, 4),
       st.sampled_from([0.0, 0.5, 1.0]),
       st.sampled_from([64, 128, 512]))
@settings(max_examples=10, deadline=None)
def test_pull_matches_push_under_randomized_schedules(g, p, dense_frac,
                                                      tile_dim):
    store = build_block_store(g, p)
    kw = dict(dense_frac=dense_frac, tile_dim=tile_dim)
    for alg_f, pkw in [(lambda: bfs_algorithm(0), {}),
                       (lambda: kcore_algorithm(2),
                        dict(mode="sparse_only")),
                       (sv_algorithm, {})]:
        push = compile_plan(alg_f(), store, direction="push",
                            **kw, **pkw).run().result
        pull = compile_plan(alg_f(), store, direction="pull",
                            **kw, **pkw).run().result
        if isinstance(push, dict):
            for k in push:
                np.testing.assert_array_equal(
                    np.asarray(push[k]), np.asarray(pull[k]), err_msg=k)
        else:
            np.testing.assert_array_equal(np.asarray(push),
                                          np.asarray(pull))
