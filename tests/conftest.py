import numpy as np
import networkx as nx
import pytest

from repro.core import rmat, grid_road, star_skew, erdos_renyi, build_block_store


def to_nx(g):
    G = nx.Graph()
    G.add_nodes_from(range(g.n))
    src, dst = g.coo()
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    return G


@pytest.fixture(scope="session")
def small_graphs():
    """Three structurally different graphs (skewed, road, extreme-skew)."""
    return {
        "rmat": rmat(8, 8, seed=3),
        "road": grid_road(16),
        "star": star_skew(512, hubs=3, seed=1),
        "er": erdos_renyi(400, 6.0, seed=2),
    }


@pytest.fixture(scope="session")
def nx_graphs(small_graphs):
    return {k: to_nx(g) for k, g in small_graphs.items()}


@pytest.fixture(scope="session")
def stores(small_graphs):
    return {k: build_block_store(g, 4) for k, g in small_graphs.items()}
