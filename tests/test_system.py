"""End-to-end behaviour tests for the PGAbB system.

Mirrors the paper's §1 motivating pipeline: connected components → take
the largest component → BFS from a high-degree vertex → triangle count,
all through the public block-based API, plus engine semantics checks
(I_B/I_A ordering, estimation-driven scheduling, hybrid == single-path
results).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import rmat, from_edges, build_block_store, BlockAlgorithm, Engine
from repro.algorithms import (
    pagerank, connected_components, bfs, triangle_count,
)


def test_paper_pipeline_end_to_end():
    g = rmat(9, 8, seed=17)
    store = build_block_store(g, 4)
    # 1. connected components, largest component
    C = connected_components(store)
    labels, counts = np.unique(C, return_counts=True)
    giant = labels[np.argmax(counts)]
    members = np.where(C == giant)[0]
    assert members.size > g.n // 2
    # 2. extract the giant component, re-index
    remap = -np.ones(g.n, np.int64)
    remap[members] = np.arange(members.size)
    s, d = g.coo()
    keep = (C[s] == giant) & (C[d] == giant)
    g2 = from_edges(remap[s[keep]], remap[d[keep]], n=members.size)
    # 3. BFS from the highest degree vertex — all reachable
    store2 = build_block_store(g2, 4)
    out = bfs(store2, source=int(np.argmax(np.diff(g2.indptr))))
    assert np.all(out["dist"] < 2**31 - 1)
    # 4. triangle count on the component
    t = triangle_count(g2, p=4)
    assert t > 0


def test_engine_iteration_hooks_order():
    calls = []

    def before(ctx, state, it):
        calls.append(("B", it))
        return state

    def after(ctx, state, it):
        calls.append(("A", it))
        return state, it < 2

    def kernel(ctx, state, it):
        return state

    alg = BlockAlgorithm(
        name="probe",
        kernel_sparse=kernel,
        init_state=lambda store: dict(x=jnp.zeros(1)),
        before=before,
        after=after,
        max_iterations=10,
    )
    g = rmat(6, 4, seed=0)
    store = build_block_store(g, 2)
    res = Engine(alg, store, mode="sparse_only").run()
    assert res.iterations == 3  # I_A true at it=0,1; false at it=2
    assert calls == [("B", 0), ("A", 0), ("B", 1), ("A", 1), ("B", 2), ("A", 2)]


def test_hybrid_equals_sparse_only():
    g = rmat(9, 8, seed=23)
    s1 = build_block_store(g, 4)
    s2 = build_block_store(g, 4)
    pr_sparse = pagerank(s1, mode="sparse_only")
    pr_hybrid = pagerank(s2, mode="hybrid", dense_density=0.001)
    np.testing.assert_allclose(pr_sparse, pr_hybrid, atol=1e-6)


def test_schedule_stats_exposed():
    g = rmat(9, 8, seed=23)
    store = build_block_store(g, 4)
    from repro.algorithms import pagerank_algorithm

    eng = Engine(pagerank_algorithm(), store, mode="hybrid", dense_density=0.001)
    st = eng.schedule.stats
    assert st["num_tasks"] == 16
    assert st["makespan_ratio"] >= 1.0
    assert 0.0 <= st["dense_weight_frac"] <= 1.0
