"""Unified telemetry layer: tracer, metrics registry, exporters, and
their integration with the executors.

Four layers of coverage:

* tracer units — no-op when disabled, span attributes and nesting,
  ring-buffer bounds, concurrent recording from many threads;
* metrics units — counter/gauge/histogram semantics, the registry's
  create-on-first-use contract, and the bounded histogram's
  within-one-bucket percentile accuracy against exact order statistics;
* exporter units — Chrome-trace structure, per-device lane expansion,
  validation teeth, and the run-report schema's byte-compatibility
  promise;
* integration — the exact per-wave span tree of a ≥4-wave streamed run
  (synchronous pipeline for determinism), spans from the background
  staging worker under ``pipeline_depth=2``, collective spans appearing
  only under a mesh, the serving path's bounded latency percentiles,
  and an 8-device subprocess whose exported timeline carries one lane
  per device plus the staging lane.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import build_block_store, compile_plan, rmat
from repro.core.stream import StreamingPlan
from repro.algorithms import pagerank_algorithm

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------- tracer
def test_disabled_tracer_is_noop():
    assert not obs.enabled()
    assert obs.tracer() is None
    s1 = obs.span("anything", wave=1)
    s2 = obs.span("other")
    assert s1 is s2                     # the shared no-op singleton
    with s1:
        pass
    assert obs.add_span("x", 0.1) is None
    assert obs.instant("x") is None
    with pytest.raises(RuntimeError):
        obs.export.chrome_trace()       # nothing to export


def test_span_records_name_lane_args_and_duration():
    with obs.tracing() as tr:
        with obs.span("work", lane="staging", wave=3, bytes=128):
            pass
        (ev,) = tr.events()
    assert ev.name == "work"
    assert ev.lane == "staging"
    assert ev.args == dict(wave=3, bytes=128)
    assert ev.dur_ns >= 0
    assert ev.end_ns == ev.start_ns + ev.dur_ns


def test_span_nesting_tracks_depth_and_parent():
    with obs.tracing() as tr:
        with obs.span("outer"):
            with obs.span("inner"):
                with obs.span("leaf"):
                    pass
        by_name = {ev.name: ev for ev in tr.events()}
    assert by_name["outer"].depth == 0 and by_name["outer"].parent is None
    assert by_name["inner"].depth == 1 and by_name["inner"].parent == "outer"
    assert by_name["leaf"].depth == 2 and by_name["leaf"].parent == "inner"
    # inner spans close first: recorded leaf-outward
    assert [ev.name for ev in tr.events()] == ["leaf", "inner", "outer"]


def test_default_lane_derives_from_thread():
    with obs.tracing() as tr:
        with obs.span("main_side"):
            pass
        t = threading.Thread(target=lambda: tr.record(
            "worker_side", 0, 1), name="bg-worker")
        t.start()
        t.join()
        lanes = {ev.name: ev.lane for ev in tr.events()}
    assert lanes == dict(main_side="main", worker_side="bg-worker")


def test_ring_buffer_bounds_and_dropped_count():
    with obs.tracing(capacity=8) as tr:
        for i in range(20):
            obs.instant("e", i=i)
        assert len(tr) == 8
        assert tr.dropped == 12
        # the retained spans are the most recent, oldest first
        assert [ev.args["i"] for ev in tr.events()] == list(range(12, 20))
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0


def test_tracer_thread_safety():
    """N threads hammering one tracer: every span lands, none tear."""
    threads, per = 8, 500
    with obs.tracing(capacity=threads * per) as tr:
        def work(tid):
            for i in range(per):
                with obs.span("t", tid=tid, i=i):
                    pass

        ts = [threading.Thread(target=work, args=(k,)) for k in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        evs = tr.events()
        assert tr.dropped == 0
    assert len(evs) == threads * per
    for k in range(threads):
        mine = [ev.args["i"] for ev in evs if ev.args["tid"] == k]
        assert sorted(mine) == list(range(per))


def test_tracing_context_restores_previous_state():
    outer = obs.enable(capacity=16)
    try:
        with obs.tracing() as inner:
            assert obs.tracer() is inner
            assert inner is not outer
        assert obs.tracer() is outer
    finally:
        obs.disable()


# --------------------------------------------------------------- metrics
def test_counter_and_gauge_semantics():
    reg = obs.MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(5)
    g.set(2)
    assert g.value == 2 and g.high_water == 5
    g.set_max(1)                        # ratchet never lowers
    assert g.value == 2
    assert reg.counter("c") is c        # create-on-first-use returns same
    with pytest.raises(TypeError):
        reg.gauge("c")                  # name registered as another type


def test_histogram_percentiles_within_one_bucket():
    """The fixed-bucket estimate lands in the same bucket as the exact
    order statistic, so |estimate - exact| <= that bucket's width."""
    rng = np.random.default_rng(7)
    values = rng.uniform(1e-4, 2.0, size=500)
    h = obs.Histogram("lat")
    for v in values:
        h.observe(v)
    edges = np.asarray(h.edges)
    for q in (50, 95, 99):
        exact = float(np.percentile(values, q, method="inverted_cdf"))
        est = h.percentile(q)
        b = int(np.searchsorted(edges, exact, side="right"))
        lo = edges[b - 1] if b > 0 else h.min
        hi = edges[b] if b < len(edges) else h.max
        assert abs(est - exact) <= hi - lo
    assert h.percentile(50) <= h.percentile(95) <= h.percentile(99)
    assert h.min <= h.percentile(0) and h.percentile(100) <= h.max


def test_histogram_memory_constant_in_observations():
    h = obs.Histogram("lat")
    buckets = len(h._counts)
    for v in np.linspace(1e-5, 10.0, 10_000):
        h.observe(v)
    assert len(h._counts) == buckets    # no per-observation storage
    assert h.count == 10_000
    snap = h.snapshot()
    assert set(snap) == {"count", "sum", "min", "max", "p50", "p95", "p99"}


def test_registry_snapshot_flat_dict():
    reg = obs.MetricsRegistry()
    reg.counter("a.b").inc(3)
    reg.gauge("a.g").set(1.5)
    reg.histogram("a.h").observe(0.25)
    snap = reg.snapshot()
    assert snap["a.b"] == 3
    assert snap["a.g"] == 1.5
    assert snap["a.h"]["count"] == 1


# --------------------------------------------------------------- export
def test_chrome_trace_structure_and_device_lane_expansion():
    with obs.tracing() as tr:
        with obs.span("compute", lane="device", wave=0, devices=3):
            pass
        with obs.span("assemble", lane="staging", wave=0):
            pass
        obj = obs.export.chrome_trace()
        info = obs.export.validate_chrome_trace(
            json.dumps(obj),
            require_lanes=("staging", "device/0", "device/1", "device/2"),
            require_phases=("compute", "assemble"))
    # the device-lane span is mirrored onto every device's track
    assert info["span_counts"]["compute"] == 3
    assert info["span_counts"]["assemble"] == 1
    assert tr.events()                  # buffer untouched by export


def test_validate_chrome_trace_teeth():
    with pytest.raises(ValueError, match="traceEvents"):
        obs.export.validate_chrome_trace({})
    bad_ts = dict(traceEvents=[
        dict(ph="X", pid=1, tid=1, name="a", ts=100.0, dur=1.0, args={}),
        dict(ph="X", pid=1, tid=1, name="b", ts=50.0, dur=1.0, args={}),
    ])
    with pytest.raises(ValueError, match="monotonic"):
        obs.export.validate_chrome_trace(bad_ts)
    neg = dict(traceEvents=[
        dict(ph="X", pid=1, tid=1, name="a", ts=1.0, dur=-2.0, args={}),
    ])
    with pytest.raises(ValueError, match="dur"):
        obs.export.validate_chrome_trace(neg)
    with pytest.raises(ValueError, match="lane"):
        obs.export.validate_chrome_trace(
            dict(traceEvents=[]), require_lanes=("staging",))


def test_run_report_schema_and_byte_compat():
    payload = dict(checks=dict(ok=True), passed=True, floors=dict(x=0.5))
    rep = obs.export.run_report("unit_test", dict(payload),
                                include_metrics=False)
    assert rep["schema"] == obs.export.RUN_REPORT_SCHEMA
    assert rep["schema_version"] == obs.export.RUN_REPORT_VERSION
    assert rep["report"] == "unit_test"
    for k, v in payload.items():        # gate fields stay at top level
        assert rep[k] == v
    with_metrics = obs.export.run_report("unit_test", dict(payload))
    assert isinstance(with_metrics["metrics"], dict)
    with pytest.raises(ValueError, match="collide"):
        obs.export.run_report("x", dict(schema="boom"))


# ----------------------------------------------------------- integration
@pytest.fixture(scope="module")
def graph():
    return rmat(8, 8, seed=3)


def _streamed_plan(graph, depth):
    return compile_plan(pagerank_algorithm(max_iters=3, tol=0.0),
                        build_block_store(graph, 4), mode="sparse_only",
                        share=False, memory_budget="16KB",
                        pipeline_depth=depth, rebalance_threshold=None)


def test_streamed_span_tree_exact(graph):
    """Synchronous (pipeline_depth=0) streamed run: the span tree is
    exactly predictable.  The calibration iteration assembles and steps
    every wave twice (warm-up + timed); later iterations once."""
    plan = _streamed_plan(graph, depth=0)
    assert isinstance(plan, StreamingPlan)
    with obs.tracing() as tr:
        res = plan.run()
        events = tr.events()
    W = res.schedule_stats["streaming"]["num_waves"]
    I = res.iterations
    assert W >= 4 and I == 3
    counts = {}
    for ev in events:
        counts[ev.name] = counts.get(ev.name, 0) + 1
    expect = 2 * W + (I - 1) * W
    assert counts["iteration"] == I
    assert counts["assemble"] == expect
    assert counts["device_put"] == expect
    assert counts["compute"] == expect
    assert "collective" not in counts   # no mesh, no collective spans
    # phase spans nest under their iteration on the main thread
    for ev in events:
        if ev.name in ("device_put", "compute", "assemble"):
            assert ev.parent == "iteration"
    lanes = {ev.name: ev.lane for ev in events}
    assert lanes["assemble"] == "staging"
    assert lanes["device_put"] == "device"
    assert lanes["compute"] == "device"
    assert lanes["iteration"] == "main"
    # per-wave attribution: every wave index shows up in each phase
    for name in ("assemble", "device_put", "compute"):
        waves = {ev.args["wave"] for ev in events if ev.name == name}
        assert waves == set(range(W))


def test_pipelined_run_records_worker_spans(graph):
    """With the background worker on (pipeline_depth=2), assemble spans
    recorded from the staging thread and main-thread spans interleave
    into one buffer without loss."""
    plan = _streamed_plan(graph, depth=2)
    with obs.tracing() as tr:
        res = plan.run()
        events = tr.events()
        assert tr.dropped == 0
    W = res.schedule_stats["streaming"]["num_waves"]
    asm = [ev for ev in events if ev.name == "assemble"]
    # calibration (2W, inline) + overlapped iterations (W each, from the
    # worker); speculative assembly may prefetch part of a never-run
    # epoch, so >= rather than ==
    assert len(asm) >= 2 * W + (res.iterations - 1) * W
    assert {ev.lane for ev in asm} == {"staging"}
    # the traced run is still bit-identical to an untraced one
    want = _streamed_plan(graph, depth=2).run()
    np.testing.assert_allclose(np.asarray(res.result),
                               np.asarray(want.result),
                               rtol=1e-6, atol=1e-9)


def test_collective_spans_only_on_mesh(graph):
    """A 1-device mesh still runs the shard_map step: collective spans
    appear; the plain streamed run records none."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("blocks",))
    plan = compile_plan(pagerank_algorithm(max_iters=2, tol=0.0),
                        build_block_store(graph, 4), mode="sparse_only",
                        share=False, memory_budget="16KB", mesh=mesh,
                        pipeline_depth=0, rebalance_threshold=None)
    with obs.tracing() as tr:
        plan.run()
        names = {ev.name for ev in tr.events()}
    assert "collective" in names
    collect = [ev for ev in tr.events() if ev.name == "collective"]
    assert {ev.lane for ev in collect} == {"device"}
    assert all(ev.args["devices"] == 1 for ev in collect)


def test_streamed_trace_exports_valid_chrome_json(graph, tmp_path):
    plan = _streamed_plan(graph, depth=0)
    path = tmp_path / "run.perfetto.json"
    with obs.tracing():
        plan.run()
        obj = obs.export.write_chrome_trace(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(obj))
    info = obs.export.validate_chrome_trace(
        on_disk, require_lanes=("main", "staging", "device/0"),
        require_phases=("assemble", "device_put", "compute", "iteration"))
    assert info["events"] > 0


def test_serving_stats_bounded_latency():
    """The serving latency block keeps its field names and ordering
    invariant while holding constant memory in the query count."""
    from repro.serve.stats import ServingStats

    st = ServingStats()
    assert st.latency_percentiles() == dict(p50=None, p95=None, p99=None)
    rng = np.random.default_rng(11)
    lats = rng.lognormal(mean=-4.0, sigma=1.0, size=2000)
    for v in lats:
        st.record_latency(v)
    snap = st.snapshot()
    lat = snap["latency_s"]
    assert set(lat) == {"p50", "p95", "p99"}
    assert lat["p50"] <= lat["p95"] <= lat["p99"]
    # accuracy: within one bucket of the exact percentile
    edges = np.asarray(st._latency.edges)
    for q in (50, 95, 99):
        exact = float(np.percentile(lats, q, method="inverted_cdf"))
        b = int(np.searchsorted(edges, exact, side="right"))
        lo = edges[b - 1] if b > 0 else lats.min()
        hi = edges[b] if b < len(edges) else lats.max()
        assert abs(lat[f"p{q}"] - exact) <= hi - lo
    # memory: fixed bucket counts, not a 2000-entry list
    assert len(st._latency._counts) == len(st._latency.edges) + 1
    assert st.completed == 2000


def test_engine_run_is_spanned(graph):
    plan = compile_plan(pagerank_algorithm(max_iters=2, tol=0.0),
                        build_block_store(graph, 4), mode="sparse_only",
                        share=False)
    with obs.tracing() as tr:
        plan.run()
        counts = {}
        for ev in tr.events():
            counts[ev.name] = counts.get(ev.name, 0) + 1
    assert counts["iteration"] == 2
    assert counts["compute"] == 2


def test_metrics_publishing_from_streamed_run(graph):
    obs.REGISTRY.reset()
    try:
        plan = _streamed_plan(graph, depth=0)
        res = plan.run()
        snap = obs.metrics.snapshot()
        st = res.schedule_stats["streaming"]
        assert snap["stream.runs"] == 1
        assert snap["stream.iterations"] == res.iterations
        assert snap["stream.bytes_staged"] == st["bytes_staged_total"]
        assert snap["stream.waves"] == st["num_waves"]
        assert snap["stream.budget_bytes"] == st["budget_bytes"]
        assert 0 < snap["stream.budget_high_water_bytes"] <= st["budget_bytes"]
        assert snap["stream.run_seconds"]["count"] == 1
        for phase in ("assemble", "device_put", "compute"):
            assert snap[f"stream.phase_seconds.{phase}"] >= 0
    finally:
        obs.REGISTRY.reset()


# ------------------------------------- 8-device subprocess composition
def _run_py(code: str, devices: int = 8, timeout: int = 500):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.mark.slow
@pytest.mark.subprocess
def test_mesh_streamed_trace_has_one_lane_per_device():
    """Acceptance: an 8-device mesh streamed run exports a valid trace
    with one lane per device plus the staging lane, carrying per-wave
    assemble / device_put / compute / collective spans."""
    r = _run_py("""
        import json
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro import obs
        from repro.core import build_block_store, compile_plan, rmat
        from repro.algorithms import pagerank_algorithm

        assert len(jax.devices()) == 8, jax.devices()
        mesh = Mesh(np.array(jax.devices()), ("blocks",))
        g = rmat(10, 16, seed=5)
        with obs.tracing() as tr:
            plan = compile_plan(pagerank_algorithm(max_iters=3, tol=0.0),
                                build_block_store(g, 8), mode="sparse_only",
                                share=False, memory_budget="12KB", mesh=mesh,
                                rebalance_threshold=None)
            res = plan.run()
            obj = obs.export.chrome_trace()
        waves = res.schedule_stats["streaming"]["num_waves"]
        lanes = ["main", "staging"] + [f"device/{i}" for i in range(8)]
        info = obs.export.validate_chrome_trace(
            obj, require_lanes=lanes,
            require_phases=("assemble", "device_put", "compute",
                            "collective", "iteration"))
        per_wave = {
            name: sorted({ev.args["wave"] for ev in tr.events()
                          if ev.name == name})
            for name in ("assemble", "device_put", "compute", "collective")
        }
        print(json.dumps(dict(
            waves=waves, lanes=info["lanes"],
            span_counts=info["span_counts"], per_wave=per_wave,
        )))
    """)
    assert r.returncode == 0, r.stderr[-4000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["waves"] >= 4
    for lane in ["main", "staging"] + [f"device/{i}" for i in range(8)]:
        assert lane in out["lanes"]
    # every wave index appears in every phase, collective included
    W = out["waves"]
    for name in ("assemble", "device_put", "compute", "collective"):
        assert out["per_wave"][name] == list(range(W)), name
    # a device-lane span is mirrored onto all 8 device tracks
    assert out["span_counts"]["collective"] % 8 == 0


# ---------------------------------------------------------------------
# metric-catalog conformance: docs/observability.md lists exactly the
# metric names the source publishes — both directions.

def test_metric_catalog_matches_source():
    import re
    from pathlib import Path

    from repro.core.stream import PHASES

    root = Path(__file__).resolve().parents[1]
    doc = (root / "docs" / "observability.md").read_text()
    start = doc.index("The metric catalog")
    table = doc[start:]
    table = table[:table.index("\n\n", table.index("| ---"))]
    doc_names = set(re.findall(r"\| `([a-z_]+(?:\.[a-z_]+)+)` \|", table))
    assert doc_names, "catalog table not found in docs/observability.md"

    published: set = set()
    for path in (root / "src" / "repro").rglob("*.py"):
        src = path.read_text()
        published |= set(re.findall(
            r'(?:counter|gauge|histogram)\(\s*"([a-z_]+(?:\.[a-z_]+)+)"',
            src))
        # the per-phase counters publish through one f-string
        if 'f"stream.phase_seconds.{' in src:
            published |= {f"stream.phase_seconds.{p}" for p in PHASES}

    missing_from_docs = sorted(published - doc_names)
    stale_in_docs = sorted(doc_names - published)
    assert not missing_from_docs, (
        f"published metrics absent from the docs catalog: "
        f"{missing_from_docs}")
    assert not stale_in_docs, (
        f"docs catalog names nothing in src publishes: {stale_in_docs}")

    # and a live streamed + served run publishes only cataloged names
    from repro.core import build_block_store, compile_plan, rmat
    from repro.algorithms import sv_algorithm
    from repro.serve import GraphServer, Query

    obs.REGISTRY.reset()
    store = build_block_store(rmat(8, 8, seed=3), 4)
    compile_plan(sv_algorithm(), store, mode="sparse_only", share=False,
                 memory_budget="16KB", host_fraction=0.3).run()
    srv = GraphServer(max_batch=4)
    srv.register_graph("g", build_block_store(rmat(8, 8, seed=3), 4))
    srv.submit(Query("g", "pagerank", dict(seeds=[1])))
    srv.drain()
    live = set(obs.metrics.snapshot())
    assert live <= doc_names, sorted(live - doc_names)
