"""Per-architecture smoke tests (deliverable f) + decode consistency.

Every assigned arch: instantiate the REDUCED config, run one forward +
one train step on CPU, assert output shapes and no NaNs.  Full configs
are exercised abstractly (eval_shape — no allocation) and via the
dry-run.
"""
import numpy as np
import pytest
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke, list_archs, SHAPES
from repro.models import lm
from repro.models.steps import (
    abstract_params, input_specs, make_serve_step, make_train_step,
    supports_shape,
)
from repro.optim import adamw_init

ARCHS = list_archs()
B, S = 2, 32


def _batch(cfg, key, b=B, s=S):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = dict(tokens=toks, labels=toks)
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        ).astype(lm.Dtype(cfg.dtype).param)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model)
        ).astype(lm.Dtype(cfg.dtype).param)
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(lambda p, b: lm.forward_loss(cfg, p, b))(
        params, batch
    )
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    p2, o2, m = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually changed & shapes preserved
    same_shapes = jax.tree.map(lambda a, b: a.shape == b.shape, params, p2)
    assert all(jax.tree.leaves(same_shapes))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke(arch)
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    state = lm.init_decode_state(cfg, B, 64)
    sb = dict(tokens=jnp.zeros((B,), jnp.int32))
    if cfg.family == "vlm":
        sb["vision"] = jnp.zeros((B, cfg.vision_tokens, cfg.d_model),
                                 lm.Dtype(cfg.dtype).param)
    if cfg.is_encdec:
        sb["memory"] = jnp.zeros((B, cfg.encoder_frames, cfg.d_model),
                                 lm.Dtype(cfg.dtype).param)
    serve = jax.jit(make_serve_step(cfg))
    logits, state = serve(params, state, sb)
    logits, state = serve(params, state, sb)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.float32(logits)).all()
    assert int(state["pos"]) == 2


@pytest.mark.parametrize(
    "arch,fix",
    [
        ("qwen2.5-32b", {}),
        ("qwen3-moe-235b-a22b", dict(capacity_factor=8.0)),  # no-drop routing
        ("deepseek-moe-16b", dict(capacity_factor=8.0)),
        ("hymba-1.5b", {}),
        ("hymba-1.5b", dict(attn_window=8)),  # ring-buffer wraparound
        ("xlstm-1.3b", {}),
        ("llama-3.2-vision-11b", {}),
        ("whisper-base", {}),
    ],
)
def test_decode_matches_prefill(arch, fix):
    """KV-cache/recurrent-state decode reproduces teacher-forced logits."""
    cfg = replace(get_smoke(arch), dtype="float32", **fix)
    key = jax.random.key(1)
    s = 12
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key, b=2, s=s)
    extra = {k: batch[k] for k in ("vision",) if k in batch}
    if cfg.is_encdec:
        extra["memory"] = jax.jit(
            lambda p, f: lm._run_encoder(cfg, p, f)
        )(params, batch["frames"])
    ref = jax.jit(lambda p, b: lm.forward_logits(cfg, p, b))(params, batch)
    state = lm.init_decode_state(cfg, 2, s)
    serve = jax.jit(make_serve_step(cfg))
    for t in range(s):
        logits, state = serve(params, state,
                              dict(tokens=batch["tokens"][:, t], **extra))
        np.testing.assert_allclose(
            np.float32(logits), np.float32(ref[:, t]), atol=2e-4, rtol=1e-3
        )


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "xlstm-1.3b", "hymba-1.5b"])
def test_training_reduces_loss(arch):
    cfg = replace(get_smoke(arch), dtype="float32")
    key = jax.random.key(2)
    params = lm.init_params(cfg, key)
    opt = adamw_init(params)
    batch = _batch(cfg, key, b=4, s=16)  # memorize one batch
    step = jax.jit(
        make_train_step(cfg, base_lr=3e-3, total_steps=100, warmup_steps=5)
    )
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, batch, jnp.int32(i))
        losses.append(float(m["nll"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatched_matches_full_batch_grad_direction():
    cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
    key = jax.random.key(3)
    params = lm.init_params(cfg, key)
    opt = adamw_init(params)
    batch = _batch(cfg, key, b=4, s=16)
    s_full = jax.jit(make_train_step(cfg))
    s_micro = jax.jit(make_train_step(cfg, microbatch=2))
    _, _, m1 = s_full(params, opt, batch, jnp.int32(0))
    _, _, m2 = s_micro(params, opt, batch, jnp.int32(0))
    np.testing.assert_allclose(
        float(m1["nll"]), float(m2["nll"]), rtol=1e-4
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """eval_shape the FULL config (no allocation) and sanity-check size."""
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    # within 2x of the configured family's nameplate (loose sanity band)
    expect = {
        "qwen2.5-32b": 32e9, "qwen1.5-32b": 32e9, "starcoder2-7b": 7e9,
        "granite-3-8b": 8e9, "hymba-1.5b": 1.5e9, "xlstm-1.3b": 1.3e9,
        "qwen3-moe-235b-a22b": 235e9, "deepseek-moe-16b": 16e9,
        "llama-3.2-vision-11b": 11e9, "whisper-base": 72e6,
    }[arch]
    assert 0.4 * expect < total < 2.6 * expect, (arch, total, expect)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_defined(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    ok, why = supports_shape(cfg, sh)
    if not ok:
        pytest.skip(why)
    specs = input_specs(cfg, sh)
    assert "tokens" in specs
    for v in jax.tree.leaves(specs):
        assert isinstance(v, jax.ShapeDtypeStruct)


def test_chunked_attention_matches_full():
    """attn_impl='chunked' (online-softmax scan) == full attention."""
    import numpy as np
    from repro.models.attention import _sdpa, _chunked_sdpa

    rng = np.random.default_rng(0)
    for (b, s, h, hkv, d, causal, win) in [
        (2, 1024, 4, 2, 64, True, 0),
        (1, 1024, 4, 4, 32, False, 0),
        (1, 1024, 4, 2, 64, True, 256),  # sliding window
    ]:
        q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
        a = _sdpa(q, k, v, causal=causal, window=win)
        c = _chunked_sdpa(q, k, v, causal=causal, window=win, block_k=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=2e-5, rtol=1e-4)


def test_forward_loss_same_with_chunked_attn():
    cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
    cfg_c = replace(cfg, attn_impl="chunked")
    key = jax.random.key(5)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key, b=1, s=1024)
    l1, _ = jax.jit(lambda p, b: lm.forward_loss(cfg, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: lm.forward_loss(cfg_c, p, b))(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
