"""Out-of-core streaming executor: footprint model, wave packing, and
streamed-vs-in-core equivalence for every algorithm.

Equivalence contract (stream.py module docstring): streamed runs fold
per-wave partials with the algorithm's declared combine op from the
iteration-start state, so results are *bit-identical* to in-core for
integer/bool attributes (SV, CC, BFS, k-core, TC) and equal up to float
summation order for real ones (PageRank, HITS).
"""
import numpy as np
import pytest

from repro.core import (
    rmat, build_block_store, build_schedule, compile_plan, choose_p,
    csr_prefix, MemoryBudget, StreamingPlan, task_footprints, build_waves,
)
from repro.core.membudget import (
    COO_EDGE_BYTES, CSR_INDEX_BYTES, bucket_size, parse_bytes,
    repack_waves, task_csr_edge_counts, tile_bytes,
)
from repro.algorithms import (
    pagerank_algorithm, sv_algorithm, afforest_algorithm, bfs_algorithm,
    kcore_algorithm, hits_algorithm, tc_algorithm,
)
from repro.algorithms.tc import orient_dag


@pytest.fixture(scope="module")
def graph():
    return rmat(8, 8, seed=3)


@pytest.fixture(scope="module")
def dag(graph):
    return orient_dag(graph)


# All seven algorithms.  Budgets are sized to force several waves on
# rmat(8, 8) while leaving room for one task (hybrid tasks must fit
# their dense tiles, hence the smaller tile_dim).
ALGORITHMS = [
    ("pagerank", pagerank_algorithm,
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "90KB"),
    ("sv", sv_algorithm, dict(mode="sparse_only"), "16KB"),
    ("afforest", afforest_algorithm, dict(mode="sparse_only"), "16KB"),
    ("bfs", lambda: bfs_algorithm(0),
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "90KB"),
    ("kcore3", lambda: kcore_algorithm(3), dict(mode="sparse_only"), "16KB"),
    ("hits", hits_algorithm, dict(mode="sparse_only"), "16KB"),
    ("tc", tc_algorithm,
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "600KB"),
]


def _assert_equivalent(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind in "fc":
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)
    else:
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name,alg_f,kw,budget",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_streamed_matches_incore(name, alg_f, kw, budget, graph, dag):
    g = dag if name == "tc" else graph
    incore = compile_plan(alg_f(), build_block_store(g, 4), share=False, **kw)
    streamed = compile_plan(alg_f(), build_block_store(g, 4), share=False,
                            memory_budget=budget, **kw)
    assert isinstance(streamed, StreamingPlan)
    r_in, r_st = incore.run(), streamed.run()

    st = r_st.schedule_stats["streaming"]
    if name != "tc":  # tc's task count varies; the others must split ≥4×
        assert st["num_waves"] >= 4
    assert r_st.iterations == r_in.iterations

    ra, rb = r_in.result, r_st.result
    if isinstance(ra, dict):
        assert ra.keys() == rb.keys()
        for k in ra:
            _assert_equivalent(ra[k], rb[k])
    else:
        _assert_equivalent(np.asarray(ra), np.asarray(rb))

    # acceptance: stats report wave count, per-wave staged bytes ≤ budget,
    # and overlap efficiency
    assert st["num_waves"] == len(st["bytes_per_wave"])
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])
    assert 0.0 <= st["overlap_efficiency"] <= 1.0
    assert st["bytes_staged_total"] >= sum(st["bytes_per_wave"])
    assert st["resident_bytes"] > 0


def test_streamed_tc_forces_multiple_waves(dag):
    """TC counterpart of the ≥4-wave requirement (pattern mode).

    The budget must absorb the heaviest triple's staged slab *plus* the
    membership test's declared device scratch (``__workspace_bytes__``)
    — 24KB used to pass only because that scratch went unpriced."""
    plan = compile_plan(tc_algorithm(), build_block_store(dag, 4),
                        mode="sparse_only", share=False,
                        memory_budget="32KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 4
    want = compile_plan(tc_algorithm(), build_block_store(dag, 4),
                        mode="sparse_only", share=False).run().result
    assert res.result == want


# ------------------------------------------------------------ membudget
def test_parse_bytes():
    assert parse_bytes(12345) == 12345
    assert parse_bytes("64KB") == 64_000
    assert parse_bytes("2MiB") == 2 * 2**20
    assert parse_bytes("1.5kb") == 1500
    with pytest.raises(ValueError):
        parse_bytes("sixty four")
    with pytest.raises(ValueError):
        MemoryBudget(0)


def test_bucket_size_ladder():
    assert bucket_size(1) == 8          # floor
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(1000) == 1024
    assert bucket_size(1025) == 2048


def test_footprint_model_prices_coo_and_tiles(graph):
    store = build_block_store(graph, 4)
    alg = pagerank_algorithm()
    sparse_sched = build_schedule(alg, store, mode="sparse_only")
    fp = task_footprints(store, sparse_sched)
    assert fp.shape == (sparse_sched.num_tasks,)
    # sparse single-block tasks price exactly edges × COO bytes
    seg = np.diff(store.block_ptr)
    want = seg[sparse_sched.blocklists[:, 0]] * COO_EDGE_BYTES
    np.testing.assert_array_equal(fp, want)

    hybrid_sched = build_schedule(alg, store, mode="hybrid",
                                  dense_density=0.001, tile_dim=128)
    fp_h = task_footprints(store, hybrid_sched)
    dense = hybrid_sched.dense_task_mask
    assert dense.any()
    # dense tasks additionally price their bitmap tiles (+ workspace)
    assert (fp_h[dense] >= want[dense] + tile_bytes(128)).all()
    np.testing.assert_array_equal(fp_h[~dense], want[~dense])


def test_wave_packing_respects_budget_and_covers_all_tasks(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = MemoryBudget(int(fp.max()) * 2)
    waves = build_waves(store, sched, budget, fp)
    assert len(waves) >= 2
    # no wave's model estimate exceeds the budget
    for w in waves:
        assert fp[w.task_ids].sum() <= budget.total_bytes
        assert w.est_bytes == fp[w.task_ids].sum()
    # union of waves == all tasks, disjointly
    all_ids = np.concatenate([w.task_ids for w in waves])
    assert len(all_ids) == len(set(all_ids.tolist()))
    assert set(all_ids.tolist()) == set(range(sched.num_tasks))


def test_wave_tasks_sorted_for_coalesced_staging(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    waves = build_waves(store, sched, MemoryBudget(int(fp.max()) * 3), fp)
    for w in waves:
        lead = sched.blocklists[w.task_ids, 0]
        assert np.all(np.diff(lead) >= 0)


def test_oversized_task_raises(graph):
    store = build_block_store(graph, 4)
    with pytest.raises(ValueError, match="budget"):
        compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                     share=False, memory_budget=64)


def test_padded_single_task_overflow_raises_not_oversubscribes(graph):
    """Regression: a budget that fits the raw footprint but not the
    bucket-padded slab must raise, never silently stage over budget."""
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = int(fp.max()) + 1  # below the padded slab of the biggest task
    try:
        plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                            share=False, memory_budget=budget)
    except ValueError:
        return  # honest refusal is the expected outcome...
    st = plan.run().schedule_stats["streaming"]  # ...or every wave fits
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])


def test_hoisted_extras_do_not_count_against_budget(graph):
    """Regression: wave-invariant prepare extras are staged once
    (resident), so a budget that fits the padded slabs but not
    slab+extras must still work — not over-split or raise."""
    from repro.core.membudget import COO_EDGE_BYTES

    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    seg = np.diff(store.block_ptr)[sched.blocklists[:, 0]]
    max_padded_slab = int(max(bucket_size(int(e)) for e in seg)) * COO_EDGE_BYTES
    budget = max_padded_slab + 200  # < slab + inv_deg/dangling extras
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget=budget)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])
    assert abs(float(np.asarray(res.result).sum()) - 1.0) < 1e-3


def test_edge_free_iterations_stage_one_wave(graph):
    """Afforest's sampling rounds declare edge_free_iterations: only one
    representative wave (plus the first-k-neighbors prefix CSR) is
    staged for the whole sampling phase, and the staged byte accounting
    reflects the warm-up + calibration passes."""
    store = build_block_store(graph, 4)
    plan = compile_plan(afforest_algorithm(), store,
                        mode="sparse_only", share=False, memory_budget="16KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    bpw = st["bytes_per_wave"]
    k_rounds = 2  # afforest default
    n_final = res.iterations - k_rounds
    assert n_final >= 1
    # sampling: wave 0 + the prefix CSR staged once, cached across
    # rounds; first final iteration: warm-up + timed calibration pass
    # (2× all waves); remaining finals: 1× all waves
    prefix_bytes = (store.n + 1) * 8 + store.n * k_rounds * 4
    assert st["edge_free_prefix_bytes"] == prefix_bytes
    expected = prefix_bytes + bpw[0] + (n_final + 1) * sum(bpw)
    assert st["bytes_staged_total"] == expected
    want = compile_plan(afforest_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False).run().result
    np.testing.assert_array_equal(np.asarray(res.result), np.asarray(want))


def test_streaming_plan_is_rebound_safely(graph):
    plan = compile_plan(pagerank_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False, memory_budget="64KB")
    other = build_block_store(graph, 4)
    with pytest.raises(TypeError, match="bound to the store"):
        plan.run(other)


def test_wave_slabs_stay_bucketed(graph):
    """All waves of one plan share a handful of padded slab shapes, so
    the jitted step does not retrace per wave."""
    plan = compile_plan(pagerank_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False, memory_budget="16KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 4
    assert len(st["edge_buckets"]) <= 3     # power-of-two ladder
    for b in st["edge_buckets"]:
        assert b == bucket_size(b)
    # one trace per (slab shape × run_dense) — far fewer than waves
    assert plan.compile_count <= len(st["edge_buckets"]) + 1


# ------------------------------------------------------- CSR streaming
def test_csr_slices_round_trip(graph):
    """Rebased row_block_ptr round-trip: every selected (row, stripe)
    slice of the staged adjacency equals the same slice of the global
    CSR; unselected slices collapse to zero length."""
    store = build_block_store(graph, 4)
    p = store.p
    blocks = np.asarray([0, 1, 5, 6, 10, 15])   # mixed stripes, with gaps
    sliced, rbp, indptr, segments = store.csr_slices(blocks)
    touched = np.zeros((p, p), bool)
    gi, gj = np.divmod(blocks, p)
    touched[gi, gj] = True
    stripe_of = np.repeat(np.arange(p), np.diff(store.layout.cuts))
    total = 0
    for u in range(store.n):
        for k in range(p):
            g_lo, g_hi = store.row_block_ptr[u, k], store.row_block_ptr[u, k + 1]
            lo, hi = rbp[u, k], rbp[u, k + 1]
            if touched[stripe_of[u], k]:
                np.testing.assert_array_equal(
                    sliced[lo:hi], store.indices[g_lo:g_hi]
                )
                total += hi - lo
            else:
                assert lo == hi    # unselected → zero-length slice
    assert total == sliced.size
    # rebased indptr delimits each row's staged adjacency
    assert indptr[0] == 0 and indptr[-1] == sliced.size
    np.testing.assert_array_equal(indptr[:-1], rbp[:, 0])
    np.testing.assert_array_equal(indptr[1:], rbp[:, p])
    # coalesced global ranges cover exactly the staged entries
    assert sum(e - s for s, e in segments) == sliced.size


def test_csr_slices_all_blocks_is_identity(graph):
    store = build_block_store(graph, 4)
    sliced, rbp, indptr, _ = store.csr_slices(np.arange(16))
    np.testing.assert_array_equal(sliced, store.indices)
    np.testing.assert_array_equal(rbp, store.row_block_ptr - store.row_block_ptr[0, 0])
    np.testing.assert_array_equal(indptr, store.indptr)


def test_csr_prefix_first_k_neighbors(graph):
    store = build_block_store(graph, 4)
    k = 3
    pptr, pidx = csr_prefix(store.indptr, store.indices, k)
    assert pidx.shape == (store.n * k,)
    np.testing.assert_array_equal(np.diff(pptr), k)
    for u in (0, 1, store.n // 2, store.n - 1):
        deg = int(store.degrees[u])
        want = store.indices[store.indptr[u] : store.indptr[u] + min(deg, k)]
        np.testing.assert_array_equal(pidx[u * k : u * k + min(deg, k)], want)


def _csr_checksum_algorithm():
    """Minimal csr='slice' algorithm: sums every staged adjacency entry.

    ``prepare`` computes (start, len) items from the store's
    ``row_block_ptr`` — rebased per wave by the executor — and the
    kernel gathers from ``ctx.indices``; any rebasing error shifts the
    gathered values and breaks the exact integer checksum against
    ``store.indices.sum()``."""
    import jax.numpy as jnp

    from repro.core import BlockAlgorithm

    def prepare(store, sched):
        p = store.p
        rbp = store.row_block_ptr
        cuts = store.layout.cuts
        starts, lens = [], []
        for b in sched.blocklists[:, 0]:
            i, j = divmod(int(b), p)
            rows = np.arange(cuts[i], cuts[i + 1])
            s = rbp[rows, j]
            ln = rbp[rows, j + 1] - rbp[rows, j]
            keep = ln > 0
            starts.append(s[keep])
            lens.append(ln[keep])
        s = np.concatenate(starts) if starts else np.zeros(0, np.int64)
        ln = np.concatenate(lens) if lens else np.zeros(0, np.int64)
        dp = int(bucket_size(int(ln.max()) if ln.size else 1, minimum=1))
        ni = int(bucket_size(s.size, minimum=1))
        ps = np.zeros(ni, np.int64)
        ps[: s.size] = s
        pl = np.zeros(ni, np.int64)
        pl[: ln.size] = ln
        return dict(csr_starts=jnp.asarray(ps), csr_lens=jnp.asarray(pl),
                    csr_dp=dp)

    def kernel(ctx, state, it):
        s = ctx.extras["csr_starts"]
        ln = ctx.extras["csr_lens"]
        dp = ctx.extras["csr_dp"]          # static → shapes stay bucketed
        m = ctx.indices.shape[0]           # the *staged* slice length
        pos = s[:, None] + jnp.arange(dp, dtype=s.dtype)[None, :]
        vals = ctx.indices[jnp.minimum(pos, m - 1)]
        msk = jnp.arange(dp)[None, :] < ln[:, None]
        tot = jnp.sum(jnp.where(msk, vals, 0).astype(jnp.int32))
        return dict(state, total=state["total"] + tot)

    return BlockAlgorithm(
        name="csr_checksum",
        kernel_sparse=kernel,
        prepare=prepare,
        init_state=lambda store: dict(total=jnp.asarray(0, jnp.int32)),
        finalize=lambda store, state: int(np.asarray(state["total"])),
        metadata=dict(combine="add", csr="slice"),
    )


def test_streamed_csr_bounded_on_skewed_rmat():
    """Acceptance: on a skewed R-MAT whose *full* CSR exceeds the
    budget, a csr='slice' algorithm streams with every wave's total
    staged bytes — and the per-wave sliced indices — ≤ the budget, and
    the rebased positions still address exactly the right entries."""
    g = rmat(10, 16, seed=5)
    budget = "32KB"
    store = build_block_store(g, 8)
    assert store.indices.nbytes > parse_bytes(budget)
    plan = compile_plan(_csr_checksum_algorithm(), store, share=False,
                        memory_budget=budget)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["csr_mode"] == "slice"
    assert st["num_waves"] >= 4
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])
    assert max(st["csr_bytes_per_wave"]) > 0
    assert all(c <= st["budget_bytes"] for c in st["csr_bytes_per_wave"])
    # the CSR slices really are slices — no wave stages the whole CSR
    assert max(st["csr_bytes_per_wave"]) < store.indices.nbytes
    # nothing edge-proportional stays resident (vertex-level arrays +
    # the scalar state only)
    vertex_level = (store.indptr.nbytes + store.degrees.nbytes
                    + store.row_block_ptr.nbytes + store.layout.cuts.nbytes)
    assert st["resident_bytes"] < vertex_level + 1024
    # exact integer checksum: every adjacency entry staged once, rebased
    # positions correct
    assert res.result == int(store.indices.sum())
    # and the in-core path computes the same thing from the global CSR
    want = compile_plan(_csr_checksum_algorithm(), store, share=False).run()
    assert want.result == res.result


def test_task_csr_edge_counts_dedups_blocks(graph):
    """Pattern-mode block-lists with repeated blocks stage each block's
    conformal rows once — the CSR pricing must not double-count."""
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    seg = np.diff(store.block_ptr)
    counts = task_csr_edge_counts(store, sched)
    np.testing.assert_array_equal(counts, seg[sched.blocklists[:, 0]])
    fp = task_footprints(store, sched, stage_csr=True)
    np.testing.assert_array_equal(
        fp, seg[sched.blocklists[:, 0]] * (COO_EDGE_BYTES + CSR_INDEX_BYTES)
    )


def test_prepare_declared_workspace_is_priced_not_staged(dag):
    """TC's prepare declares its membership-test scratch under the
    reserved __workspace_bytes__ key: the executor must count it
    against the budget, strip it from the kernel-visible extras, and
    the in-core plan must strip it too."""
    store = build_block_store(dag, 4)
    plan = compile_plan(tc_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="32KB")
    assert any(s.workspace_bytes > 0 for s in plan._slabs)
    for s in plan._slabs:
        assert s.workspace_bytes + s.staged_bytes <= plan.budget.total_bytes
        if s.extras is not None:
            assert "__workspace_bytes__" not in s.extras
    incore = compile_plan(tc_algorithm(), store, mode="sparse_only",
                          share=False)
    assert "__workspace_bytes__" not in incore.context.extras


def test_rebalance_threshold_requires_budget(graph):
    store = build_block_store(graph, 4)
    with pytest.raises(ValueError, match="memory_budget"):
        compile_plan(pagerank_algorithm(), store, rebalance_threshold=1.5)


# ------------------------------------------------- budget-aware schedule
def test_budget_aware_schedule_shrinks_tiles_and_demotes(graph):
    store = build_block_store(graph, 4)
    # without a budget the hybrid schedule claims dense tasks at 128
    free = build_schedule(pagerank_algorithm(), store, mode="hybrid",
                          dense_density=0.001, tile_dim=128)
    assert free.dense_task_mask.any()
    # a budget far below one 128-tile forces the tile cut-off down
    tight = build_schedule(pagerank_algorithm(), store, mode="hybrid",
                           dense_density=0.001, tile_dim=128,
                           memory_budget="20KB")
    assert tight.tile_dim < 128
    assert tight.stats["budget_bytes"] == 20_000
    # with a budget below any dense working set every task is demoted
    # to the sparse path — the planner never emits an unrunnable wave
    tiny = build_schedule(pagerank_algorithm(), store, mode="hybrid",
                          dense_density=0.001, tile_dim=128,
                          memory_budget="18KB")
    assert not tiny.dense_task_mask.any()


def test_choose_p_bounds_stripe_edges(graph):
    p = choose_p(graph, "16KB")
    assert p > 1
    store = build_block_store(graph, p)
    heaviest = store.layout.max_stripe_edges(graph)
    # the heaviest stripe fits half the budget — except that a single
    # hub row is irreducible by any contiguous 1-D partition
    cap = 16_000 // (2 * (COO_EDGE_BYTES + CSR_INDEX_BYTES))
    assert heaviest <= max(cap, int(graph.degrees.max()))
    # a generous budget needs no partitioning at all
    assert choose_p(graph, "1GB") == 1


# ------------------------------------------------------- rebalancing
def test_rebalance_triggers_on_skew(graph):
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB",
                        rebalance_threshold=1.5)
    nw = plan.num_waves
    assert nw >= 4
    before = [s.wave.task_ids.copy() for s in plan._slabs]
    # forced skew: the last wave dominates → re-pack must trigger
    times = [1.0] * (nw - 1) + [10.0 * nw]
    assert plan.rebalance(times) is True
    st_waves = plan._slabs
    # all tasks still covered exactly once
    all_ids = np.concatenate([s.wave.task_ids for s in st_waves])
    assert sorted(all_ids.tolist()) == sorted(
        np.concatenate(before).tolist()
    )
    # budget invariant survives the re-pack
    assert all(
        s.staged_bytes + s.workspace_bytes <= plan.budget.total_bytes
        for s in st_waves
    )
    # the re-packed plan still computes the right answer
    res = plan.run()
    assert res.schedule_stats["streaming"]["rebalanced"] is True
    want = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False).run().result
    np.testing.assert_allclose(np.asarray(res.result), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


def test_rebalance_ignores_balanced_waves(graph):
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB",
                        rebalance_threshold=1.5)
    nw = plan.num_waves
    assert plan.rebalance([1.0] * nw) is False
    assert plan._rebalanced is False
    # explicitly disabled (None): even huge skew is a no-op
    off = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                       share=False, memory_budget="16KB",
                       rebalance_threshold=None)
    assert off.rebalance([1.0] * (off.num_waves - 1) + [100.0]) is False


# ------------------------------------------------- default-on rebalancing
def test_auto_rebalance_is_the_default(graph):
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB")
    assert plan.rebalance_threshold == "auto"


def test_auto_rebalance_fires_on_divergence(graph):
    """Observed skew far beyond the estimate's predicted skew (and above
    the noise floor) re-packs the queue — deterministically, given the
    measurements."""
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB")
    nw = plan.num_waves
    assert nw >= 4
    before = np.concatenate([s.wave.task_ids for s in plan._slabs])
    # one wave dominating 10×nw over balanced peers, well above the
    # 10 ms noise floor
    times = [0.1] * (nw - 1) + [10.0 * nw * 0.1]
    assert plan.rebalance(times) is True
    assert plan._rebalanced is True
    st_waves = plan._slabs
    all_ids = np.concatenate([s.wave.task_ids for s in st_waves])
    assert sorted(all_ids.tolist()) == sorted(before.tolist())
    assert all(
        s.staged_bytes + s.workspace_bytes <= plan.budget.total_bytes
        for s in st_waves
    )
    nw2 = plan.num_waves
    # hysteresis latch: the fire disarmed the trigger — the same skew
    # on the freshly re-packed queue must NOT thrash a second re-pack…
    assert plan._reb_armed is False
    times2 = [0.1] * (nw2 - 1) + [10.0 * nw2 * 0.1]
    assert plan.rebalance(times2) is False
    # …until an evaluation under the low watermark re-arms it
    assert plan.rebalance([0.1] * nw2) is False     # balanced → re-arm
    assert plan._reb_armed is True
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["rebalanced"] is True
    assert st["rebalance_mode"] == "auto"
    assert st["rebalance_divergence"] is not None
    want = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False).run().result
    np.testing.assert_allclose(np.asarray(res.result), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


def test_auto_rebalance_noise_floor_and_hysteresis(graph):
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB")
    nw = plan.num_waves
    # same skew pattern, but sub-millisecond waves: timing noise — the
    # trigger must deterministically stand down (staged-byte accounting
    # stays reproducible on small runs)
    tiny = [1e-4] * (nw - 1) + [1e-4 * 10 * nw]
    assert plan.rebalance(tiny) is False
    assert plan._rebalanced is False
    # balanced waves above the floor: divergence ~1, inside the re-arm
    # band — no fire
    assert plan.rebalance([0.1] * nw) is False
    assert plan._rebalanced is False


def test_repack_waves_balances_time_under_budget(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = MemoryBudget(int(fp.max()) * 3)
    t = np.ones(sched.num_tasks)
    t[0] = 50.0                     # one dominating task
    waves = repack_waves(sched, budget, fp, t)
    # byte budget holds per wave
    for w in waves:
        assert fp[w.task_ids].sum() <= budget.total_bytes
    # the dominating task is isolated from the rest of the queue
    heavy = [w for w in waves if 0 in w.task_ids.tolist()]
    assert len(heavy) == 1 and heavy[0].task_ids.size == 1
    # coverage is a disjoint partition
    all_ids = np.concatenate([w.task_ids for w in waves])
    assert sorted(all_ids.tolist()) == list(range(sched.num_tasks))


# ------------------------------------------------- pipeline + trace cache
def _shape_key(recipe):
    """The slab-shape identity a jit trace is keyed on: padded slab
    widths, dense routing, and the shapes of the extras leaves."""
    import jax

    ex = tuple(
        tuple(np.asarray(leaf).shape)
        for leaf in jax.tree_util.tree_leaves(recipe.extras)
        if hasattr(leaf, "shape")
    )
    return (recipe.src_bucket, recipe.csr_bytes, recipe.run_dense, ex)


@pytest.fixture(scope="module")
def graph9():
    return rmat(9, 8, seed=3)


TRACE_ALGORITHMS = [
    ("pagerank", pagerank_algorithm, dict(mode="sparse_only"), "24KB"),
    ("sv", sv_algorithm, dict(mode="sparse_only"), "24KB"),
    ("afforest", afforest_algorithm, dict(mode="sparse_only"), "24KB"),
    ("bfs", lambda: bfs_algorithm(0), dict(mode="sparse_only"), "24KB"),
    ("kcore3", lambda: kcore_algorithm(3), dict(mode="sparse_only"), "24KB"),
    ("hits", hits_algorithm, dict(mode="sparse_only"), "24KB"),
    ("tc", tc_algorithm, dict(mode="sparse_only"), "64KB"),
]


@pytest.mark.parametrize("name,alg_f,kw,budget", TRACE_ALGORITHMS,
                         ids=[a[0] for a in TRACE_ALGORITHMS])
def test_traces_once_per_distinct_bucket_shape(name, alg_f, kw, budget,
                                               graph9, dag):
    """Satellite regression (the TC retrace): across a ≥6-wave streamed
    run, the wave step traces once per *distinct slab shape* — far
    fewer than once per wave — verified via the compiled step's traces
    counter.  Streamed results stay equivalent to in-core with the
    pipeline, arena, and default-on rebalancing all enabled."""
    from repro.algorithms.tc import orient_dag as _orient

    g = _orient(graph9) if name == "tc" else graph9
    store = build_block_store(g, 4)
    plan = compile_plan(alg_f(), store, share=False,
                        memory_budget=budget, **kw)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 6
    distinct = {_shape_key(r) for r in plan._slabs}
    # + 2: the edge-free/prefix-CSR context variants (afforest) and the
    # resident-context step shape trace once each on top of the wave
    # ladder
    assert st["trace_count"] <= len(distinct) + 2
    assert len(distinct) < st["num_waves"]

    want = compile_plan(alg_f(), build_block_store(g, 4),
                        share=False, **kw).run().result
    got = res.result
    # pipelined results are bit-identical for int/bool attributes
    # (_assert_equivalent uses exact comparison for those dtypes)
    if isinstance(want, dict):
        assert want.keys() == got.keys()
        for k in want:
            _assert_equivalent(got[k], want[k])
    else:
        _assert_equivalent(np.asarray(got), np.asarray(want))


def test_tc_trace_count_independent_of_wave_count():
    """Acceptance: TC's trace count is one per distinct bucket shape —
    constant as the wave count grows (the shared BucketPlan +
    cross-wave extras unification), not linear in waves as the per-wave
    dp/steps ladders used to make it."""
    from repro.algorithms.tc import orient_dag as _orient

    dag = _orient(rmat(10, 8, seed=5))
    runs = {}
    want = None
    for budget in ("512KB", "128KB"):
        plan = compile_plan(tc_algorithm(), build_block_store(dag, 8),
                            mode="sparse_only", share=False,
                            memory_budget=budget)
        res = plan.run()
        st = res.schedule_stats["streaming"]
        if want is None:
            want = res.result
        assert res.result == want
        # unified shapes: the mesh_pack-declared scratch replaces the
        # per-wave declarations uniformly, never leaks into ctx.extras,
        # and the budget still bounds slab + scratch per wave
        ws = {r.workspace_bytes for r in plan._slabs}
        assert len(ws) == 1 and ws.pop() > 0
        for r in plan._slabs:
            assert "__workspace_bytes__" not in (r.extras or {})
            assert (r.staged_bytes + r.workspace_bytes
                    <= plan.budget.total_bytes)
        runs[budget] = (st["num_waves"], st["trace_count"],
                        len({_shape_key(r) for r in plan._slabs}))
    (w1, t1, _), (w2, t2, d2) = runs["512KB"], runs["128KB"]
    assert w2 >= 2 * w1            # far more waves under the tight budget…
    assert t2 <= d2                # …but still one trace per distinct shape
    assert d2 <= max(w2 // 2, 3)   # and the shapes dedupe across waves


def test_pipeline_depth_zero_is_synchronous_and_identical(graph):
    """pipeline_depth=0 (the benchmark baseline) assembles inline; the
    result is bit-identical to the pipelined run."""
    store = build_block_store(graph, 4)
    runs = {}
    for depth in (2, 0):
        plan = compile_plan(sv_algorithm(), store, mode="sparse_only",
                            share=False, memory_budget="16KB",
                            pipeline_depth=depth)
        res = plan.run()
        st = res.schedule_stats["streaming"]
        assert st["pipeline_depth"] == depth
        if depth == 0:
            assert st["host_stage_overlap"] == 0.0
        runs[depth] = np.asarray(res.result)
    np.testing.assert_array_equal(runs[2], runs[0])


def test_arena_and_phase_stats(graph):
    """The staging arena recycles buffers across waves/iterations and
    the per-phase wall clock is reported."""
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 4
    assert st["arena_bytes"] > 0
    assert st["arena_reuses"] > 0          # buffers really cycle
    assert st["arena_model_bytes"] >= max(st["bytes_per_wave"])
    assert 0.0 <= st["host_stage_overlap"] <= 1.0
    phases = st["phase_seconds"]
    assert set(phases) == {"assemble", "prepare", "device_put", "compute",
                           "collective", "host_compute"}
    assert all(v >= 0.0 for v in phases.values())
    assert phases["assemble"] > 0.0
    assert phases["device_put"] > 0.0


def test_pipeline_depth_requires_budget(graph):
    store = build_block_store(graph, 4)
    with pytest.raises(ValueError, match="memory_budget"):
        compile_plan(pagerank_algorithm(), store, pipeline_depth=2)


def test_schedule_restrict_subsets(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="hybrid",
                           dense_density=0.001, tile_dim=128)
    ids = np.asarray([0, 3, 5])
    sub = sched.restrict(ids)
    assert sub.num_tasks == 3
    np.testing.assert_array_equal(sub.blocklists, sched.blocklists[ids])
    np.testing.assert_array_equal(sub.weights, sched.weights[ids])
    np.testing.assert_array_equal(sub.dense_task_mask,
                                  sched.dense_task_mask[ids])
    # dense blocks recomputed from the restricted tasks only
    want = (np.unique(sched.blocklists[ids][sched.dense_task_mask[ids]])
            if sched.dense_task_mask[ids].any() else np.zeros(0))
    np.testing.assert_array_equal(sub.dense_block_ids, want)
