"""Out-of-core streaming executor: footprint model, wave packing, and
streamed-vs-in-core equivalence for every algorithm.

Equivalence contract (stream.py module docstring): streamed runs fold
per-wave partials with the algorithm's declared combine op from the
iteration-start state, so results are *bit-identical* to in-core for
integer/bool attributes (SV, CC, BFS, k-core, TC) and equal up to float
summation order for real ones (PageRank, HITS).
"""
import numpy as np
import pytest

from repro.core import (
    rmat, build_block_store, build_schedule, compile_plan,
    MemoryBudget, StreamingPlan, task_footprints, build_waves,
)
from repro.core.membudget import (
    COO_EDGE_BYTES, bucket_size, parse_bytes, tile_bytes,
)
from repro.algorithms import (
    pagerank_algorithm, sv_algorithm, afforest_algorithm, bfs_algorithm,
    kcore_algorithm, hits_algorithm, tc_algorithm,
)
from repro.algorithms.tc import orient_dag


@pytest.fixture(scope="module")
def graph():
    return rmat(8, 8, seed=3)


@pytest.fixture(scope="module")
def dag(graph):
    return orient_dag(graph)


# All seven algorithms.  Budgets are sized to force several waves on
# rmat(8, 8) while leaving room for one task (hybrid tasks must fit
# their dense tiles, hence the smaller tile_dim).
ALGORITHMS = [
    ("pagerank", pagerank_algorithm,
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "90KB"),
    ("sv", sv_algorithm, dict(mode="sparse_only"), "16KB"),
    ("afforest", afforest_algorithm, dict(mode="sparse_only"), "16KB"),
    ("bfs", lambda: bfs_algorithm(0),
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "90KB"),
    ("kcore3", lambda: kcore_algorithm(3), dict(mode="sparse_only"), "16KB"),
    ("hits", hits_algorithm, dict(mode="sparse_only"), "16KB"),
    ("tc", tc_algorithm,
     dict(mode="hybrid", dense_density=0.001, tile_dim=128), "600KB"),
]


def _assert_equivalent(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind in "fc":
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)
    else:
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name,alg_f,kw,budget",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_streamed_matches_incore(name, alg_f, kw, budget, graph, dag):
    g = dag if name == "tc" else graph
    incore = compile_plan(alg_f(), build_block_store(g, 4), share=False, **kw)
    streamed = compile_plan(alg_f(), build_block_store(g, 4), share=False,
                            memory_budget=budget, **kw)
    assert isinstance(streamed, StreamingPlan)
    r_in, r_st = incore.run(), streamed.run()

    st = r_st.schedule_stats["streaming"]
    if name != "tc":  # tc's task count varies; the others must split ≥4×
        assert st["num_waves"] >= 4
    assert r_st.iterations == r_in.iterations

    ra, rb = r_in.result, r_st.result
    if isinstance(ra, dict):
        assert ra.keys() == rb.keys()
        for k in ra:
            _assert_equivalent(ra[k], rb[k])
    else:
        _assert_equivalent(np.asarray(ra), np.asarray(rb))

    # acceptance: stats report wave count, per-wave staged bytes ≤ budget,
    # and overlap efficiency
    assert st["num_waves"] == len(st["bytes_per_wave"])
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])
    assert 0.0 <= st["overlap_efficiency"] <= 1.0
    assert st["bytes_staged_total"] >= sum(st["bytes_per_wave"])
    assert st["resident_bytes"] > 0


def test_streamed_tc_forces_multiple_waves(dag):
    """TC counterpart of the ≥4-wave requirement (pattern mode)."""
    plan = compile_plan(tc_algorithm(), build_block_store(dag, 4),
                        mode="sparse_only", share=False,
                        memory_budget="24KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 4
    want = compile_plan(tc_algorithm(), build_block_store(dag, 4),
                        mode="sparse_only", share=False).run().result
    assert res.result == want


# ------------------------------------------------------------ membudget
def test_parse_bytes():
    assert parse_bytes(12345) == 12345
    assert parse_bytes("64KB") == 64_000
    assert parse_bytes("2MiB") == 2 * 2**20
    assert parse_bytes("1.5kb") == 1500
    with pytest.raises(ValueError):
        parse_bytes("sixty four")
    with pytest.raises(ValueError):
        MemoryBudget(0)


def test_bucket_size_ladder():
    assert bucket_size(1) == 8          # floor
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(1000) == 1024
    assert bucket_size(1025) == 2048


def test_footprint_model_prices_coo_and_tiles(graph):
    store = build_block_store(graph, 4)
    alg = pagerank_algorithm()
    sparse_sched = build_schedule(alg, store, mode="sparse_only")
    fp = task_footprints(store, sparse_sched)
    assert fp.shape == (sparse_sched.num_tasks,)
    # sparse single-block tasks price exactly edges × COO bytes
    seg = np.diff(store.block_ptr)
    want = seg[sparse_sched.blocklists[:, 0]] * COO_EDGE_BYTES
    np.testing.assert_array_equal(fp, want)

    hybrid_sched = build_schedule(alg, store, mode="hybrid",
                                  dense_density=0.001, tile_dim=128)
    fp_h = task_footprints(store, hybrid_sched)
    dense = hybrid_sched.dense_task_mask
    assert dense.any()
    # dense tasks additionally price their bitmap tiles (+ workspace)
    assert (fp_h[dense] >= want[dense] + tile_bytes(128)).all()
    np.testing.assert_array_equal(fp_h[~dense], want[~dense])


def test_wave_packing_respects_budget_and_covers_all_tasks(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = MemoryBudget(int(fp.max()) * 2)
    waves = build_waves(store, sched, budget, fp)
    assert len(waves) >= 2
    # no wave's model estimate exceeds the budget
    for w in waves:
        assert fp[w.task_ids].sum() <= budget.total_bytes
        assert w.est_bytes == fp[w.task_ids].sum()
    # union of waves == all tasks, disjointly
    all_ids = np.concatenate([w.task_ids for w in waves])
    assert len(all_ids) == len(set(all_ids.tolist()))
    assert set(all_ids.tolist()) == set(range(sched.num_tasks))


def test_wave_tasks_sorted_for_coalesced_staging(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    waves = build_waves(store, sched, MemoryBudget(int(fp.max()) * 3), fp)
    for w in waves:
        lead = sched.blocklists[w.task_ids, 0]
        assert np.all(np.diff(lead) >= 0)


def test_oversized_task_raises(graph):
    store = build_block_store(graph, 4)
    with pytest.raises(ValueError, match="budget"):
        compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                     share=False, memory_budget=64)


def test_padded_single_task_overflow_raises_not_oversubscribes(graph):
    """Regression: a budget that fits the raw footprint but not the
    bucket-padded slab must raise, never silently stage over budget."""
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = int(fp.max()) + 1  # below the padded slab of the biggest task
    try:
        plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                            share=False, memory_budget=budget)
    except ValueError:
        return  # honest refusal is the expected outcome...
    st = plan.run().schedule_stats["streaming"]  # ...or every wave fits
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])


def test_hoisted_extras_do_not_count_against_budget(graph):
    """Regression: wave-invariant prepare extras are staged once
    (resident), so a budget that fits the padded slabs but not
    slab+extras must still work — not over-split or raise."""
    from repro.core.membudget import COO_EDGE_BYTES

    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    seg = np.diff(store.block_ptr)[sched.blocklists[:, 0]]
    max_padded_slab = int(max(bucket_size(int(e)) for e in seg)) * COO_EDGE_BYTES
    budget = max_padded_slab + 200  # < slab + inv_deg/dangling extras
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget=budget)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert all(b <= st["budget_bytes"] for b in st["bytes_per_wave"])
    assert abs(float(np.asarray(res.result).sum()) - 1.0) < 1e-3


def test_edge_free_iterations_stage_one_wave(graph):
    """Afforest's sampling rounds declare edge_free_iterations: only one
    representative wave is staged per sampling round, and the staged
    byte accounting reflects the warm-up + calibration passes."""
    plan = compile_plan(afforest_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False, memory_budget="16KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    bpw = st["bytes_per_wave"]
    k_rounds = 2  # afforest default
    n_final = res.iterations - k_rounds
    assert n_final >= 1
    # sampling: wave 0 staged once, cached across rounds; first final
    # iteration: warm-up + timed calibration pass (2× all waves);
    # remaining finals: 1× all waves
    expected = bpw[0] + (n_final + 1) * sum(bpw)
    assert st["bytes_staged_total"] == expected
    want = compile_plan(afforest_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False).run().result
    np.testing.assert_array_equal(np.asarray(res.result), np.asarray(want))


def test_streaming_plan_is_rebound_safely(graph):
    plan = compile_plan(pagerank_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False, memory_budget="64KB")
    other = build_block_store(graph, 4)
    with pytest.raises(TypeError, match="bound to the store"):
        plan.run(other)


def test_wave_slabs_stay_bucketed(graph):
    """All waves of one plan share a handful of padded slab shapes, so
    the jitted step does not retrace per wave."""
    plan = compile_plan(pagerank_algorithm(), build_block_store(graph, 4),
                        mode="sparse_only", share=False, memory_budget="16KB")
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 4
    assert len(st["edge_buckets"]) <= 3     # power-of-two ladder
    for b in st["edge_buckets"]:
        assert b == bucket_size(b)
    # one trace per (slab shape × run_dense) — far fewer than waves
    assert plan.compile_count <= len(st["edge_buckets"]) + 1


def test_schedule_restrict_subsets(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="hybrid",
                           dense_density=0.001, tile_dim=128)
    ids = np.asarray([0, 3, 5])
    sub = sched.restrict(ids)
    assert sub.num_tasks == 3
    np.testing.assert_array_equal(sub.blocklists, sched.blocklists[ids])
    np.testing.assert_array_equal(sub.weights, sched.weights[ids])
    np.testing.assert_array_equal(sub.dense_task_mask,
                                  sched.dense_task_mask[ids])
    # dense blocks recomputed from the restricted tasks only
    want = (np.unique(sched.blocklists[ids][sched.dense_task_mask[ids]])
            if sched.dense_task_mask[ids].any() else np.zeros(0))
    np.testing.assert_array_equal(sub.dense_block_ids, want)
