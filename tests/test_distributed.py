"""Multi-device tests: run in a subprocess with 8 forced host devices
(XLA locks the device count at first init, so the main pytest process —
which sees 1 device — cannot host these)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8, timeout: int = 500):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_distributed_pagerank_matches_single_device():
    """shard_map block-parallel PR over 8 devices == host numpy oracle."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core import rmat, build_block_store, build_schedule
        from repro.core.distributed import DistributedEngine
        from repro.algorithms import pagerank_algorithm, pagerank

        g = rmat(9, 8, seed=3)
        store = build_block_store(g, 4)
        sched = build_schedule(pagerank_algorithm(), store, num_devices=8,
                               mode="sparse_only")
        inv_deg = jnp.asarray(1.0 / np.maximum(np.diff(store.indptr), 1))
        n = store.n

        def edge_update(src, dst, valid, state):
            contrib = state["rank"] * inv_deg
            vals = jnp.where(valid, contrib[src], 0.0)
            acc = jnp.zeros(n, jnp.float32).at[dst].add(vals)
            return dict(rank=state["rank"], acc=acc)

        eng = DistributedEngine(store, sched, edge_update,
                                combine=dict(rank="max", acc="add"))
        state = dict(rank=jnp.full((n,), 1.0 / n), acc=jnp.zeros(n))
        dangling = jnp.asarray(np.diff(store.indptr) == 0)
        for _ in range(20):
            state = eng.step(state)
            dm = jnp.sum(jnp.where(dangling, state["rank"], 0.0))
            rank = 0.15 / n + 0.85 * (state["acc"] + dm / n)
            state = dict(rank=rank, acc=jnp.zeros(n))
        got = np.asarray(state["rank"])

        store2 = build_block_store(g, 4)
        want = pagerank(store2, mode="sparse_only")
        err = float(np.abs(got - want).max())
        assert err < 1e-5, err
        print("DIST_OK", err)
    """)
    assert "DIST_OK" in r.stdout, r.stdout + r.stderr


def test_lpt_devices_reduce_wallclock_imbalance():
    r = _run_py("""
        import numpy as np, jax
        from repro.core import rmat, build_block_store, build_schedule
        from repro.core.distributed import make_device_edge_partition
        from repro.algorithms import pagerank_algorithm

        g = rmat(10, 8, seed=1)
        store = build_block_store(g, 8)
        sched = build_schedule(pagerank_algorithm(), store, num_devices=8,
                               mode="sparse_only")
        part = make_device_edge_partition(store, sched)
        loads = part["valid"].sum(1)
        ratio = loads.max() / max(loads.mean(), 1)
        assert ratio < 1.35, ratio     # LPT keeps devices balanced
        # every edge appears exactly once across devices
        assert int(part["valid"].sum()) == store.m
        print("LPT_OK", float(ratio))
    """)
    assert "LPT_OK" in r.stdout, r.stdout + r.stderr


def test_mini_dryrun_8dev_mesh():
    """lower+compile a smoke arch on a (4,2) mesh with real shardings —
    the dry-run machinery end-to-end at test scale."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.sharding import (
            set_mesh_ctx, param_specs, named_sharding_tree, batch_spec)
        from repro.models.steps import (
            make_train_step, abstract_params, abstract_opt_state)
        from repro.configs.base import ShapeSpec

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = set_mesh_ctx(mesh)
        cfg = get_smoke("qwen2.5-32b")
        p_shapes = abstract_params(cfg)
        o_shapes = abstract_opt_state(cfg)
        p_sh = named_sharding_tree(ctx, param_specs(ctx, p_shapes))
        o_sh = named_sharding_tree(ctx, param_specs(ctx, o_shapes))
        specs = dict(
            tokens=jax.ShapeDtypeStruct((8, 64), jnp.int32),
            labels=jax.ShapeDtypeStruct((8, 64), jnp.int32),
        )
        b_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, batch_spec(ctx, s.shape)), specs)
        rep = NamedSharding(mesh, P())
        with mesh:
            step = make_train_step(cfg)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, rep),
                         out_shardings=(p_sh, o_sh, rep))
            lowered = jf.lower(p_shapes, o_shapes, specs,
                               jax.ShapeDtypeStruct((), np.int32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes > 0
            hlo = compiled.as_text()
            from repro.roofline import collective_bytes_from_hlo
            coll = collective_bytes_from_hlo(hlo)
            assert coll["total"] > 0, "expected collectives in SPMD program"
            print("MINI_DRYRUN_OK", int(coll["total"]))
    """)
    assert "MINI_DRYRUN_OK" in r.stdout, r.stdout + r.stderr


def test_mini_dryrun_executes_on_8dev():
    """Not just compile — actually run one sharded train step on 8 devices."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models import lm
        from repro.models.sharding import (
            set_mesh_ctx, param_specs, named_sharding_tree)
        from repro.models.steps import make_train_step
        from repro.optim import adamw_init

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = set_mesh_ctx(mesh)
        cfg = get_smoke("qwen2.5-32b")
        with mesh:
            params = lm.init_params(cfg, jax.random.key(0))
            p_sh = named_sharding_tree(ctx, param_specs(ctx, params))
            params = jax.device_put(params, p_sh)
            opt = adamw_init(params)
            batch = dict(
                tokens=jnp.zeros((8, 64), jnp.int32),
                labels=jnp.zeros((8, 64), jnp.int32),
            )
            step = jax.jit(make_train_step(cfg))
            p2, o2, m = step(params, opt, batch, jnp.int32(0))
            loss = float(m["loss"])
            assert np.isfinite(loss)
            print("EXEC_OK", loss)
    """)
    assert "EXEC_OK" in r.stdout, r.stdout + r.stderr


def test_elastic_restore_onto_8dev_mesh():
    """Checkpoint written on 1 device restores + trains on an (4,2) mesh."""
    r = _run_py("""
        import os, tempfile
        import numpy as np, jax, jax.numpy as jnp
        from dataclasses import replace
        from repro.configs import get_smoke
        from repro.models import lm
        from repro.models.sharding import (
            set_mesh_ctx, param_specs, named_sharding_tree)
        from repro.models.steps import make_train_step
        from repro.optim import adamw_init
        from repro.checkpoint import save_checkpoint, restore_checkpoint

        cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
        params = lm.init_params(cfg, jax.random.key(0))
        state = dict(params=params, opt=adamw_init(params))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 0, state)  # written host-side (1-device logical)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = set_mesh_ctx(mesh)
        template = jax.eval_shape(lambda: state)
        sh = dict(
            params=named_sharding_tree(ctx, param_specs(ctx, template["params"])),
            opt=named_sharding_tree(ctx, param_specs(ctx, template["opt"])),
        )
        restored, step = restore_checkpoint(d, template, shardings=sh)
        with mesh:
            batch = dict(tokens=jnp.zeros((8, 32), jnp.int32),
                         labels=jnp.zeros((8, 32), jnp.int32))
            stepf = jax.jit(make_train_step(cfg))
            p2, o2, m = stepf(restored["params"], restored["opt"], batch,
                              jnp.int32(step))
            assert np.isfinite(float(m["loss"]))
        # round-trip: values identical to the saved ones
        a = jax.device_get(restored["params"]["embed"])
        b = jax.device_get(params["embed"])
        assert np.allclose(a, b)
        print("ELASTIC_OK", float(m["loss"]))
    """)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_grad_compression_dp_loop_8dev():
    """int8-compressed DP psum with error feedback converges on 8 shards."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import compressed_psum, error_feedback_init

        mesh = jax.make_mesh((8,), ("data",))
        w_true = jnp.asarray(np.random.default_rng(0).standard_normal(16))

        def local_grad(w, x):
            # per-shard quadratic: grad of mean((x@w - x@w_true)^2)
            err = x @ (w - w_true)
            return 2 * x.T @ err / x.shape[0]

        @jax.jit
        @partial(shard_map, mesh=mesh,
                 in_specs=(P(), P("data", None, None, None), P()),
                 out_specs=(P(), P()), check_rep=False)
        def step(w, x, r):
            g = local_grad(w, x[0, 0])
            g, r = compressed_psum(dict(w=g), dict(w=r), "data")
            return g["w"], r["w"]

        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((8, 1, 64, 16)).astype(np.float32))
        w = jnp.zeros(16)
        resid = jnp.zeros(16)
        for i in range(200):
            g, resid = step(w, x, resid)
            w = w - 0.05 * g
        err = float(jnp.abs(w - w_true).max())
        assert err < 2e-2, err
        print("COMPRESS_OK", err)
    """)
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr
