"""Per-iteration direction optimization (push/pull/auto).

Three layers of coverage:

* unit tests for the density controller — Beamer threshold, hysteresis
  band (both edges and the hold inside it), env-knob overrides, fixed
  modes, and capability/declaration validation;
* a differential harness: every direction-capable algorithm ×
  {push, pull, auto} × {in-core, streamed (≥ 4 waves), host lane} must
  land integer-checksum-exact on the fixed-push in-core baseline, on
  two R-MAT seeds;
* an 8-device host-platform mesh subprocess (slow lane) running the
  same differential through ``shard_map``.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import build_block_store, compile_plan, rmat
from repro.core.direction import (
    DirectionController, direction_spec, frontier_count, resolve_direction,
    workspace_kernels,
)
from repro.core.functors import BlockAlgorithm, Mode
from repro.core.stream import compile_streaming_plan
from repro.algorithms import (
    afforest_algorithm, bfs_algorithm, kcore_algorithm, pagerank_algorithm,
    sv_algorithm,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SEEDS = (3, 11)

# (name, factory, plan kwargs, streaming budget tuned to ≥4 waves)
ALGS = [
    ("bfs", lambda: bfs_algorithm(0), {}, "256KB"),
    ("kcore3", lambda: kcore_algorithm(3), dict(mode="sparse_only"), "24KB"),
    ("sv", sv_algorithm, {}, "24KB"),
    ("afforest", afforest_algorithm, {}, "24KB"),
]


def _flat(res):
    if isinstance(res, dict):
        return {k: np.asarray(v) for k, v in res.items()}
    return {"result": np.asarray(res)}


def _assert_exact(name, base, got):
    assert base.keys() == got.keys(), name
    for k in base:
        a, b = base[k], got[k]
        assert a.dtype.kind not in "fc", (name, k)  # int/bool contract
        np.testing.assert_array_equal(a, b, err_msg=f"{name}.{k}")
        assert int(a.astype(np.int64).sum()) == int(b.astype(np.int64).sum())


@pytest.fixture(scope="module", params=SEEDS, ids=lambda s: f"seed{s}")
def graph(request):
    return rmat(8, 8, seed=request.param)


@pytest.fixture(scope="module")
def store(graph):
    return build_block_store(graph, 4)


@pytest.fixture(scope="module")
def push_baselines(store):
    """Fixed-push in-core results, computed once per seed."""
    out = {}
    for name, alg_f, kw, _ in ALGS:
        out[name] = _flat(
            compile_plan(alg_f(), store, direction="push", **kw).run().result)
    return out


# ------------------------------------------------------------ controller
def test_decide_density_threshold():
    alg = bfs_algorithm(0)
    c = DirectionController(alg, "auto", n=1000)  # beta=24
    # push → pull exactly when count*beta > population (rule is pure —
    # it never mutates c.current, so one controller probes both sides)
    assert c.decide_density(42, 1000) == "pull"      # 42*24 = 1008 > 1000
    assert c.decide_density(41, 1000) == "push"      # 41*24 = 984 ≤ 1000


def test_hysteresis_band_holds_current_direction():
    alg = bfs_algorithm(0)
    c = DirectionController(alg, "auto", n=1000)  # hysteresis=0.75
    assert c.decide_density(50, 1000) == "pull"      # 1200 > 1000
    c.current = "pull"
    # inside the band [750, 1000]: hold pull
    assert c.decide_density(35, 1000) == "pull"      # 840 ∈ band
    # below population*hysteresis: release back to push
    assert c.decide_density(31, 1000) == "push"      # 744 < 750
    # a controller still in push with the same in-band density stays push
    c2 = DirectionController(alg, "auto", n=1000)
    assert c2.decide_density(35, 1000) == "push"


def test_fixed_modes_never_switch():
    alg = bfs_algorithm(0)
    for mode in ("push", "pull"):
        c = DirectionController(alg, mode, n=100)
        for count in (0, 10, 100):
            assert c.decide_density(count, 100) == mode
        state = dict(nf=np.asarray(100, np.int32))
        for it in range(3):
            assert c.decide(state, it) == mode
        assert c.switches == 0
        assert c.stats()["switches"] == 0


def test_env_knobs_override_beta_and_hysteresis(monkeypatch):
    alg = bfs_algorithm(0)
    monkeypatch.setenv("REPRO_DIRECTION_BETA", "2.0")
    monkeypatch.setenv("REPRO_DIRECTION_HYSTERESIS", "0.5")
    c = DirectionController(alg, "auto", n=1000)
    assert c.beta == 2.0 and c.hysteresis == 0.5
    assert c.decide_density(501, 1000) == "pull"     # 1002 > 1000
    c.current = "pull"
    assert c.decide_density(300, 1000) == "pull"     # 600 ∈ [500, 1000]
    assert c.decide_density(249, 1000) == "push"     # 498 < 500
    monkeypatch.setenv("REPRO_DIRECTION_BETA", "-1")
    with pytest.raises(ValueError, match="beta must be > 0"):
        DirectionController(alg, "auto", n=10)


def test_frontier_count_bool_and_numeric_leaves():
    n = 100
    cnt, pop = frontier_count(dict(f=np.zeros(n, bool)), "f", n)
    assert (cnt, pop) == (0, n)
    cnt, pop = frontier_count(dict(H=np.asarray(7, np.int32)), "H", n)
    assert (cnt, pop) == (7, n)
    cnt, pop = frontier_count(dict(nf=np.asarray([3, 4], np.int32)), "nf", n)
    assert (cnt, pop) == (7, 2 * n)
    with pytest.raises(KeyError):
        frontier_count(dict(), "missing", n)


def test_direction_capability_validation():
    # pull/auto on an algorithm without the declaration is an error
    pr = pagerank_algorithm()
    assert direction_spec(pr) is None
    assert resolve_direction(pr, None) == "push"
    assert resolve_direction(pr, "push") == "push"
    with pytest.raises(ValueError, match="direction"):
        resolve_direction(pr, "pull")
    with pytest.raises(ValueError, match="direction"):
        resolve_direction(pr, "auto")
    with pytest.raises(ValueError, match="'push', 'pull', 'auto'"):
        resolve_direction(pr, "sideways")

    # a dense push kernel without its pull twin cannot honor a pull
    # iteration — declaring the capability anyway must be rejected
    lopsided = BlockAlgorithm(
        name="lopsided", mode=Mode.BULK,
        kernel_sparse=lambda ctx, s, it: s,
        kernel_sparse_pull=lambda ctx, s, it: s,
        kernel_dense=lambda ctx, s, it: s,
        init_state=lambda store: dict(x=np.zeros(1)),
        metadata=dict(direction=dict(frontier="x")),
    )
    with pytest.raises(ValueError, match="kernel_dense_pull"):
        direction_spec(lopsided)


def test_workspace_kernels_prices_both_variants():
    alg = bfs_algorithm(0)
    assert workspace_kernels(alg, None) == "frontier_tiles"
    assert workspace_kernels(alg, "push") == "frontier_tiles"
    assert workspace_kernels(alg, "pull") == "frontier_tiles"
    # auto dedupes identical names back to a single str
    assert workspace_kernels(alg, "auto") == "frontier_tiles"
    two = BlockAlgorithm(
        name="two", mode=Mode.BULK,
        kernel_sparse=lambda ctx, s, it: s,
        kernel_sparse_pull=lambda ctx, s, it: s,
        init_state=lambda store: dict(x=np.zeros(1)),
        metadata=dict(direction=dict(frontier="x"),
                      workspace_kernel="spmv_tiles",
                      workspace_kernel_pull="frontier_tiles"),
    )
    assert set(workspace_kernels(two, "auto")) == {
        "spmv_tiles", "frontier_tiles"}


# ---------------------------------------------------------- differential
@pytest.mark.parametrize("name,alg_f,kw,budget", ALGS,
                         ids=[a[0] for a in ALGS])
@pytest.mark.parametrize("direction", ["push", "pull", "auto"])
def test_incore_matches_fixed_push(name, alg_f, kw, budget, direction,
                                   store, push_baselines):
    rr = compile_plan(alg_f(), store, direction=direction, **kw).run()
    _assert_exact(name, push_baselines[name], _flat(rr.result))
    stats = rr.schedule_stats["direction"]
    assert stats["mode"] == direction
    assert len(stats["decisions"]) == rr.iterations
    if direction == "pull":
        assert stats["pull_iterations"] == rr.iterations
    if direction in ("push", "pull"):
        assert stats["switches"] == 0
        assert all(d == direction for d in stats["decisions"])


@pytest.mark.parametrize("name,alg_f,kw,budget", ALGS,
                         ids=[a[0] for a in ALGS])
@pytest.mark.parametrize("direction", ["pull", "auto"])
def test_streamed_matches_fixed_push(name, alg_f, kw, budget, direction,
                                     store, push_baselines):
    sp = compile_streaming_plan(alg_f(), store, memory_budget=budget,
                                direction=direction, **kw)
    rr = sp.run()
    assert rr.schedule_stats["streaming"]["num_waves"] >= 4, name
    _assert_exact(name, push_baselines[name], _flat(rr.result))
    stats = rr.schedule_stats["direction"]
    assert len(stats["decisions"]) == rr.iterations


@pytest.mark.parametrize("name,alg_f,kw,budget", ALGS,
                         ids=[a[0] for a in ALGS])
def test_host_lane_matches_fixed_push(name, alg_f, kw, budget,
                                      store, push_baselines):
    sp = compile_streaming_plan(alg_f(), store, memory_budget=budget,
                                host_fraction=0.3, direction="auto", **kw)
    rr = sp.run()
    _assert_exact(name, push_baselines[name], _flat(rr.result))


def test_auto_takes_pull_iterations_on_skewed_rmat(store):
    """Acceptance: BFS, k-core, and CC under direction="auto" run ≥ 1
    bottom-up (pull) iteration on a skewed R-MAT, visibly in
    ``schedule_stats["direction"]``."""
    for name, alg_f, kw, _ in ALGS:
        if name == "sv":
            continue  # SV's hook counter resets before each decision
        rr = compile_plan(alg_f(), store, direction="auto", **kw).run()
        stats = rr.schedule_stats["direction"]
        assert stats["pull_iterations"] >= 1, (name, stats)
        assert stats["pull_iterations"] == sum(
            1 for d in stats["decisions"] if d == "pull")
        assert len(stats["densities"]) == len(stats["decisions"])


def test_default_direction_keeps_legacy_contract(store):
    """No ``direction=`` → plain push: no controller, no stats block."""
    rr = compile_plan(bfs_algorithm(0), store).run()
    assert "direction" not in rr.schedule_stats
    srr = compile_streaming_plan(bfs_algorithm(0), store,
                                 memory_budget="256KB").run()
    assert "direction" not in srr.schedule_stats


def test_direction_switch_metric_increments(store):
    from repro import obs

    obs.REGISTRY.reset()
    try:
        rr = compile_plan(bfs_algorithm(0), store, direction="auto").run()
        stats = rr.schedule_stats["direction"]
        assert obs.metrics.counter(
            "stream.direction_switches").value == stats["switches"]
        assert stats["switches"] >= 1  # skewed R-MAT crosses the band
    finally:
        obs.REGISTRY.reset()


def test_compiled_step_cache_keyed_by_direction(store):
    """push and pull variants of one algorithm must not collide in the
    shared compiled-step cache; two same-direction plans must share."""
    a = compile_plan(bfs_algorithm(0), store, direction="push")
    b = compile_plan(bfs_algorithm(0), store, direction="pull")
    c = compile_plan(bfs_algorithm(0), store, direction="push")
    ra, rb, rc = a.run(), b.run(), c.run()
    _assert_exact("bfs", _flat(ra.result), _flat(rb.result))
    _assert_exact("bfs", _flat(ra.result), _flat(rc.result))


# ------------------------------------------------- 8-device mesh (slow)
def _run_py(code: str, devices: int = 8, timeout: int = 500):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.mark.slow
@pytest.mark.subprocess
def test_mesh_streamed_direction_differential():
    """All direction-capable algorithms × {pull, auto} through an
    8-device host-platform mesh land checksum-exact on the in-core
    fixed-push baseline (XLA locks the device count at first init,
    hence the subprocess)."""
    r = _run_py("""
        import json
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import build_block_store, compile_plan, rmat
        from repro.algorithms import (
            afforest_algorithm, bfs_algorithm, kcore_algorithm, sv_algorithm,
        )

        assert len(jax.devices()) == 8, jax.devices()
        mesh = Mesh(np.array(jax.devices()), ("blocks",))
        store = build_block_store(rmat(8, 8, seed=3), 4)

        ALGS = [
            ("bfs", lambda: bfs_algorithm(0), {}, "256KB"),
            ("kcore3", lambda: kcore_algorithm(3),
             dict(mode="sparse_only"), "24KB"),
            ("sv", sv_algorithm, {}, "24KB"),
            ("afforest", afforest_algorithm, {}, "24KB"),
        ]

        def flat(res):
            if isinstance(res, dict):
                return {k: np.asarray(v) for k, v in res.items()}
            return {"result": np.asarray(res)}

        report = {}
        for name, alg_f, kw, budget in ALGS:
            base = flat(compile_plan(alg_f(), store, direction="push",
                                     **kw).run().result)
            for direction in ("pull", "auto"):
                rr = compile_plan(alg_f(), store, memory_budget=budget,
                                  mesh=mesh, direction=direction, **kw).run()
                got = flat(rr.result)
                assert base.keys() == got.keys(), name
                for k in base:
                    np.testing.assert_array_equal(base[k], got[k])
                    assert (int(base[k].astype(np.int64).sum())
                            == int(got[k].astype(np.int64).sum()))
                st = rr.schedule_stats
                assert st["streaming"]["mesh_devices"] == 8
                report[f"{name}:{direction}"] = dict(
                    waves=st["streaming"]["num_waves"],
                    pull=st["direction"]["pull_iterations"],
                )
        print("DIR_MESH_OK", json.dumps(report))
    """)
    assert "DIR_MESH_OK" in r.stdout, r.stdout + r.stderr
    report = json.loads(r.stdout.split("DIR_MESH_OK", 1)[1])
    for key, row in report.items():
        name, direction = key.split(":")
        if direction == "pull":
            assert row["pull"] >= 1, (key, row)
