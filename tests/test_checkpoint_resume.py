"""Checkpoint/resume bit-identity across the full algorithm matrix.

The contract (see ``repro.checkpoint.runstate``): a run snapshot taken
at ANY iteration boundary, resumed on a freshly compiled plan, yields
the same final attributes as the uninterrupted run — exactly for
integer/boolean state, to float tolerance otherwise.  The matrix
covers all seven registered algorithms on a >=4-wave streamed plan,
resuming from every boundary the run wrote; direction-optimized runs
additionally round-trip the hysteresis controller's latch state.

A crashed run is the same story: an injected fault that exhausts its
retry budget escapes mid-run, and ``resume()`` from the last on-disk
boundary finishes the computation checksum-exact.
"""
import glob
import os
import re

import numpy as np
import pytest

from repro.algorithms import (
    afforest_algorithm, bfs_algorithm, hits_algorithm, kcore_algorithm,
    pagerank_algorithm, sv_algorithm, tc_algorithm,
)
from repro.checkpoint.runstate import latest_runstate_step, load_runstate
from repro.core import build_block_store, compile_plan, rmat
from repro.core.faults import InjectedFault
from repro.core.resilience import RetryPolicy

_GRAPHS: dict = {}

BUDGET = "32KB"   # rmat(9) at p=8: 5 waves


def _store(scale=9, p=8, seed=3):
    key = (scale, p, seed)
    if key not in _GRAPHS:
        _GRAPHS[key] = build_block_store(rmat(scale, 8, seed=seed), p)
    return _GRAPHS[key]


def _streamed(factory, **kw):
    return compile_plan(factory(), _store(), mode="sparse_only",
                        share=False, memory_budget=BUDGET,
                        rebalance_threshold=None, host_fraction=None, **kw)


def _incore(factory, **kw):
    return compile_plan(factory(), _store(), mode="sparse_only",
                        share=False, **kw)


def _steps(ckpt_dir):
    out = []
    for fn in glob.glob(os.path.join(ckpt_dir, "step_*.npz")):
        m = re.fullmatch(r"step_(\d+)\.npz", os.path.basename(fn))
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def _assert_same(a, b):
    if isinstance(a, dict) or isinstance(b, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_same(a[k], b[k])
        return
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype
    if a.dtype.kind in "biu":
        assert int(a.astype(np.int64).sum()) == int(b.astype(np.int64).sum())
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


ALGS = [
    ("pagerank", lambda: pagerank_algorithm(max_iters=5)),
    ("bfs", lambda: bfs_algorithm(0)),
    ("cc", lambda: afforest_algorithm()),
    ("sv", lambda: sv_algorithm()),
    ("hits", lambda: hits_algorithm(max_iters=5)),
    ("kcore", lambda: kcore_algorithm(3)),
    ("tc", lambda: tc_algorithm()),
]


class TestStreamedEveryBoundary:
    """Every algorithm, every boundary, streamed >=4-wave execution."""

    @pytest.mark.parametrize("name,factory", ALGS,
                             ids=[n for n, _ in ALGS])
    def test_resume_bit_identical(self, name, factory, tmp_path):
        base = _streamed(factory).run()
        assert base.schedule_stats["streaming"]["num_waves"] >= 4

        d = str(tmp_path / "ck")
        ck = _streamed(factory, checkpoint_every=1, checkpoint_dir=d).run()
        _assert_same(ck.result, base.result)
        steps = _steps(d)
        assert steps, "checkpoint_every=1 wrote no snapshots"
        assert steps[0] == 1 and steps == list(range(1, len(steps) + 1))

        fresh = _streamed(factory)   # resume plan never re-checkpoints
        for s in steps:
            res = fresh.resume(d, step=s)
            _assert_same(res.result, base.result)

    def test_snapshot_roundtrip_dtypes(self, tmp_path):
        """load_runstate casts every leaf back to the init_state
        template dtype — int/bool attributes round-trip exactly."""
        d = str(tmp_path / "ck")
        plan = _streamed(sv_algorithm, checkpoint_every=1, checkpoint_dir=d)
        plan.run()
        template = plan.alg.init_state(plan.store)
        snap = load_runstate(d, template, step=1)
        assert snap.it == 1 and snap.step == 1
        for k, leaf in template.items():
            assert np.asarray(snap.state[k]).dtype == np.asarray(leaf).dtype

    def test_latest_pointer_tracks_newest(self, tmp_path):
        d = str(tmp_path / "ck")
        _streamed(sv_algorithm, checkpoint_every=1, checkpoint_dir=d).run()
        assert latest_runstate_step(d) == max(_steps(d))


class TestDirectionControllerRestore:
    """direction="auto" runs snapshot the hysteresis latch too."""

    def test_bfs_auto_resumes_exact(self, tmp_path):
        factory = lambda: bfs_algorithm(0)                     # noqa: E731
        base = _streamed(factory, direction="auto").run()
        fixed = _streamed(factory).run()
        _assert_same(base.result, fixed.result)   # auto == push contract

        d = str(tmp_path / "ck")
        _streamed(factory, direction="auto", checkpoint_every=1,
                  checkpoint_dir=d).run()
        steps = _steps(d)
        assert len(steps) >= 2

        # the snapshot carries the controller dict
        snap = load_runstate(d, factory().init_state(_store()),
                             step=steps[len(steps) // 2])
        assert snap.ctrl is not None
        assert snap.ctrl["current"] in ("push", "pull")
        assert len(snap.ctrl["decisions"]) == snap.it

        fresh = _streamed(factory, direction="auto")
        for s in steps:
            res = fresh.resume(d, step=s)
            _assert_same(res.result, base.result)


class TestInCorePlan:
    """The non-streamed engine shares the same snapshot surface."""

    def test_resume_matches(self, tmp_path):
        base = _incore(lambda: pagerank_algorithm(max_iters=6)).run()
        d = str(tmp_path / "ck")
        _incore(lambda: pagerank_algorithm(max_iters=6),
                checkpoint_every=2, checkpoint_dir=d).run()
        steps = _steps(d)
        assert steps and all(s % 2 == 0 or s == max(steps) for s in steps)
        fresh = _incore(lambda: pagerank_algorithm(max_iters=6))
        for s in steps:
            _assert_same(fresh.resume(d, step=s).result, base.result)

    def test_crash_then_resume(self, tmp_path):
        """A fault that exhausts max_retries escapes mid-run; the last
        on-disk boundary resumes to the fault-free answer."""
        base = _incore(lambda: pagerank_algorithm(max_iters=6)).run()
        d = str(tmp_path / "ck")
        doomed = _incore(lambda: pagerank_algorithm(max_iters=6),
                         faults="wave.compute:raise:at(3)",
                         retry_policy=RetryPolicy(max_retries=0),
                         checkpoint_every=1, checkpoint_dir=d)
        with pytest.raises(InjectedFault):
            doomed.run()
        assert latest_runstate_step(d) == 3   # iterations 0..2 persisted

        fresh = _incore(lambda: pagerank_algorithm(max_iters=6))
        res = fresh.resume(d)                 # latest boundary
        _assert_same(res.result, base.result)

    def test_checkpoint_requires_dir(self):
        with pytest.raises(ValueError, match="checkpoint_dir"):
            _incore(lambda: pagerank_algorithm(max_iters=6),
                    checkpoint_every=2)
        with pytest.raises(ValueError):
            _incore(lambda: pagerank_algorithm(max_iters=6),
                    checkpoint_every=0, checkpoint_dir="/tmp/x")

    def test_resume_without_dir_raises(self):
        plan = _incore(lambda: pagerank_algorithm(max_iters=6))
        with pytest.raises(ValueError, match="checkpoint"):
            plan.resume()


class TestFaultDifferentialWithCheckpoints:
    """Recovery and checkpointing compose: a faulted-but-recovered run
    writes the same restorable boundaries as a clean one."""

    @pytest.mark.parametrize("spec", [
        "stage.device_put:raise:at(1)",
        "stage.assemble:raise:at(2)",
        "wave.compute:oom:at(1)",
    ])
    def test_recovered_run_checkpoints_match(self, spec, tmp_path):
        base = _streamed(sv_algorithm).run()
        d = str(tmp_path / "ck")
        res = _streamed(sv_algorithm, faults=spec, checkpoint_every=1,
                        checkpoint_dir=d).run()
        _assert_same(res.result, base.result)
        r = res.schedule_stats["resilience"]
        assert r["injected"] >= 1 and r["checkpoints"] >= 1

        fresh = _streamed(sv_algorithm)
        for s in _steps(d):
            _assert_same(fresh.resume(d, step=s).result, base.result)
