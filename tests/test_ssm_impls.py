"""Equivalence of the §Perf recurrent-layer reformulations vs their
sequential-oracle forms (the hillclimb must not change the math)."""
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.models import lm
from repro.models.ssm import (
    init_mamba, init_mlstm, mamba_seq, mamba_seq_assoc,
    mlstm_seq, mlstm_seq_chunked,
)


@pytest.mark.parametrize("b,s,d,h,w", [(2, 128, 64, 4, 32), (1, 256, 128, 4, 64)])
def test_mlstm_chunked_equals_recurrent(b, s, d, h, w):
    p = init_mlstm(jax.random.key(0), d, h, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    a = mlstm_seq(p, x, n_heads=h)
    c = mlstm_seq_chunked(p, x, n_heads=h, chunk=w)
    rel = float(jnp.abs(a - c).max()) / float(jnp.abs(a).max())
    assert rel < 1e-3, rel


@pytest.mark.parametrize("b,s,d,n", [(2, 64, 32, 8), (1, 128, 64, 16)])
def test_mamba_assoc_equals_scan(b, s, d, n):
    p = init_mamba(jax.random.key(0), d, n, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    a = mamba_seq(p, x, d_state=n)
    c = mamba_seq_assoc(p, x, d_state=n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               atol=1e-5, rtol=1e-4)


def test_xlstm_forward_loss_impl_invariant():
    cfg = replace(get_smoke("xlstm-1.3b"), dtype="float32")
    cfg_c = replace(cfg, mlstm_impl="chunked", mlstm_chunk=32)
    key = jax.random.key(2)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    batch = dict(tokens=toks, labels=toks)
    l1, _ = jax.jit(lambda p, b: lm.forward_loss(cfg, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: lm.forward_loss(cfg_c, p, b))(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_hymba_forward_loss_impl_invariant():
    cfg = replace(get_smoke("hymba-1.5b"), dtype="float32")
    cfg_a = replace(cfg, mamba_impl="assoc")
    key = jax.random.key(3)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    batch = dict(tokens=toks, labels=toks)
    l1, _ = jax.jit(lambda p, b: lm.forward_loss(cfg, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: lm.forward_loss(cfg_a, p, b))(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_qwen_loss_remat_policy_invariant():
    cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
    cfg_s = replace(cfg, remat_policy="save_attn")
    key = jax.random.key(4)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    batch = dict(tokens=toks, labels=toks)
    from repro.models.steps import make_train_step
    from repro.optim import adamw_init

    s1 = jax.jit(make_train_step(cfg))
    s2 = jax.jit(make_train_step(cfg_s))
    _, _, m1 = s1(params, adamw_init(params), batch, jnp.int32(0))
    _, _, m2 = s2(params, adamw_init(params), batch, jnp.int32(0))
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-4)
