"""Integration tests: the paper's five algorithms vs networkx oracles."""
import numpy as np
import networkx as nx
import pytest

from repro.core import build_block_store
from repro.algorithms import (
    pagerank, shiloach_vishkin, connected_components, bfs, triangle_count,
)

GRAPHS = ["rmat", "road", "star", "er"]
_UNVISITED = 2**31 - 1


@pytest.mark.parametrize("name", GRAPHS)
def test_pagerank_matches_networkx(name, small_graphs, nx_graphs, stores):
    g, G, store = small_graphs[name], nx_graphs[name], stores[name]
    pr = pagerank(store, mode="hybrid", dense_density=0.001)
    want = nx.pagerank(G, alpha=0.85, tol=1e-12)
    want = np.array([want[i] for i in range(g.n)])
    assert np.abs(pr.sum() - 1.0) < 1e-3
    np.testing.assert_allclose(pr, want, atol=5e-5)


@pytest.mark.parametrize("name", GRAPHS)
def test_sv_components(name, small_graphs, nx_graphs, stores):
    g, G, store = small_graphs[name], nx_graphs[name], stores[name]
    C = shiloach_vishkin(store)
    comps = list(nx.connected_components(G))
    assert len(np.unique(C)) == len(comps)
    for comp in comps:  # all members share one label
        labels = {int(C[v]) for v in comp}
        assert len(labels) == 1


@pytest.mark.parametrize("name", GRAPHS)
def test_afforest_components(name, small_graphs, nx_graphs, stores):
    g, G, store = small_graphs[name], nx_graphs[name], stores[name]
    C = connected_components(store)
    comps = list(nx.connected_components(G))
    assert len(np.unique(C)) == len(comps)
    for comp in comps:
        labels = {int(C[v]) for v in comp}
        assert len(labels) == 1


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("mode", ["sparse_only", "hybrid"])
def test_bfs_distances(name, mode, small_graphs, nx_graphs, small_graphs_source=0):
    g, G = small_graphs[name], nx_graphs[name]
    store = build_block_store(g, 4)
    src = int(np.argmax(np.diff(g.indptr)))  # highest-degree vertex
    out = bfs(store, source=src, mode=mode, dense_density=0.001)
    want = np.full(g.n, _UNVISITED, np.int64)
    for k, v in nx.single_source_shortest_path_length(G, src).items():
        want[k] = v
    assert np.array_equal(out["dist"].astype(np.int64), want)
    # parent validity: parent[v] is a real neighbor one level closer
    par, dist = out["parent"], out["dist"]
    for v in range(g.n):
        if dist[v] not in (0, _UNVISITED):
            assert par[v] in g.neighbors(v)
            assert dist[par[v]] == dist[v] - 1


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("mode", ["sparse_only", "dense_only", "hybrid"])
def test_triangle_count(name, mode, small_graphs, nx_graphs):
    g, G = small_graphs[name], nx_graphs[name]
    want = sum(nx.triangles(G).values()) // 3
    got = triangle_count(g, p=4, mode=mode, tile_dim=512)
    assert got == want


@pytest.mark.parametrize("p", [1, 2, 3, 8])
def test_triangle_count_partition_invariance(p, small_graphs, nx_graphs):
    g, G = small_graphs["rmat"], nx_graphs["rmat"]
    want = sum(nx.triangles(G).values()) // 3
    assert triangle_count(g, p=p) == want


def test_pallas_paths_match_xla(small_graphs, nx_graphs):
    g, G = small_graphs["rmat"], nx_graphs["rmat"]
    store = build_block_store(g, 4)
    pr_x = pagerank(store, mode="hybrid", dense_density=0.001, use_pallas=False)
    store2 = build_block_store(g, 4)
    pr_p = pagerank(store2, mode="hybrid", dense_density=0.001, use_pallas=True)
    np.testing.assert_allclose(pr_x, pr_p, rtol=1e-6)
    assert triangle_count(g, p=4, use_pallas=True) == sum(
        nx.triangles(G).values()) // 3


@pytest.mark.parametrize("name", ["rmat", "er"])
@pytest.mark.parametrize("k", [2, 3, 4])
def test_kcore_matches_networkx(name, k, small_graphs, nx_graphs, stores):
    from repro.algorithms import k_core

    g, G, store = small_graphs[name], nx_graphs[name], stores[name]
    alive = k_core(store, k)
    want = set(nx.k_core(G, k).nodes())
    got = set(np.where(alive)[0].tolist())
    assert got == want


@pytest.mark.parametrize("name", ["rmat", "er"])
def test_hits_matches_networkx(name, small_graphs, nx_graphs, stores):
    from repro.algorithms import hits

    g, G, store = small_graphs[name], nx_graphs[name], stores[name]
    out = hits(store)
    want_h, want_a = nx.hits(G, max_iter=500, tol=1e-12)
    wh = np.array([want_h[i] for i in range(g.n)])
    wa = np.array([want_a[i] for i in range(g.n)])
    # networkx normalizes to sum=1; ours to L2 — compare directions
    np.testing.assert_allclose(
        out["hub"] / out["hub"].sum(), wh, atol=1e-4)
    np.testing.assert_allclose(
        out["auth"] / out["auth"].sum(), wa, atol=1e-4)
