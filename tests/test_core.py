"""Unit tests: graph container, partitioner, block store, scheduler."""
import numpy as np
import pytest

from repro.core import (
    from_edges, rmat, grid_road, degree_order, save_binary, load_binary,
    partition_1d, partition_symmetric_2d, make_layout, build_block_store,
    build_schedule, lpt_assign,
)
from repro.algorithms import pagerank_algorithm
from repro.algorithms.tc import tc_algorithm, orient_dag


# ---------------------------------------------------------------- graph
def test_from_edges_dedup_symmetrize():
    g = from_edges([0, 0, 1, 2, 2], [1, 1, 0, 2, 3], n=4)
    # (0,1) deduped+symmetrized, (2,2) self-loop dropped, (2,3) symmetric
    assert g.m == 4  # 0-1, 1-0, 2-3, 3-2
    assert set(g.neighbors(0).tolist()) == {1}
    assert set(g.neighbors(2).tolist()) == {3}


def test_directed_edges_kept():
    g = from_edges([0, 1], [1, 2], n=3, symmetrize=False)
    assert g.m == 2
    assert g.directed


def test_binary_roundtrip(tmp_path):
    g = rmat(7, 4, seed=0)
    path = str(tmp_path / "g.npz")
    save_binary(g, path)
    g2 = load_binary(path)
    assert g2.n == g.n and g2.m == g.m
    assert np.array_equal(g2.indptr, g.indptr)
    assert np.array_equal(g2.indices, g.indices)


@pytest.mark.parametrize("fname", ["graph.bin", "cache.npz"])
def test_binary_roundtrip_atomic_any_suffix(tmp_path, fname):
    """Regression: save_binary used a conditional rename that could miss
    (savez always appends .npz to the temp name) and leave stale temp
    files behind.  Any destination suffix must work, atomically."""
    g = rmat(6, 4, seed=3)
    path = str(tmp_path / fname)
    save_binary(g, path)
    g2 = load_binary(path)
    assert g2.n == g.n and np.array_equal(g2.indices, g.indices)
    assert np.array_equal(g2.indptr, g.indptr)
    assert g2.directed == g.directed
    # no temp litter: exactly the destination file remains
    assert sorted(p.name for p in tmp_path.iterdir()) == [fname]
    save_binary(g, path)  # overwrite path is exercised too
    assert sorted(p.name for p in tmp_path.iterdir()) == [fname]


def test_read_edge_list_comments_and_blanks(tmp_path):
    from repro.core import read_edge_list

    text = (
        "# a comment line\n"
        "% another comment style\n"
        "\n"
        "0 1\n"
        "1 2 0.5\n"         # trailing weight column ignored
        "   \n"
        "2 3\n"
        "# trailing comment\n"
        "3 0\n"
    )
    path = tmp_path / "edges.txt"
    path.write_text(text)
    g = read_edge_list(str(path))
    assert g.n == 4
    assert g.m == 8  # 4 undirected edges, symmetrized
    assert set(g.neighbors(0).tolist()) == {1, 3}
    assert set(g.neighbors(2).tolist()) == {1, 3}


def test_degree_order_ascending():
    g = rmat(7, 6, seed=1)
    go, perm = degree_order(g, ascending=True)
    d = go.degrees
    assert go.m == g.m
    # degrees must be (weakly) sorted under the new labels
    assert np.all(np.diff(d) >= -0)  # non-decreasing


# ------------------------------------------------------------ partition
@pytest.mark.parametrize("p", [1, 2, 4, 7])
def test_partition_cuts_valid(p):
    g = rmat(8, 8, seed=2)
    cuts = partition_symmetric_2d(g, p)
    assert cuts[0] == 0 and cuts[-1] == g.n
    assert np.all(np.diff(cuts) >= 0)
    assert len(cuts) == p + 1


def test_partition_1d_balance():
    g = rmat(8, 8, seed=2)
    cuts = partition_1d(g, 4)
    loads = g.indptr[cuts[1:]] - g.indptr[cuts[:-1]]
    assert loads.sum() == g.m
    # bottleneck within 2x of ideal for a graph with max degree << m/p
    assert loads.max() <= 2 * (g.m // 4 + int(g.degrees.max()))


@pytest.mark.parametrize("order", ["row_major", "snake"])
def test_grid_of_matches_block_ids(order):
    """grid_of must invert block_ids exactly (now via the precomputed
    O(1) inverse map rather than an O(p²) argwhere per call)."""
    g = rmat(7, 6, seed=2)
    layout = make_layout(g, 5, order=order)
    assert layout.grid_pos is not None
    for i in range(layout.p):
        for j in range(layout.p):
            assert layout.grid_of(int(layout.block_ids[i, j])) == (i, j)


def test_layout_conformal_counts():
    g = rmat(8, 8, seed=5)
    lay = make_layout(g, 4)
    assert lay.block_edge_counts.sum() == g.m


# --------------------------------------------------------------- blocks
def test_blocks_disjoint_cover():
    """Paper §3.1: blocks are disjoint and their union is G."""
    g = rmat(8, 8, seed=7)
    store = build_block_store(g, 4)
    assert store.block_ptr[-1] == g.m  # every edge exactly once
    # every edge is in the block its endpoints dictate
    bi = np.searchsorted(store.layout.cuts, store.src.astype(np.int64), "right") - 1
    bj = np.searchsorted(store.layout.cuts, store.dst.astype(np.int64), "right") - 1
    assert np.array_equal(bi * 4 + bj, store.edge_block)


def test_conformal_row_slices():
    g = rmat(8, 8, seed=7)
    store = build_block_store(g, 4)
    for u in [0, 1, g.n // 2, g.n - 1]:
        adj = g.neighbors(u)
        for k in range(4):
            lo, hi = store.layout.cuts[k], store.layout.cuts[k + 1]
            want = adj[(adj >= lo) & (adj < hi)]
            s, e = store.row_block_ptr[u, k], store.row_block_ptr[u, k + 1]
            assert np.array_equal(want, store.indices[s:e])


def test_tile_materialization_exact():
    g = rmat(7, 8, seed=9)
    store = build_block_store(g, 2)
    t = int(max(max(store.block_range(b) for b in range(4))))
    tdim = 1 << int(np.ceil(np.log2(t)))
    store.materialize_tiles(np.arange(4, dtype=np.int32), tdim)
    assert store.tiles.sum() == g.m  # every edge is one tile bit
    # per-block bit counts match edge counts
    for slot, b in enumerate(store.tile_block_ids):
        s, e = store.block_ptr[b], store.block_ptr[b + 1]
        assert store.tiles[slot].sum() == e - s


# ------------------------------------------------------------ scheduler
def test_lpt_assignment_properties():
    w = np.array([10.0, 9, 8, 2, 2, 2, 1, 1])
    a = lpt_assign(w, 3)
    assert a.shape == w.shape
    loads = np.zeros(3)
    np.add.at(loads, a, w)
    assert loads.sum() == w.sum()
    # LPT guarantee: makespan <= 4/3 OPT; OPT >= max(mean, max w)
    opt_lb = max(w.sum() / 3, w.max())
    assert loads.max() <= 4 / 3 * opt_lb + 1e-9


def test_schedule_modes():
    g = rmat(8, 8, seed=11)
    dag = orient_dag(g)
    store = build_block_store(dag, 4)
    alg = tc_algorithm()
    s_sparse = build_schedule(alg, store, mode="sparse_only")
    assert not s_sparse.dense_task_mask.any()
    store2 = build_block_store(dag, 4)
    s_hyb = build_schedule(alg, store2, mode="hybrid", tile_dim=512,
                           dense_density=1e-5, dense_frac=0.5)
    # heavy tasks claimed first: every dense task at least as heavy as the
    # heaviest unclaimed *eligible* task is not guaranteed post-cutoff, but
    # total dense weight must respect the cut-off fraction loosely
    st = s_hyb.stats
    assert 0 <= st["dense_weight_frac"] <= 1.0


def test_schedule_weight_is_paper_default():
    g = rmat(7, 8, seed=13)
    store = build_block_store(g, 2)
    alg = pagerank_algorithm()
    sched = build_schedule(alg, store, mode="sparse_only")
    # default E = #edges in the block-list
    want = np.diff(store.block_ptr)
    got = sched.weights
    assert np.array_equal(got.astype(np.int64), want)
