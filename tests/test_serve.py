"""Serving-engine tests: wave batching, retirement, decode==prefill greed."""
from dataclasses import replace

import numpy as np
import jax
import pytest

from repro.configs import get_smoke
from repro.models import lm
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_engine_drains_all_requests(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, batch_slots=3, cache_len=64)
    for uid in range(7):  # 3 waves: 3 + 3 + 1
        eng.submit(Request(uid=uid, prompt=[1 + uid, 2, 3], max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(r.done for r in done)
    assert all(len(r.output) == 5 for r in done)
    assert {r.uid for r in done} == set(range(7))


def test_engine_eos_stops_early(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, batch_slots=2, cache_len=64)
    # find what the model emits first, then use it as EOS
    probe = ServeEngine(cfg, params, batch_slots=1, cache_len=64)
    probe.submit(Request(uid=0, prompt=[5], max_new_tokens=1))
    first = probe.run_until_drained()[0].output[0]
    eng.submit(Request(uid=1, prompt=[5], max_new_tokens=20, eos_id=first))
    done = eng.run_until_drained()
    assert len(done[0].output) == 1  # stopped at EOS immediately


def test_cache_length_retirement_sets_truncated(small_model):
    """A request the wave's cache cannot finish is done AND truncated;
    normally-finished requests are not."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, batch_slots=2, cache_len=16)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=100))
    eng.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=3))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[0].done and done[0].truncated
    assert len(done[0].output) < 100
    assert done[1].done and not done[1].truncated
    assert len(done[1].output) == 3


def test_engine_greedy_matches_single_stream(small_model):
    """Batched slots must not leak state between requests."""
    cfg, params = small_model
    solo = ServeEngine(cfg, params, batch_slots=1, cache_len=64)
    solo.submit(Request(uid=0, prompt=[7, 11, 13], max_new_tokens=6))
    want = solo.run_until_drained()[0].output

    batched = ServeEngine(cfg, params, batch_slots=4, cache_len=64)
    for uid, p0 in enumerate([3, 7, 9, 21]):
        batched.submit(Request(uid=uid, prompt=[p0, 11, 13], max_new_tokens=6))
    done = batched.run_until_drained()
    got = next(r for r in done if r.uid == 1).output
    assert got == want
