"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.tc_tile import tc_tiles
from repro.kernels.spmv_tile import spmv_tiles
from repro.kernels.frontier_tile import frontier_tiles
from repro.kernels.attn_tile import flash_attention

RNG = np.random.default_rng(42)


def _tiles(nb, t, density, dtype):
    return jnp.asarray((RNG.random((nb, t, t)) < density).astype(dtype))


@pytest.mark.parametrize("nb,t", [(1, 128), (3, 128), (2, 256), (1, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_tc_tiles(nb, t, dtype):
    a, b, m = (_tiles(nb, t, 0.05, dtype) for _ in range(3))
    got = tc_tiles(a, b, m, interpret=True)
    want = ref.tc_tiles_ref(a, b, m)
    np.testing.assert_allclose(np.float32(got), np.float32(want), rtol=1e-5)


@pytest.mark.parametrize("block_t", [128, 256])
def test_tc_tiles_block_sweep(block_t):
    a, b, m = (_tiles(2, 256, 0.05, np.float32) for _ in range(3))
    got = tc_tiles(a, b, m, block_t=block_t, interpret=True)
    np.testing.assert_allclose(np.float32(got), np.float32(ref.tc_tiles_ref(a, b, m)),
                               rtol=1e-5)


@pytest.mark.parametrize("nb,t", [(1, 128), (4, 128), (2, 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmv_tiles(nb, t, dtype):
    tiles = _tiles(nb, t, 0.1, dtype)
    xs = jnp.asarray(RNG.random((nb, t)).astype(np.float32)).astype(dtype)
    got = spmv_tiles(tiles, xs, interpret=True)
    want = ref.spmv_tiles_ref(tiles, xs)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("nb,t", [(1, 128), (4, 128), (2, 256), (1, 512)])
def test_frontier_tiles(nb, t):
    tiles = _tiles(nb, t, 0.05, np.float32)
    f = jnp.asarray((RNG.random((nb, t)) < 0.3).astype(np.float32))
    got = frontier_tiles(tiles, f, interpret=True)
    want = ref.frontier_tiles_ref(tiles, f)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_frontier_tiles_empty_frontier():
    tiles = _tiles(2, 128, 0.05, np.float32)
    f = jnp.zeros((2, 128), jnp.float32)
    got = frontier_tiles(tiles, f, interpret=True)
    assert np.all(np.asarray(got) == 2**31 - 1)


@pytest.mark.parametrize("nb,t,block_t", [(2, 192, 128), (1, 96, 64),
                                          (2, 160, 128), (1, 48, 128)])
def test_frontier_tiles_non_power_of_two_tile_dim(nb, t, block_t):
    """Regression: a tile dim the requested row-panel height does not
    divide used to trip a bare ``assert`` (gone under ``python -O``);
    the kernel now shrinks the panel to the largest divisor and still
    matches the oracle."""
    tiles = _tiles(nb, t, 0.05, np.float32)
    f = jnp.asarray((RNG.random((nb, t)) < 0.3).astype(np.float32))
    got = frontier_tiles(tiles, f, block_t=block_t, interpret=True)
    want = ref.frontier_tiles_ref(tiles, f)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_frontier_tiles_rejects_non_positive_block_t():
    tiles = _tiles(1, 128, 0.05, np.float32)
    f = jnp.zeros((1, 128), jnp.float32)
    with pytest.raises(ValueError, match="block_t must be a positive int"):
        frontier_tiles(tiles, f, block_t=0, interpret=True)


@pytest.mark.parametrize(
    "b,h,sq,sk,d,causal",
    [
        (1, 2, 128, 128, 64, True),
        (2, 1, 128, 256, 64, True),   # suffix-aligned causal (decode-like)
        (1, 1, 256, 256, 128, False),
        (1, 1, 256, 128, 64, False),
    ],
)
def test_flash_attention(b, h, sq, sk, d, causal):
    q = jnp.asarray(RNG.standard_normal((b, h, sq, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, h, sk, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, h, sk, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.float32(got), np.float32(want), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("b,r,k,n", [(1, 128, 8, 256), (3, 256, 16, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmv_ell(b, r, k, n, dtype):
    from repro.kernels.spmv_ell import spmv_ell

    idx = jnp.asarray(RNG.integers(0, n, (b, r, k)).astype(np.int32))
    valid = jnp.asarray((RNG.random((b, r, k)) < 0.7))
    x = jnp.asarray(RNG.random((b, n)).astype(np.float32)).astype(dtype)
    got = spmv_ell(idx, valid, x, interpret=True)
    want = ref.spmv_ell_ref(idx, valid, x)
    np.testing.assert_allclose(
        np.float32(got), np.float32(want),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5,
    )
