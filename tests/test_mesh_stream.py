"""Mesh-cooperative streaming: budgeted waves through ``shard_map``.

Three layers of coverage:

* host-side units for the generalized device partitioner (all blocks of
  a task, bucket padding, per-device CSR slabs), mesh-capacity wave
  packing, the device-aware partition grain, and per-device workspace
  pricing — no mesh required;
* in-process mesh runs over whatever devices the test process has
  (1 in the plain pytest job, 8 under the CI ``distributed`` job's
  ``XLA_FLAGS``) — the acceptance criterion's "runs on a 1-device
  mesh" half;
* an 8-device host-platform subprocess (XLA locks the device count at
  first init) running streamed-vs-distributed-vs-in-core equivalence
  for all seven algorithms on a skewed R-MAT with ≥ 4 waves — integer
  attributes checksum-exact, floats up to summation order.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    BlockAlgorithm, MemoryBudget, build_block_store, build_schedule,
    build_waves, choose_p, compile_plan, make_device_edge_partition, rmat,
    task_footprints,
)
from repro.core.membudget import bucket_size
from repro.algorithms import pagerank_algorithm, tc_algorithm
from repro.algorithms.tc import orient_dag

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------- host-side units
@pytest.fixture(scope="module")
def graph():
    return rmat(8, 8, seed=3)


def test_partition_covers_all_blocks_of_multiblock_tasks(graph):
    """Regression: a device's edges are the union of *every* block of
    its tasks — the old partitioner took only the first block of each
    block-list, silently dropping TC triples' B_ik/B_jk edges."""
    dag = orient_dag(graph)
    store = build_block_store(dag, 4)
    sched = build_schedule(tc_algorithm(), store, num_devices=4,
                           mode="sparse_only")
    part = make_device_edge_partition(store, sched)
    staged = set()
    for bl in part["blocks"]:
        staged.update(int(b) for b in bl)
    needed = {int(b) for row in sched.blocklists for b in row}
    assert needed <= staged
    # and per device: every block of every assigned task is present
    for dev in range(4):
        dev_blocks = set(int(b) for b in part["blocks"][dev])
        for t in np.nonzero(sched.device_assignment == dev)[0]:
            assert {int(b) for b in sched.blocklists[t]} <= dev_blocks


def test_partition_single_block_tasks_cover_each_edge_once(graph):
    """Bulk composition (one block per task): the all-blocks fix must
    not change the disjoint-cover property the engine relies on."""
    store = build_block_store(graph, 8)
    sched = build_schedule(pagerank_algorithm(), store, num_devices=8,
                           mode="sparse_only")
    part = make_device_edge_partition(store, sched)
    assert int(part["valid"].sum()) == store.m


def test_partition_bucket_padding_and_csr_slabs(graph):
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, num_devices=4,
                           mode="sparse_only")
    part = make_device_edge_partition(store, sched, bucket=True,
                                      stage_csr=True)
    width = part["src"].shape[1]
    assert width == bucket_size(width)     # on the power-of-two ladder
    assert part["indices"].shape[1] == bucket_size(part["indices"].shape[1])
    # each device's CSR slab is exactly its blocks' conformal slices
    for dev in range(4):
        want, _, _, _ = store.csr_slices(part["blocks"][dev])
        n = part["csr_entries"][dev]
        assert n == want.shape[0]
        np.testing.assert_array_equal(part["indices"][dev, :n], want)
        assert not part["indices"][dev, n:].any()


def test_build_waves_mesh_capacity(graph):
    """devices=D packs waves to D × budget, but a single task is atomic
    on one device — the per-task bound must not relax."""
    store = build_block_store(graph, 4)
    sched = build_schedule(pagerank_algorithm(), store, mode="sparse_only")
    fp = task_footprints(store, sched)
    budget = MemoryBudget(int(fp.max()) * 2)
    solo = build_waves(store, sched, budget, fp)
    mesh4 = build_waves(store, sched, budget, fp, devices=4)
    assert len(mesh4) < len(solo)
    for w in mesh4:
        assert fp[w.task_ids].sum() <= budget.total_bytes * 4
    # union is still a disjoint cover
    ids = np.concatenate([w.task_ids for w in mesh4])
    assert sorted(ids.tolist()) == list(range(sched.num_tasks))
    # per-task bound: an oversized task raises regardless of mesh size
    tiny = MemoryBudget(max(int(fp.max()) // 2, 1))
    with pytest.raises(ValueError, match="per-device budget"):
        build_waves(store, sched, tiny, fp, devices=8)


def test_choose_p_devices_floor(graph):
    # generous budget: a lone device needs no partitioning at all ...
    assert choose_p(graph, "1GB") == 1
    # ... but an 8-device mesh needs at least 8 single-block tasks per
    # wave to keep every device busy: p² ≥ 8 → p = 4 on the pow-2 ladder
    p = choose_p(graph, "1GB", devices=8)
    assert p * p >= 8
    assert p == 4


def test_registry_per_device_pricing():
    from repro.kernels.registry import workspace_bytes

    one = workspace_bytes("spmv_tiles", nd=8, tile_dim=64)
    split = workspace_bytes("spmv_tiles", nd=8, tile_dim=64, devices=4)
    assert split == one // 4
    # ceil-division: 5 items over 4 devices price the 2-item device
    assert (workspace_bytes("csr_bucket_search", items=5, depth=8, devices=4)
            == workspace_bytes("csr_bucket_search", items=2, depth=8))


def test_mesh_requires_budget_and_declaration(graph):
    import jax
    from jax.sharding import Mesh

    store = build_block_store(graph, 4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("blocks",))
    with pytest.raises(ValueError, match="memory_budget"):
        compile_plan(pagerank_algorithm(), store, mesh=mesh)
    # an algorithm that never declared mesh="shard" must not silently
    # run under collectives
    import jax.numpy as jnp

    undeclared = BlockAlgorithm(
        name="mesh_undeclared",
        kernel_sparse=lambda ctx, state, it: dict(
            state, x=state["x"].at[ctx.dst].add(1.0)),
        init_state=lambda store: dict(x=jnp.zeros(store.n)),
        metadata=dict(combine="add", csr="none"),
    )
    with pytest.raises(ValueError, match="metadata\\['mesh'\\]"):
        compile_plan(undeclared, store, memory_budget="64KB", mesh=mesh,
                     share=False)


# ------------------------------------------- in-process mesh execution
def _mesh_all_devices():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("blocks",))


def test_mesh_streamed_matches_incore_inprocess(graph):
    """Whatever mesh this process can build (1 device in the plain test
    job, 8 under the distributed CI job): per-device staged bytes stay
    under the per-device budget and results match in-core."""
    mesh = _mesh_all_devices()
    store = build_block_store(graph, 4)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="16KB", mesh=mesh)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert st["mesh_devices"] == mesh.size
    assert len(st["per_device_bytes"]) == st["num_waves"]
    assert all(b <= st["budget_bytes"] for b in st["per_device_bytes"])
    assert st["collective_bytes"] > 0          # acc crossed a psum
    want = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False).run().result
    np.testing.assert_allclose(np.asarray(res.result), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


def test_mesh_streamed_tc_pattern_mode_inprocess(graph):
    """TC under a mesh: multi-block triples partition per device, the
    mesh_pack-unified buckets index per-device CSR slabs, and the
    triangle count psums to the exact in-core integer."""
    dag = orient_dag(graph)
    mesh = _mesh_all_devices()
    store = build_block_store(dag, 4)
    plan = compile_plan(tc_algorithm(), store, mode="hybrid",
                        dense_density=0.001, tile_dim=128, share=False,
                        memory_budget="600KB", mesh=mesh)
    res = plan.run()
    st = res.schedule_stats["streaming"]
    assert all(b <= st["budget_bytes"] for b in st["per_device_bytes"])
    want = compile_plan(tc_algorithm(), store, mode="hybrid",
                        dense_density=0.001, tile_dim=128,
                        share=False).run().result
    assert res.result == want


def test_mesh_rebalance_keeps_per_device_budget(graph):
    """Tail-wave rebalancing composes with the mesh: a forced-skew
    re-pack rebuilds per-device slabs that still satisfy the per-device
    budget and computes the identical result.

    The mesh is capped at 2 devices so the wave capacity (D × budget)
    stays below the graph's staged working set on every CI
    configuration — an 8-device mesh at this budget would pack the
    whole graph into one wave, leaving nothing to rebalance."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:2]), ("blocks",))
    store = build_block_store(graph, 8)
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False, memory_budget="8KB", mesh=mesh,
                        rebalance_threshold=1.5)
    nw = plan.num_waves
    assert nw >= 2
    times = [1.0] * (nw - 1) + [10.0 * nw]
    assert plan.rebalance(times) is True
    for s in plan._slabs:
        assert (s.per_device_bytes + s.workspace_bytes
                <= plan.budget.total_bytes)
    res = plan.run()
    want = compile_plan(pagerank_algorithm(), store, mode="sparse_only",
                        share=False).run().result
    np.testing.assert_allclose(np.asarray(res.result), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


# ------------------------------------- 8-device subprocess composition
def _run_py(code: str, devices: int = 8, timeout: int = 500):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.mark.slow
@pytest.mark.subprocess
def test_streamed_vs_distributed_vs_incore_all_algorithms():
    """Acceptance: a skewed R-MAT whose staged working set exceeds one
    device's budget runs as ≥ 4 budgeted waves through an 8-device
    host-platform mesh, with every per-device staged wave ≤ its budget,
    and all seven algorithms produce results matching both the
    single-device streaming plan and the in-core Plan — integer
    attributes checksum-exact."""
    r = _run_py("""
        import json
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import build_block_store, choose_p, compile_plan, rmat
        from repro.algorithms import (
            pagerank_algorithm, sv_algorithm, afforest_algorithm,
            bfs_algorithm, kcore_algorithm, hits_algorithm, tc_algorithm,
        )
        from repro.algorithms.tc import orient_dag

        assert len(jax.devices()) == 8, jax.devices()
        mesh = Mesh(np.array(jax.devices()), ("blocks",))
        g = rmat(10, 16, seed=5)          # skewed: hub-heavy Kronecker
        dag = orient_dag(g)

        ALGS = [
            ("pagerank", pagerank_algorithm, g, "12KB", {}),
            ("sv", sv_algorithm, g, "12KB", {}),
            ("afforest", afforest_algorithm, g, "12KB", {}),
            ("bfs", lambda: bfs_algorithm(0), g, "12KB", {}),
            ("kcore3", lambda: kcore_algorithm(3), g, "12KB", {}),
            ("hits", hits_algorithm, g, "12KB", {}),
            ("tc", tc_algorithm, dag, "48KB", dict(safety=12)),
        ]

        def checksum(x):
            x = np.asarray(x)
            if x.dtype.kind in "fc":
                return None
            return int(x.astype(np.int64).sum())

        def compare(name, a, b, ctx):
            if isinstance(a, dict):
                assert a.keys() == b.keys(), (name, ctx)
                for k in a:
                    compare(f"{name}.{k}", a[k], b[k], ctx)
                return
            a, b = np.asarray(a), np.asarray(b)
            if a.dtype.kind in "fc":
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7,
                                           err_msg=f"{name} ({ctx})")
            else:
                # integer attributes: bit-identical, checksum-exact
                np.testing.assert_array_equal(a, b, err_msg=f"{name} ({ctx})")
                assert checksum(a) == checksum(b)

        report = {}
        for name, alg_f, graph, budget, pkw in ALGS:
            p = max(choose_p(graph, budget, devices=8, **pkw), 4)
            store = build_block_store(graph, p)
            mode = "sparse_only"
            incore = compile_plan(alg_f(), build_block_store(graph, p),
                                  mode=mode, share=False).run()
            solo = compile_plan(alg_f(), build_block_store(graph, p),
                                mode=mode, share=False,
                                memory_budget=budget).run()
            meshed = compile_plan(alg_f(), store, mode=mode, share=False,
                                  memory_budget=budget, mesh=mesh).run()
            st = meshed.schedule_stats["streaming"]
            assert st["mesh_devices"] == 8
            # the graph's staged working set exceeds one device's budget
            assert sum(st["bytes_per_wave"]) > st["budget_bytes"]
            assert st["num_waves"] >= 4, (name, st["num_waves"])
            assert all(b <= st["budget_bytes"]
                       for b in st["per_device_bytes"]), name
            assert st["collective_bytes"] > 0, name
            compare(name, incore.result, meshed.result, "mesh vs incore")
            compare(name, solo.result, meshed.result, "mesh vs solo-stream")
            report[name] = dict(
                waves=st["num_waves"],
                max_per_device=max(st["per_device_bytes"]),
                budget=st["budget_bytes"],
                collective_kb=st["collective_bytes"] // 1000,
            )
        print("MESH_OK", json.dumps(report))
    """)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr
    report = json.loads(r.stdout.split("MESH_OK", 1)[1])
    assert set(report) == {
        "pagerank", "sv", "afforest", "bfs", "kcore3", "hits", "tc"
    }
    for name, row in report.items():
        assert row["waves"] >= 4, (name, row)
        assert row["max_per_device"] <= row["budget"], (name, row)
