"""Roofline analysis unit tests: HLO collective parsing + term math."""
import numpy as np
import pytest

from repro.roofline import (
    HW, collective_bytes_from_hlo, model_flops, roofline_terms,
)
from repro.roofline.analysis import parse_shape_bytes
from repro.configs import SHAPES, get_config


def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[16,2048,512]") == 16 * 2048 * 512 * 2
    assert parse_shape_bytes("f32[8]") == 32
    assert parse_shape_bytes("(f32[4,4], bf16[2])") == 64 + 4
    assert parse_shape_bytes("pred[128]") == 128
    assert parse_shape_bytes("f32[]") == 4  # scalar


def test_collective_parse():
    hlo = """
  %all-gather.1 = bf16[16,1024]{1,0} all-gather(%p0), dimensions={0}
  %x = f32[4]{0} add(%a, %b)
  ROOT %all-reduce.2 = f32[256,256]{1,0} all-reduce(%x2), to_apply=%sum
  %rs = f32[8,8]{1,0} reduce-scatter(%y), dimensions={0}
  %ag2 = (bf16[2,2]{1,0}, bf16[2,2]{1,0}) all-gather-start(%z), dimensions={0}
"""
    out = collective_bytes_from_hlo(hlo)
    ag = 16 * 1024 * 2 + 2 * (2 * 2 * 2)     # all-gather + all-gather-start
    ar = 256 * 256 * 4 * 2.0                 # ring factor 2
    rs = 8 * 8 * 4
    assert out["per_kind"]["all-gather"] == ag
    assert out["per_kind"]["all-reduce"] == ar
    assert out["per_kind"]["reduce-scatter"] == rs
    assert out["counts"]["all-gather"] == 2
    assert out["total"] == ag + ar + rs


def test_roofline_terms_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    coll = dict(total=50e9 * 2, per_kind={}, counts={})
    t = roofline_terms(cost, coll, chips=256)
    assert abs(t["t_compute"] - 1.0) < 1e-9
    assert abs(t["t_memory"] - 0.5) < 1e-9
    assert abs(t["t_collective"] - 2.0) < 1e-9
    assert t["dominant"] == "collective"


def test_model_flops_moe_uses_active_params():
    dense = get_config("qwen2.5-32b")
    moe = get_config("qwen3-moe-235b-a22b")
    sh = SHAPES["train_4k"]
    # MoE 235B has ~22B active → its MODEL_FLOPS must be well below a
    # same-token dense-235B estimate and in the same ballpark as 32B dense
    f_moe = model_flops(moe, sh)
    f_dense = model_flops(dense, sh)
    assert f_moe < 2.5 * f_dense
    full_would_be = 6.0 * moe.param_count() * sh.global_batch * sh.seq_len
    assert f_moe < 0.25 * full_would_be


def test_decode_flops_scale_with_batch_only():
    cfg = get_config("qwen2.5-32b")
    f = model_flops(cfg, SHAPES["decode_32k"])
    assert f == 2.0 * cfg.active_param_count() * SHAPES["decode_32k"].global_batch


def test_param_counts_sane():
    """Abstract-params and analytic counts agree (consistency of both)."""
    import jax
    from repro.models.steps import abstract_params

    for arch in ["qwen2.5-32b", "deepseek-moe-16b", "whisper-base"]:
        cfg = get_config(arch)
        exact = sum(
            int(np.prod(l.shape))
            for l in jax.tree.leaves(abstract_params(cfg))
        )
        approx = cfg.param_count()
        assert 0.6 < exact / approx < 1.7, (arch, exact, approx)
