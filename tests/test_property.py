"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import from_edges, build_block_store, partition_symmetric_2d
from repro.core.scheduler import lpt_assign
from repro.algorithms import pagerank, shiloach_vishkin, triangle_count

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@st.composite
def random_graph(draw, max_n=64, max_m=160):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(1, max_m))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    return from_edges(np.array(src), np.array(dst), n=n)


@given(random_graph(), st.integers(1, 5))
def test_blocks_partition_edges(g, p):
    """Invariant (paper §3.1): blocks are disjoint, B ≡ G."""
    store = build_block_store(g, p)
    assert store.block_ptr[-1] == g.m
    # sorted (src,dst) multiset identical to the graph's edge set
    a = np.sort(store.src.astype(np.int64) * g.n + store.dst)
    s, d = g.coo()
    b = np.sort(s.astype(np.int64) * g.n + d)
    assert np.array_equal(a, b)


@given(random_graph(), st.integers(1, 5))
def test_cuts_monotone_cover(g, p):
    cuts = partition_symmetric_2d(g, p)
    assert cuts[0] == 0 and cuts[-1] == g.n
    assert np.all(np.diff(cuts) >= 0)


@given(
    st.lists(st.floats(0.1, 100.0), min_size=1, max_size=40),
    st.integers(1, 6),
)
def test_lpt_bound(weights, d):
    w = np.asarray(weights)
    a = lpt_assign(w, d)
    loads = np.zeros(d)
    np.add.at(loads, a, w)
    assert np.isclose(loads.sum(), w.sum())
    opt_lb = max(w.sum() / d, w.max())
    assert loads.max() <= 4 / 3 * opt_lb + 1e-6


@given(random_graph())
def test_pagerank_is_distribution(g):
    store = build_block_store(g, 2)
    pr = pagerank(store, mode="sparse_only", max_iters=30)
    assert np.all(pr >= 0)
    assert abs(pr.sum() - 1.0) < 1e-3


@given(random_graph())
def test_sv_is_valid_components(g):
    """Same label ⇔ connected (union-find oracle)."""
    store = build_block_store(g, 2)
    C = shiloach_vishkin(store)
    parent = list(range(g.n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    s, d = g.coo()
    for u, v in zip(s.tolist(), d.tolist()):
        parent[find(u)] = find(v)
    roots = {find(v) for v in range(g.n)}
    assert len(np.unique(C)) == len(roots)
    for u in range(g.n):
        for v in range(u + 1, g.n):
            if find(u) == find(v):
                assert C[u] == C[v]


@given(random_graph(max_n=40, max_m=100), st.permutations(list(range(8))))
def test_tc_permutation_invariant(g, perm_seed):
    """Triangle count is invariant under vertex relabeling."""
    want = triangle_count(g, p=2)
    rng = np.random.default_rng(sum(perm_seed))
    perm = rng.permutation(g.n)
    s, d = g.coo()
    g2 = from_edges(perm[s], perm[d], n=g.n)
    assert triangle_count(g2, p=2) == want
