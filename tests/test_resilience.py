"""Fault-tolerant runtime: injection, recovery ladder, serving robustness.

The contract under test: seeded fault injection at every executor seam
(``repro.core.faults``) must be recovered by the resilience ladder
(``repro.core.resilience`` wired into both executors) with results
bit-identical to the fault-free run for integer/bool attributes —
retries fold from iteration-start state, OOM re-packs never relax the
per-task budget bound, worker death fails over to synchronous
assembly, and host-lane failures carry their blame context.  Injection
disabled must be free: ``schedule_stats`` keys unchanged.

Serving robustness rides the same registry: per-query deadlines,
cancellation, queue-full shedding with a retry-after hint, and failed
cohort batches isolated to solo re-runs.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import build_block_store, compile_plan, rmat
from repro.core.faults import FaultPlan, InjectedFault, InjectedOOM
from repro.core.knobs import env_flag, env_float, env_int
from repro.core.resilience import (
    HostTaskError, RetryPolicy, WorkerDeath, classify, is_oom,
)
from repro.algorithms import pagerank_algorithm, sv_algorithm
from repro.serve.graphserve import GraphServer, Query

_GRAPHS: dict = {}


def _store(scale=9, p=4, seed=3):
    key = (scale, p, seed)
    if key not in _GRAPHS:
        _GRAPHS[key] = build_block_store(rmat(scale, 8, seed=seed), p)
    return _GRAPHS[key]


def _checksum(result):
    arr = np.asarray(result)
    if arr.dtype.kind in "biu":
        return int(arr.astype(np.int64).sum())
    return arr  # float results compare via allclose


def _assert_same(a, b):
    ca, cb = _checksum(a), _checksum(b)
    if isinstance(ca, int):
        assert ca == cb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        np.testing.assert_allclose(ca, cb, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------- spec


class TestFaultSpec:
    def test_parse_rules(self):
        fp = FaultPlan.parse(
            "wave.compute:raise:at(2); host.task:delay(0.01):every(3)")
        assert [(r.site, r.action, r.trigger, r.k) for r in fp.rules] == [
            ("wave.compute", "raise", "at", 2),
            ("host.task", "delay", "every", 3),
        ]

    def test_none_and_empty_disable(self):
        assert FaultPlan.parse(None) is None
        assert FaultPlan.parse("") is None
        assert FaultPlan.parse(" ; ") is None

    def test_passthrough(self):
        fp = FaultPlan.parse("wave.compute:raise")
        assert FaultPlan.parse(fp) is fp

    @pytest.mark.parametrize("bad", [
        "wave.compute",                  # no action
        "nowhere:raise",                 # unknown site
        "wave.compute:explode",          # unknown action
        "wave.compute:raise:sometimes",  # unknown trigger
        "wave.compute:delay",            # delay needs an argument
        "wave.compute:raise(2)",         # raise takes none
        "wave.compute:raise:every(0)",   # k >= 1
    ])
    def test_malformed_raises(self, bad):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)

    def test_at_is_single_shot(self):
        """A recovered retry of wave k must not re-trip the same rule."""
        fp = FaultPlan.parse("wave.compute:raise:at(1)")
        fp.fire("wave.compute", wave=0)
        with pytest.raises(InjectedFault):
            fp.fire("wave.compute", wave=1)
        fp.fire("wave.compute", wave=1)   # the retry passes
        assert fp.injected == 1

    def test_oom_classifies(self):
        fp = FaultPlan.parse("wave.compute:oom")
        with pytest.raises(InjectedOOM) as ei:
            fp.fire("wave.compute", wave=0)
        assert is_oom(ei.value) and classify(ei.value) == "oom"

    def test_corrupt_damages_value(self):
        fp = FaultPlan.parse("wave.compute:corrupt")
        out = fp.fire("wave.compute",
                      dict(x=np.arange(3), m=np.array([True, False])))
        np.testing.assert_array_equal(out["x"], [1, 2, 3])
        np.testing.assert_array_equal(out["m"], [False, True])

    def test_counters(self):
        fp = FaultPlan.parse("stage.assemble:delay(0):every(2)")
        for _ in range(4):
            fp.fire("stage.assemble")
        st = fp.stats()
        assert st["injected"] == 2
        assert st["rules"][0]["fired"] == 2


# --------------------------------------------------------------- knobs


class TestKnobs:
    def test_malformed_float_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_HETERO_HOST_RATIO", "fast")
        with pytest.raises(ValueError, match="REPRO_HETERO_HOST_RATIO"):
            env_float("REPRO_HETERO_HOST_RATIO", 1.0)

    def test_unknown_knob_raises(self):
        with pytest.raises(KeyError, match="REPRO_NOT_A_KNOB"):
            env_float("REPRO_NOT_A_KNOB", 1.0)

    def test_empty_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_HETERO_HOST_RATIO", "  ")
        assert env_float("REPRO_HETERO_HOST_RATIO", 2.5) == 2.5

    def test_flag_values(self, monkeypatch):
        monkeypatch.setenv("REPRO_TRACE", "yes")
        assert env_flag("REPRO_TRACE") is True
        monkeypatch.setenv("REPRO_TRACE", "off")
        assert env_flag("REPRO_TRACE") is False
        monkeypatch.setenv("REPRO_TRACE", "maybe")
        with pytest.raises(ValueError):
            env_flag("REPRO_TRACE")

    def test_malformed_int_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_CHAOS_WALL_RATIO", "1.x")
        with pytest.raises(ValueError):
            env_int("REPRO_CHAOS_WALL_RATIO", 1)

    def test_env_fault_spec_reaches_plan(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "wave.compute:raise:once")
        plan = compile_plan(pagerank_algorithm(max_iters=3), _store(),
                            share=False)
        res = plan.run()
        assert res.schedule_stats["resilience"]["injected"] == 1


# ------------------------------------------------- recovery (streaming)

BUDGET = "32KB"   # rmat(9) at p=8: 5 waves


def _streamed(alg_factory, *, faults=None, policy=None, depth=None,
              host=None, **kw):
    return compile_plan(
        alg_factory(), _store(9, 8), mode="sparse_only", share=False,
        memory_budget=BUDGET, rebalance_threshold=None,
        host_fraction=host, faults=faults, retry_policy=policy,
        **(dict(pipeline_depth=depth) if depth is not None else {}), **kw)


@pytest.fixture(scope="module")
def pr_baseline():
    res = _streamed(lambda: pagerank_algorithm(max_iters=6)).run()
    assert res.schedule_stats["streaming"]["num_waves"] >= 4
    assert "resilience" not in res.schedule_stats
    return res


@pytest.fixture(scope="module")
def sv_baseline():
    return _streamed(sv_algorithm).run()


class TestStreamingRecovery:
    @pytest.mark.parametrize("spec", [
        "stage.assemble:raise:at(1)",
        "stage.device_put:raise:at(1)",
        "wave.compute:raise:at(1)",
        "wave.compute:raise:at(0)",
        "stage.device_put:delay(0.01):once",
    ])
    def test_site_recovery_checksum_exact(self, spec, pr_baseline):
        res = _streamed(lambda: pagerank_algorithm(max_iters=6),
                        faults=spec, depth=0).run()
        _assert_same(res.result, pr_baseline.result)
        r = res.schedule_stats["resilience"]
        assert r["injected"] == 1
        if "delay" not in spec:
            assert r["detected"] == 1 and r["retries"] == 1

    def test_oom_shrink_repack(self, sv_baseline):
        res = _streamed(sv_algorithm, faults="wave.compute:oom:at(1)",
                        depth=0).run()
        _assert_same(res.result, sv_baseline.result)
        r = res.schedule_stats["resilience"]
        assert r["oom_repacks"] == 1 and r["demotions"] == 0

    def test_repeated_oom_demotes_to_host(self, sv_baseline):
        """demote_after consecutive OOMs on one iteration move the
        offending wave to the host lane — and the run still completes
        checksum-exact (two single-shot rules at the same wave: the
        first triggers a shrink-repack, the second crosses the
        demotion threshold)."""
        res = _streamed(
            sv_algorithm,
            faults="wave.compute:oom:at(1);wave.compute:oom:at(1)",
            policy=RetryPolicy(max_retries=4, demote_after=2),
            depth=0).run()
        _assert_same(res.result, sv_baseline.result)
        r = res.schedule_stats["resilience"]
        assert r["demotions"] >= 1 and r["oom_repacks"] >= 1

    def test_assemble_fault_in_worker_recovers(self, pr_baseline):
        """stage.assemble raising inside the executor (here: during
        the synchronous calibration pass) retries checksum-exact."""
        res = _streamed(lambda: pagerank_algorithm(max_iters=6),
                        faults="stage.assemble:raise:at(2)", depth=2).run()
        _assert_same(res.result, pr_baseline.result)
        assert res.schedule_stats["resilience"]["retries"] >= 1

    @staticmethod
    def _kill_worker(plan, deaths: int):
        """Make assembly raise the next ``deaths`` times it runs OFF
        the main thread — i.e. inside the background staging worker —
        so the failure deterministically surfaces as WorkerDeath."""
        orig = plan._assemble_runtime
        state = dict(deaths=0)

        def bomb(recipe, wave=None):
            if (threading.current_thread() is not threading.main_thread()
                    and state["deaths"] < deaths):
                state["deaths"] += 1
                raise RuntimeError("simulated staging worker crash")
            return orig(recipe, wave=wave)

        plan._assemble_runtime = bomb
        return state

    def test_worker_death_fails_over(self, pr_baseline):
        """A dead staging worker surfaces as WorkerDeath at get(); the
        iteration re-runs with synchronous assembly, then the pipeline
        resumes (one death is under failover_after)."""
        plan = _streamed(lambda: pagerank_algorithm(max_iters=6), depth=2)
        killed = self._kill_worker(plan, 1)
        res = plan.run()
        _assert_same(res.result, pr_baseline.result)
        assert killed["deaths"] == 1
        r = res.schedule_stats["resilience"]
        assert r["failovers"] == 1 and r["retries"] >= 1
        assert plan.pipeline_depth > 0   # transient: pipeline survives

    def test_permanent_worker_failover(self, pr_baseline):
        """failover_after deaths force pipeline_depth=0 for good."""
        plan = _streamed(lambda: pagerank_algorithm(max_iters=6),
                         policy=RetryPolicy(failover_after=1), depth=2)
        killed = self._kill_worker(plan, 5)
        res = plan.run()
        _assert_same(res.result, pr_baseline.result)
        assert killed["deaths"] == 1     # sync assembly never re-arms it
        assert plan.pipeline_depth == 0
        assert res.schedule_stats["resilience"]["failovers"] >= 1

    def test_exhausted_retries_raise(self):
        plan = _streamed(lambda: pagerank_algorithm(max_iters=6),
                         faults="wave.compute:raise:every(1)",
                         policy=RetryPolicy(max_retries=2), depth=0)
        with pytest.raises(InjectedFault):
            plan.run()
        assert plan._resil.actions[-1]["action"] == "exhausted"

    def test_corrupt_is_detectable(self, pr_baseline):
        """Silent corruption is NOT auto-detected — the differential
        harness must be sensitive enough to catch it.  This is the
        sensitivity control for every checksum-exact test above.
        ``every(1)`` hits the real iteration computes, not just the
        discarded calibration warm-up pass."""
        res = _streamed(lambda: pagerank_algorithm(max_iters=6),
                        faults="wave.compute:corrupt:every(1)", depth=0).run()
        assert res.schedule_stats["resilience"]["injected"] >= 1
        base = np.asarray(pr_baseline.result)
        assert not np.allclose(np.asarray(res.result), base)

    def test_disabled_keys_unchanged(self, pr_baseline):
        """No faults, no checkpoints → stats dict has no resilience
        block and the streaming keys match the seed contract."""
        assert "resilience" not in pr_baseline.schedule_stats
        res = compile_plan(pagerank_algorithm(max_iters=3), _store(),
                           share=False).run()
        assert "resilience" not in res.schedule_stats


# ------------------------------------------------------ host-lane blame


class TestHostLane:
    def test_host_fault_recovers(self, sv_baseline):
        res = _streamed(sv_algorithm, faults="host.task:raise:once",
                        host=0.25).run()
        _assert_same(res.result, sv_baseline.result)
        assert res.schedule_stats["resilience"]["retries"] >= 1

    def test_host_error_carries_context(self):
        """Satellite regression: a host-lane task failure names its
        unit, tasks, and iteration instead of surfacing as a bare
        exception at fold time."""
        plan = _streamed(sv_algorithm, faults="host.task:raise:every(1)",
                         policy=RetryPolicy(max_retries=0), host=0.25)
        with pytest.raises(HostTaskError) as ei:
            plan.run()
        err = ei.value
        assert err.unit >= 0 and err.it >= 0
        assert "host-lane unit" in str(err) and "iteration" in str(err)
        assert isinstance(err.__cause__, InjectedFault)

    def test_repeated_host_failure_disables_lane(self, sv_baseline):
        res = _streamed(sv_algorithm, faults="host.task:raise:every(1)",
                        policy=RetryPolicy(max_retries=6,
                                           failover_after=1),
                        host=0.25).run()
        _assert_same(res.result, sv_baseline.result)
        assert res.schedule_stats["resilience"]["host_failovers"] >= 1


# ----------------------------------------------------- teardown (close)


class TestTeardown:
    def test_close_and_context_manager(self):
        """Satellite regression: an aborted streamed run (here: retries
        exhausted at wave 2) must tear down its staging worker thread
        and host pool deterministically via close()/__exit__."""
        before = {t.ident for t in threading.enumerate()}
        plan = _streamed(sv_algorithm,
                         faults="wave.compute:raise:at(2)",
                         policy=RetryPolicy(max_retries=0),
                         depth=2, host=0.25)
        with pytest.raises(InjectedFault):
            with plan:
                plan.run()
        deadline = time.time() + 10.0
        while time.time() < deadline:
            leaked = [t for t in threading.enumerate()
                      if t.ident not in before and t.is_alive()
                      and not t.daemon]
            if not leaked:
                break
            time.sleep(0.05)
        assert not leaked, f"leaked threads: {leaked}"
        assert plan._pipe is None and plan._host_futs is None

    def test_close_idempotent_and_rerunnable(self, sv_baseline):
        plan = _streamed(sv_algorithm, depth=2, host=0.25)
        res1 = plan.run()
        plan.close()
        plan.close()
        res2 = plan.run()   # run() rebuilds the lane/pipe lazily
        _assert_same(res1.result, sv_baseline.result)
        _assert_same(res2.result, sv_baseline.result)


# ------------------------------------------------------------ policies


class TestRetryPolicy:
    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(backoff=1.0)
        with pytest.raises(TypeError):
            compile_plan(pagerank_algorithm(), _store(),
                         retry_policy="aggressive")

    def test_checkpoint_validation(self, tmp_path):
        with pytest.raises(ValueError, match="checkpoint_dir"):
            compile_plan(pagerank_algorithm(), _store(),
                         checkpoint_every=2)
        with pytest.raises(ValueError, match="checkpoint_every"):
            compile_plan(pagerank_algorithm(), _store(),
                         checkpoint_every=0, checkpoint_dir=str(tmp_path))


# ------------------------------------------------------------- serving


def _server(**kw):
    srv = GraphServer(**kw)
    srv.register_graph("web", _store(8, 4, seed=5))
    return srv


class TestServingRobustness:
    def test_cohort_failure_isolated_to_solo(self):
        """One poisoned batch must not sink its cohort: members are
        re-admitted solo and every query still completes."""
        srv = _server(faults="serve.query:raise:once")
        uids = [srv.submit(Query("web", "pagerank", dict(seeds=[i])))
                for i in range(3)]
        done = srv.drain()
        assert [done[u].status for u in uids] == ["done"] * 3
        assert srv.stats()["batch_failures"] == 1

    def test_singleton_failure_marks_failed(self):
        srv = _server(faults="serve.query:raise:once")
        uid = srv.submit(Query("web", "pagerank"))
        done = srv.drain()
        assert done[uid].status == "failed"
        assert "InjectedFault" in done[uid].reason
        assert srv.stats()["batch_failures"] == 1

    def test_deadline_expires_waiting_query(self):
        srv = _server()
        uid = srv.submit(Query("web", "pagerank", deadline_s=0.0))
        time.sleep(0.01)
        done = srv.drain()
        assert done[uid].status == "expired"
        assert srv.stats()["deadline_exceeded"] == 1

    def test_deadline_none_never_expires(self):
        srv = _server()
        uid = srv.submit(Query("web", "pagerank"))
        assert srv.drain()[uid].status == "done"

    def test_cancel(self):
        srv = _server()
        u1 = srv.submit(Query("web", "pagerank"))
        u2 = srv.submit(Query("web", "pagerank"))
        assert srv.cancel(u1) is True
        assert srv.cancel(u1) is False      # already cancelled
        assert srv.cancel(10_000) is False  # never submitted
        done = srv.drain()
        assert done[u1].status == "cancelled"
        assert done[u2].status == "done"
        assert srv.stats()["cancelled"] == 1

    def test_queue_full_sheds_with_retry_after(self):
        probe = _server()
        plan = probe.plan_for("web", "pagerank")
        u = probe.submit(Query("web", "pagerank"))
        priced = next(q for q in probe._admitted if q.uid == u).priced_bytes
        budget = plan.resident_device_bytes + priced + priced // 2

        srv = _server(memory_budget=budget, max_queue=1)
        admitted = srv.submit(Query("web", "pagerank"))
        queued = srv.submit(Query("web", "pagerank"))
        shed = srv.submit(Query("web", "pagerank"))
        q = srv.result(shed)
        assert q.status == "rejected"
        assert q.retry_after_s is not None and q.retry_after_s > 0
        assert "queue full" in q.reason
        assert srv.stats()["retry_after_rejections"] == 1
        done = srv.drain()   # the shed query never blocks the others
        assert done[admitted].status == done[queued].status == "done"

    def test_batch_results_match_fault_free(self):
        base = _server()
        b1 = base.submit(Query("web", "pagerank", dict(seeds=[1])))
        b2 = base.submit(Query("web", "pagerank", dict(seeds=[2])))
        base_done = base.drain()
        srv = _server(faults="serve.query:raise:once")
        u1 = srv.submit(Query("web", "pagerank", dict(seeds=[1])))
        u2 = srv.submit(Query("web", "pagerank", dict(seeds=[2])))
        done = srv.drain()
        np.testing.assert_allclose(np.asarray(done[u1].result),
                                   np.asarray(base_done[b1].result),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(done[u2].result),
                                   np.asarray(base_done[b2].result),
                                   rtol=1e-6)
