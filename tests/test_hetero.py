"""Differential host/device correctness harness (heterogeneous
co-scheduling).

The contract under test: splitting any wave into a device partition and
a host partition — at any ``host_fraction`` — must be invisible in the
results.  Host partials fold through the same ``metadata["combine"]``
contract as mesh partials, so integer/bool attributes are bit-identical
to the in-core plan and float attributes agree to tolerance, for every
shipped algorithm.

Also covered here: the forced-skew calibration unit tests for
``peel_host_tasks`` (a 10x-slower host peels nothing, a dominant dense
tile pushes the light tail to the host, hysteresis, the byte budget),
and the end-to-end invariant that staged device slabs never exceed
``memory_budget`` no matter what moved to the host.
"""
import numpy as np
import pytest

from repro.core import build_block_store, compile_plan, rmat
from repro.core.membudget import (
    MemoryBudget, build_waves, hetero_split_diverged, peel_host_tasks,
    task_footprints,
)
from repro.core.scheduler import build_schedule
from repro.algorithms import (
    afforest_algorithm, bfs_algorithm, hits_algorithm, kcore_algorithm,
    pagerank_algorithm, sv_algorithm, tc_algorithm,
)

# TC needs headroom for its conformal CSR slices on this graph — 64KB
# cannot hold a single triple's staged bytes even device-only.
ALGS = {
    "pagerank": (pagerank_algorithm, "64KB"),
    "afforest": (afforest_algorithm, "64KB"),
    "tc": (tc_algorithm, "256KB"),
    "bfs": (bfs_algorithm, "64KB"),
    "sv": (sv_algorithm, "64KB"),
    "kcore": (lambda: kcore_algorithm(3), "64KB"),
    "hits": (hits_algorithm, "64KB"),
}
FRACTIONS = (0.0, 0.3, "auto", 1.0)

_GRAPHS: dict = {}
_BASELINES: dict = {}


def _graph(seed: int):
    if seed not in _GRAPHS:
        _GRAPHS[seed] = rmat(9, 8, seed=seed)
    return _GRAPHS[seed]


def _baseline(name: str, seed: int):
    """In-core (no budget, no waves, no host lane) reference result."""
    key = (name, seed)
    if key not in _BASELINES:
        factory, _ = ALGS[name]
        store = build_block_store(_graph(seed), 4)
        plan = compile_plan(factory(), store, mode="sparse_only",
                            share=False)
        _BASELINES[key] = plan.run().result
    return _BASELINES[key]


def _leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)


def _assert_matches(result, expected):
    got, want = _leaves(result), _leaves(expected)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        if g.dtype.kind in "biu":
            # integer-checksum equality, then the full array
            assert int(g.astype(np.int64).sum()) == int(
                w.astype(np.int64).sum())
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


def _streamed(name: str, seed: int, frac):
    factory, budget = ALGS[name]
    store = build_block_store(_graph(seed), 4)
    plan = compile_plan(factory(), store, mode="sparse_only", share=False,
                        memory_budget=budget, host_fraction=frac)
    return plan.run()


@pytest.mark.parametrize("frac", FRACTIONS, ids=str)
@pytest.mark.parametrize("name", sorted(ALGS))
def test_differential_host_device(name, frac):
    """Every algorithm x every host fraction == the in-core plan."""
    res = _streamed(name, 3, frac)
    _assert_matches(res.result, _baseline(name, 3))
    het = res.schedule_stats["hetero"]
    assert het["enabled"]          # every shipped algorithm is capable
    if frac == 0.0 or frac == "auto":
        # "auto" starts device-only; waves here sit under the
        # production noise floor, so the split never activates
        assert het["resolved_split"] == 0.0
        assert het["host_tasks"] == 0
    else:
        # whenever the split is nonzero, host tasks really ran
        assert het["resolved_split"] > 0.0
        assert het["host_tasks"] > 0
        assert het["host_tasks_executed"] > 0


@pytest.mark.parametrize("name", sorted(ALGS))
def test_differential_second_seed(name):
    """A second randomized R-MAT instance at a fixed split."""
    res = _streamed(name, 11, 0.3)
    _assert_matches(res.result, _baseline(name, 11))
    assert res.schedule_stats["hetero"]["host_tasks"] > 0


def test_auto_activates_under_low_noise_floor(monkeypatch):
    """Lowering the calibration noise floor makes the auto split probe
    the host on CI-sized waves — and the result still matches."""
    monkeypatch.setenv("REPRO_HETERO_NOISE_FLOOR_S", "0.00001")
    res = _streamed("sv", 3, "auto")
    _assert_matches(res.result, _baseline("sv", 3))
    het = res.schedule_stats["hetero"]
    assert het["host_tasks_executed"] > 0
    assert het["host_ratio_measured"]


def test_staged_slabs_respect_budget_with_host_split():
    """Peeling to the host only ever shrinks the staged device slab."""
    res = _streamed("pagerank", 3, 0.3)
    st = res.schedule_stats["streaming"]
    assert st["num_waves"] >= 2
    assert max(st["bytes_per_wave"]) <= st["budget_bytes"]
    mk = res.schedule_stats["hetero"]["makespan"]
    assert mk["device_s"] >= 0.0 and mk["host_s"] > 0.0


def test_hetero_stats_shape():
    het = _streamed("sv", 3, 1.0).schedule_stats["hetero"]
    assert het["enabled"]
    assert het["host_fraction"] == 1.0
    assert het["resolved_split"] == pytest.approx(1.0)
    assert het["device_tasks"] == 0
    assert het["host_tasks"] > 0
    assert het["host_seconds"] > 0.0


# ---------------------------------------------------------------------
# validation

def test_host_fraction_requires_budget(stores):
    with pytest.raises(ValueError, match="memory_budget"):
        compile_plan(sv_algorithm(), stores["rmat"], host_fraction=0.5)


def test_host_fraction_rejects_bad_values(stores):
    with pytest.raises(ValueError):
        compile_plan(sv_algorithm(), stores["rmat"],
                     memory_budget="64KB", host_fraction=1.5)
    with pytest.raises(ValueError):
        compile_plan(sv_algorithm(), stores["rmat"],
                     memory_budget="64KB", host_fraction="sometimes")


def test_host_never_blocks_explicit_fraction(stores):
    alg = sv_algorithm()
    alg.metadata = dict(alg.metadata, host="never")
    with pytest.raises(ValueError, match="host"):
        compile_plan(alg, stores["rmat"], memory_budget="64KB",
                     host_fraction=0.3)
    # but "auto" quietly stays device-only
    plan = compile_plan(alg, stores["rmat"], memory_budget="64KB",
                        host_fraction="auto")
    assert not plan._host_capable


def test_uncertified_host_kernel_blocks_peeling(stores):
    alg = sv_algorithm()
    alg.metadata = dict(alg.metadata, host_kernels=("not_a_real_kernel",))
    with pytest.raises(ValueError, match="host"):
        compile_plan(alg, stores["rmat"], memory_budget="64KB",
                     host_fraction=0.3)


# ---------------------------------------------------------------------
# forced-skew calibration unit tests for the peel policy

def _sched_and_waves(store, budget="64KB"):
    sched = build_schedule(sv_algorithm(), store, mode="sparse_only",
                           memory_budget=budget)
    fp = task_footprints(store, sched)
    waves = build_waves(store, sched, MemoryBudget.of(budget), fp)
    return sched, fp, waves


def test_auto_without_times_peels_nothing(stores):
    """Design rule: with nothing measured the auto split stays at zero
    (compile-time state is identical to a device-only plan)."""
    sched, _, waves = _sched_and_waves(stores["rmat"])
    out = peel_host_tasks(sched, waves, "auto")
    assert all(w.host_task_ids.size == 0 for w in out)
    assert [w.task_ids.tolist() for w in out] == \
        [w.task_ids.tolist() for w in waves]


def test_slow_host_peels_nothing(stores):
    """Host 10x slower than the device on uniform tasks: no candidate
    can hide behind the remaining device work, so the split is ~0."""
    sched, fp, waves = _sched_and_waves(stores["rmat"])
    times = np.ones(sched.num_tasks)
    out = peel_host_tasks(sched, waves, "auto", task_times=times,
                          host_ratio=10.0, footprints=fp)
    # the hide rule caps host time at HETERO_HIDE_FACTOR/host_ratio of
    # the device's — on uniform tasks that is under 10% of each wave
    # (and exactly 0 for any wave smaller than ~13 tasks)
    n_host = sum(w.host_task_ids.size for w in out)
    n_all = sum(w.task_ids.size + w.host_task_ids.size for w in out)
    assert n_host <= 0.1 * n_all
    for w in out:
        if w.task_ids.size + w.host_task_ids.size < 13:
            assert w.host_task_ids.size == 0


def test_dominant_task_pushes_tail_to_host(stores):
    """One task dominates the wave: the light tail hides behind it."""
    sched, fp, waves = _sched_and_waves(stores["rmat"])
    times = np.full(sched.num_tasks, 0.01)
    wave = max(waves, key=lambda w: w.task_ids.size)
    assert wave.task_ids.size >= 2
    times[int(wave.task_ids[0])] = 10.0        # one dense-tile-like hog
    out = peel_host_tasks(sched, [wave], "auto", task_times=times,
                          host_ratio=4.0, footprints=fp)
    assert out[0].host_task_ids.size == wave.task_ids.size - 1
    assert int(wave.task_ids[0]) in out[0].task_ids  # hog stays on device


def test_peel_never_violates_wave_budget(stores):
    """Device est_bytes is re-priced from footprints after the peel, so
    a wave that fit before can only shrink."""
    sched, fp, waves = _sched_and_waves(stores["rmat"])
    budget = MemoryBudget.of("64KB")
    for f in (0.3, 0.7, 1.0):
        for w in peel_host_tasks(sched, waves, f, footprints=fp):
            assert w.est_bytes <= budget.total_bytes
            if w.task_ids.size:
                assert w.est_bytes == int(fp[w.task_ids].sum())


def test_numeric_fraction_hits_target(stores):
    sched, fp, waves = _sched_and_waves(stores["rmat"])
    times = np.ones(sched.num_tasks)
    out = peel_host_tasks(sched, waves, 0.5, task_times=times,
                          footprints=fp)
    for before, after in zip(waves, out):
        if before.task_ids.size >= 2:
            assert after.host_task_ids.size >= 1
            assert after.task_ids.size >= 1      # device side never empties
    out = peel_host_tasks(sched, waves, 1.0, footprints=fp)
    assert all(w.task_ids.size == 0 for w in out)


def test_split_hysteresis():
    """Small drifts in the measured split must not thrash the plan."""
    assert not hetero_split_diverged(0.30, 0.33)    # under both bands
    assert not hetero_split_diverged(0.30, 0.26)
    assert hetero_split_diverged(0.30, 0.40)        # abs band crossed
    assert hetero_split_diverged(0.0, 0.06)         # activation from zero
    assert not hetero_split_diverged(0.0, 0.04)
    assert hetero_split_diverged(0.5, 0.2)


# ---------------------------------------------------------------------
# property-style randomized differential (hypothesis-backed)

def test_property_random_graphs_differential():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (pip install .[dev])"
    )
    from hypothesis import given, settings, strategies as st

    from repro.core import from_edges

    settings.register_profile("hetero", deadline=None, max_examples=10)
    settings.load_profile("hetero")

    @st.composite
    def random_graph(draw, max_n=64, max_m=160):
        n = draw(st.integers(8, max_n))
        m = draw(st.integers(4, max_m))
        src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        return from_edges(np.array(src), np.array(dst), n=n)

    @given(random_graph(), st.sampled_from([0.3, 1.0]))
    def check(g, frac):
        store = build_block_store(g, 2)
        want = compile_plan(sv_algorithm(), store, mode="sparse_only",
                            share=False).run().result
        store2 = build_block_store(g, 2)
        try:
            plan = compile_plan(sv_algorithm(), store2, mode="sparse_only",
                                share=False, memory_budget="16KB",
                                host_fraction=frac)
        except ValueError:
            hypothesis.assume(False)    # a task outgrew the tiny budget
        got = plan.run().result
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    check()
