"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault-tolerant resume, elastic reshard."""
import os
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.data import TokenPipeline, synthetic_batch
from repro.models import lm
from repro.optim import (
    adamw_init, adamw_update, compress_int8, decompress_int8,
    cosine_schedule, sgdm_init, sgdm_update,
)
from repro.checkpoint import (
    CheckpointManager, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.train import TrainConfig, TrainLoop


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_sharded():
    p = TokenPipeline(seed=7, batch=8, seq=16, vocab=100)
    b1, b2 = p(3), p(3)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # replay-safe
    assert not np.array_equal(p(3)["tokens"], p(4)["tokens"])
    # shards are disjoint slices of the same logical batch
    s0 = synthetic_batch(7, 3, 8, 16, 100, shard=0, num_shards=2)
    s1 = synthetic_batch(7, 3, 8, 16, 100, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are the next-token shift structure (same dtype/shape)
    assert b1["labels"].shape == b1["tokens"].shape


# ----------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    params = dict(w=jnp.asarray([5.0, -3.0]))
    state = adamw_init(params)

    def grad(p):
        return dict(w=2 * p["w"])  # d/dw of w²

    for _ in range(300):
        params, state, _ = adamw_update(
            params, grad(params), state, lr=5e-2, weight_decay=0.0
        )
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_sgdm_step():
    params = dict(w=jnp.ones(3))
    state = sgdm_init(params)
    params2, state = sgdm_update(params, dict(w=jnp.ones(3)), state, lr=0.1)
    assert np.allclose(np.asarray(params2["w"]), 0.9)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, 10, 100)
    assert float(fn(0)) < 0.2
    assert float(fn(10)) > 0.9
    assert float(fn(99)) < 0.2


def test_int8_compression_roundtrip_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, scale = compress_int8(g)
    assert q.dtype == jnp.int8
    rec = decompress_int8(q, scale)
    # max error bounded by scale/2
    assert float(jnp.abs(rec - g).max()) <= float(scale) * 0.51 + 1e-7


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = dict(a=jnp.arange(5, dtype=jnp.float32),
                 nested=dict(b=jnp.ones((2, 3), jnp.bfloat16)),
                 count=jnp.asarray(7, jnp.int32))
    save_checkpoint(str(tmp_path), 3, state)
    template = jax.eval_shape(lambda: state)
    got, step = restore_checkpoint(str(tmp_path), template)
    assert step == 3
    assert np.array_equal(np.asarray(got["a"]), np.arange(5, dtype=np.float32))
    assert got["nested"]["b"].dtype == jnp.bfloat16
    assert int(got["count"]) == 7


def test_checkpoint_latest_pointer_survives_corruption(tmp_path):
    state = dict(a=jnp.zeros(4))
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 2, state)
    # corrupt the newest payload: pointer hash now mismatches → fall back
    newest = os.path.join(str(tmp_path), "step_00000002.npz")
    with open(newest, "r+b") as f:
        f.seek(0)
        f.write(b"garbage!")
    assert latest_step(str(tmp_path)) in (1, 2)  # never crashes
    # a torn LATEST pointer also falls back to directory scan
    with open(os.path.join(str(tmp_path), "LATEST"), "w") as f:
        f.write("{not json")
    assert latest_step(str(tmp_path)) == 2  # dir scan finds newest file


def test_checkpoint_gc_keeps_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for s in range(5):
        mgr.maybe_save(s, dict(a=jnp.zeros(2)))
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2


# ------------------------------------------------- fault-tolerant resume
def test_train_resume_exact(tmp_path):
    """Kill after k steps, resume, final state == uninterrupted run."""
    cfg = replace(get_smoke("qwen2.5-32b"), dtype="float32")
    tc = TrainConfig(steps=6, batch=4, seq=16, ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=2, base_lr=1e-3, warmup_steps=2, log_every=1)
    # uninterrupted
    full = TrainLoop(cfg, tc).run()
    # interrupted: run 3 steps (simulated crash = fresh loop object), resume
    tc_b = replace_tc(tc, ckpt_dir=str(tmp_path / "b"), steps=3)
    TrainLoop(cfg, tc_b).run()
    tc_b2 = replace_tc(tc_b, steps=6)
    resumed = TrainLoop(cfg, tc_b2).run()
    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(
            np.float32(a), np.float32(b), atol=1e-5, rtol=1e-4
        )


def replace_tc(tc, **kw):
    from dataclasses import replace as _r
    return _r(tc, **kw)


def test_elastic_restore_reshards(tmp_path):
    """Restore works regardless of the saving topology (host arrays)."""
    state = dict(w=jnp.arange(16, dtype=jnp.float32).reshape(4, 4))
    save_checkpoint(str(tmp_path), 0, state)
    template = jax.eval_shape(lambda: state)
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = dict(w=NamedSharding(mesh, P(None, None)))
    got, _ = restore_checkpoint(str(tmp_path), template, shardings=shardings)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
