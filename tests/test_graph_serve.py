"""GraphServer: batching semantics, admission bounds, resident-plan reuse."""
import numpy as np
import networkx as nx
import pytest

from repro.core import (
    batch_states, build_block_store, compile_plan, from_edges, rmat,
    unbatch_state,
)
from repro.core.membudget import batch_state_bytes, tree_array_bytes
from repro.algorithms import bfs, pagerank
from repro.algorithms.bfs import bfs_algorithm
from repro.algorithms.pagerank import pagerank_algorithm
from repro.serve import GraphServer, Query

_UNVISITED = 2**31 - 1


def _permuted_copy(g, seed=0):
    """Same n/m, different labels — a genuinely different graph."""
    perm = np.random.default_rng(seed).permutation(g.n)
    s, d = g.coo()
    return from_edges(perm[s], perm[d], n=g.n)


@pytest.fixture(scope="module")
def store(small_graphs):
    return build_block_store(small_graphs["rmat"], 4)


# ------------------------------------------------------- batched algorithms
def test_multi_source_bfs_matches_solo_exactly(store):
    srcs = [0, 5, 17, 100, 63]
    out = bfs(store, sources=srcs, mode="hybrid", dense_density=0.001)
    assert out["parent"].shape == (len(srcs), store.n)
    for i, s in enumerate(srcs):
        solo = bfs(store, source=s, mode="hybrid", dense_density=0.001)
        assert np.array_equal(out["parent"][i], solo["parent"])
        assert np.array_equal(out["dist"][i], solo["dist"])


def test_multi_source_bfs_streamed_matches_solo(store):
    srcs = [3, 11, 42]
    plan = compile_plan(bfs_algorithm(sources=srcs), store,
                        memory_budget="40KB")
    assert plan.num_waves >= 2
    out = plan.run().result
    for i, s in enumerate(srcs):
        solo = bfs(store, source=s)
        assert np.array_equal(np.asarray(out["parent"])[i], solo["parent"])
        assert np.array_equal(np.asarray(out["dist"])[i], solo["dist"])


def test_personalized_pagerank_matches_networkx(small_graphs, nx_graphs):
    g, G = small_graphs["rmat"], nx_graphs["rmat"]
    store = build_block_store(g, 4)
    seeds = [3, 9, 27]
    pr = pagerank(store, seeds=seeds, tol=1e-9, max_iters=200)
    pers = {v: 0.0 for v in G}
    for s in seeds:
        pers[s] = 1.0 / len(seeds)
    want = nx.pagerank(G, alpha=0.85, personalization=pers, dangling=pers,
                       tol=1e-12, max_iter=500)
    want = np.array([want[i] for i in range(g.n)])
    np.testing.assert_allclose(pr, want, atol=5e-5)
    assert abs(pr.sum() - 1.0) < 1e-3


def test_batched_pagerank_freezes_to_solo_state(store):
    """Each row of a batched run ends bit-identical to its solo run,
    even though queries converge at different iterations."""
    seedsets = [[0], [7, 19], [3, 9, 27]]
    plan = compile_plan(pagerank_algorithm(), store, mode="sparse_only")
    states = [pagerank_algorithm(seeds=s).init_state(store)
              for s in seedsets]
    res = plan.run(state=batch_states(states, pad_to=4))
    for i, s in enumerate(seedsets):
        solo = compile_plan(pagerank_algorithm(seeds=s), store,
                            mode="sparse_only").run()
        got = np.asarray(unbatch_state(res.state, i)["rank"])
        assert np.array_equal(got, solo.result)


# ----------------------------------------------------------- GraphServer
def test_server_batch_bit_identical_to_solo(store):
    srv = GraphServer(max_batch=8)
    srv.register_graph("web", store, mode="sparse_only")
    srcs = [0, 5, 17, 100, 63]
    uids = [srv.submit(Query("web", "bfs", dict(source=s))) for s in srcs]
    done = srv.drain()
    for uid, s in zip(uids, srcs):
        solo = bfs(store, source=s, mode="sparse_only")
        q = done[uid]
        assert q.status == "done"
        assert np.array_equal(q.result["parent"], solo["parent"])
        assert np.array_equal(q.result["dist"], solo["dist"])
        assert q.latency_s is not None and q.latency_s > 0


def test_server_mixed_kinds_and_nonbatchable(store):
    from repro.algorithms import connected_components, k_core

    srv = GraphServer(max_batch=4)
    srv.register_graph("web", store, mode="sparse_only")
    u_pr = srv.submit(Query("web", "pagerank", dict(seeds=[1])))
    u_kc = srv.submit(Query("web", "kcore", dict(k=3)))
    u_cc = srv.submit(Query("web", "cc"))
    done = srv.drain()
    np.testing.assert_array_equal(
        done[u_pr].result,
        pagerank(store, seeds=[1], mode="sparse_only"))
    np.testing.assert_array_equal(done[u_kc].result, k_core(store, 3))
    np.testing.assert_array_equal(
        done[u_cc].result, connected_components(store))


def test_server_bucket_ladder_traces_once_per_bucket(store):
    srv = GraphServer(max_batch=8)
    # distinctive params → a private compiled-step cache entry, so
    # trace counts aren't polluted by other tests in this process
    srv.register_graph("web", store, mode="sparse_only")
    params = dict(seeds=None, damping=0.66)
    for s in ([2], [5], [9]):
        srv.submit(Query("web", "pagerank", dict(params, seeds=s)))
    srv.drain()                      # batch of 3 → bucket 4
    plan = srv.plan_for("web", "pagerank", dict(damping=0.66, seeds=[2]))
    c = plan.compile_count
    for s in ([11], [13], [17], [21]):
        srv.submit(Query("web", "pagerank", dict(params, seeds=s)))
    srv.drain()                      # batch of 4 → same bucket, no retrace
    assert plan.compile_count == c
    st = srv.stats()
    assert st["bucket_sizes"] == [4, 4]
    assert st["batch_sizes"] == [3, 4]


def test_admission_budget_never_exceeded_streamed(store):
    """The acceptance invariant: priced resident+batch footprint stays
    under the serving budget, asserted under a streamed plan (≥4 waves),
    while every query still completes with solo-exact results."""
    wave_budget = "40KB"
    probe = compile_plan(pagerank_algorithm(), store,
                         memory_budget=wave_budget)
    assert probe.num_waves >= 4
    per_q = batch_state_bytes(
        tree_array_bytes(pagerank_algorithm(seeds=[0]).init_state(store)), 1)
    budget = probe.resident_device_bytes + 3 * per_q

    srv = GraphServer(memory_budget=budget, max_batch=8)
    srv.register_graph("web", store, memory_budget=wave_budget)
    uids = [srv.submit(Query("web", "pagerank", dict(seeds=[s])))
            for s in range(8)]
    st = srv.stats()
    assert st["queue_depth"] > 0          # budget forces queueing
    done = srv.drain()
    st = srv.stats()
    assert st["footprint_high_water_bytes"] <= budget
    assert st["rejected"] == 0
    assert st["completed"] == 8
    assert st["queued"] > 0
    for s, uid in enumerate(uids):
        solo = compile_plan(pagerank_algorithm(seeds=[s]), store,
                            memory_budget=wave_budget).run().result
        assert np.array_equal(done[uid].result, solo)


def test_admission_rejects_query_that_never_fits(store):
    probe = compile_plan(pagerank_algorithm(), store, memory_budget="40KB")
    per_q = batch_state_bytes(
        tree_array_bytes(pagerank_algorithm(seeds=[0]).init_state(store)), 1)
    srv = GraphServer(memory_budget=probe.resident_device_bytes + per_q // 2)
    srv.register_graph("web", store, memory_budget="40KB")
    uid = srv.submit(Query("web", "pagerank", dict(seeds=[1])))
    q = srv.result(uid)
    assert q.status == "rejected" and q.reason
    assert srv.stats()["rejected"] == 1
    assert srv.drain()[uid] is q          # drain still returns it


def test_tenant_cap_queues_own_burst_not_others(store):
    per_q = batch_state_bytes(
        tree_array_bytes(pagerank_algorithm(seeds=[0]).init_state(store)), 1)
    srv = GraphServer(max_batch=1, tenant_budgets={"a": per_q})
    srv.register_graph("web", store, mode="sparse_only")
    srv.submit(Query("web", "pagerank", dict(seeds=[1]), tenant="a"))
    srv.submit(Query("web", "pagerank", dict(seeds=[2]), tenant="a"))
    srv.submit(Query("web", "pagerank", dict(seeds=[3]), tenant="b"))
    st = srv.stats()
    assert st["queued"] == 1              # a's burst waits behind a's cap
    assert st["admitted"] == 2            # b admits immediately
    done = srv.drain()
    assert all(q.status == "done" for q in done.values())
    # a query alone over its tenant cap is rejected, not queued forever
    srv2 = GraphServer(tenant_budgets={"c": per_q // 2})
    srv2.register_graph("web", store, mode="sparse_only")
    uid = srv2.submit(Query("web", "pagerank", dict(seeds=[1]), tenant="c"))
    assert srv2.result(uid).status == "rejected"


def test_serving_stats_block(store):
    srv = GraphServer(memory_budget="256MB", max_batch=4)
    srv.register_graph("web", store, mode="sparse_only")
    for s in range(5):
        srv.submit(Query("web", "bfs", dict(source=s)))
    done = srv.drain()
    st = srv.stats()
    assert st["admitted"] == 5 and st["completed"] == 5
    lat = st["latency_s"]
    assert lat["p50"] <= lat["p95"] <= lat["p99"]
    assert 0 < st["batch_occupancy"] <= 1.0
    assert st["steps_executed"] > 0
    assert st["budget_bytes"] == 256_000_000
    assert 0 < st["footprint_high_water_bytes"] <= st["budget_bytes"]
    # the serving block rides on every batch's schedule_stats
    q = next(iter(done.values()))
    serving = q.schedule_stats["serving"]
    for key in ("queue_depth", "admitted", "rejected", "batch_occupancy",
                "latency_s"):
        assert key in serving


def test_server_unknown_inputs_fail_loudly(store):
    srv = GraphServer()
    srv.register_graph("web", store)
    with pytest.raises(KeyError):
        srv.submit(Query("nope", "pagerank"))
    with pytest.raises(ValueError):
        srv.submit(Query("web", "pagerankk"))
    with pytest.raises(ValueError):
        srv.submit(Query("web", "bfs", dict(sauce=3)))
    with pytest.raises(ValueError):
        srv.register_graph("web", store)


# ----------------------------------------------- cross-graph plan reuse
def test_server_shares_in_core_plan_across_same_shape_graphs(small_graphs):
    g1 = small_graphs["rmat"]
    g2 = _permuted_copy(g1, seed=7)
    s1, s2 = build_block_store(g1, 4), build_block_store(g2, 4)
    srv = GraphServer(max_batch=4)
    srv.register_graph("a", s1, mode="sparse_only")
    srv.register_graph("b", s2, mode="sparse_only")
    u1 = srv.submit(Query("a", "pagerank", dict(seeds=[1], damping=0.71)))
    srv.drain()
    plan = srv.plan_for("a", "pagerank", dict(seeds=[1], damping=0.71))
    c = plan.compile_count
    u2 = srv.submit(Query("b", "pagerank", dict(seeds=[1], damping=0.71)))
    done = srv.drain()
    assert srv.plan_for("b", "pagerank",
                        dict(seeds=[1], damping=0.71)) is plan
    assert plan.compile_count == c        # zero new steps for graph b
    fresh = compile_plan(pagerank_algorithm(seeds=[1], damping=0.71), s2,
                         mode="sparse_only", share=False).run().result
    np.testing.assert_allclose(done[u2].result, fresh, atol=1e-7)
    assert done[u1].result.shape == fresh.shape


def test_streamed_plan_reuse_compiles_zero_new_steps(small_graphs):
    """Satellite: a second streamed plan over a same-shape graph rides
    the process-wide stream-step cache — zero new compiles when the
    wave bucket ladder coincides — and matches a fresh unshared plan."""
    g1 = small_graphs["rmat"]

    def alg():
        return pagerank_algorithm(damping=0.81)      # private cache entry

    s1, s2 = build_block_store(g1, 4), build_block_store(g1, 4)
    p1 = compile_plan(alg(), s1, memory_budget="40KB")
    p1.run()
    c = p1.compile_count
    assert c >= 1
    p2 = compile_plan(alg(), s2, memory_budget="40KB")
    r2 = p2.run()
    assert p2.compile_count == c          # same buckets → zero new steps
    # a genuinely different (relabeled) graph may pack different wave
    # buckets — each NEW bucket shape traces once, results still match
    g3 = _permuted_copy(g1, seed=11)
    s3 = build_block_store(g3, 4)
    p3 = compile_plan(alg(), s3, memory_budget="40KB")
    r3 = p3.run()
    fresh = compile_plan(alg(), s3, memory_budget="40KB",
                         share=False).run()
    np.testing.assert_allclose(r3.result, fresh.result, atol=1e-7)
    np.testing.assert_allclose(r2.result, p1.run().result, atol=1e-7)


def test_in_core_plan_run_other_store_matches_fresh(small_graphs):
    """Satellite: plan.run(other_store) — zero new steps AND the same
    numbers a fresh plan computes (the serving path leans on this)."""
    g1 = small_graphs["rmat"]
    g2 = _permuted_copy(g1, seed=13)
    s1, s2 = build_block_store(g1, 4), build_block_store(g2, 4)
    plan = compile_plan(pagerank_algorithm(damping=0.79), s1,
                        mode="sparse_only", share=False)
    plan.run()
    assert plan.compile_count == 1
    via_reuse = plan.run(s2)
    assert plan.compile_count == 1        # zero new compiled steps
    fresh = compile_plan(pagerank_algorithm(damping=0.79), s2,
                         mode="sparse_only", share=False).run()
    np.testing.assert_allclose(via_reuse.result, fresh.result, atol=1e-7)


# ------------------------------------------------------ batch-state helpers
def test_batch_state_helpers_round_trip():
    states = [dict(x=np.full((3,), i, np.int32), s=np.int32(i))
              for i in range(3)]
    b = batch_states(states, pad_to=4)
    assert b["x"].shape == (4, 3) and b["s"].shape == (4,)
    for i in range(3):
        row = unbatch_state(b, i)
        assert np.array_equal(np.asarray(row["x"]), states[i]["x"])
        assert int(row["s"]) == i
    assert int(unbatch_state(b, 3)["s"]) == 2   # pad replicates the last
    with pytest.raises(ValueError):
        batch_states([])
    with pytest.raises(ValueError):
        batch_states(states, pad_to=2)
