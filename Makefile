# Tier-1 verify — the exact command CI runs (see ROADMAP.md).
.PHONY: test lint bench examples docs-test

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

lint:
	ruff check src tests benchmarks examples

# every ">>> " block in README.md and docs/ is executed — the quickstart
# cannot rot
docs-test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -q \
		--doctest-glob='*.md' README.md docs

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --scale small

examples:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python examples/quickstart.py
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python examples/pipeline.py
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python examples/heterogeneous_schedule.py
