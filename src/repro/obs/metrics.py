"""Process-wide metrics registry: counters, gauges, bounded histograms.

The registry is the one place the executors publish quantitative
telemetry — :mod:`repro.core.stream` (phase seconds, staged/arena
bytes, budget high water), :mod:`repro.core.engine` (runs, iterations),
:mod:`repro.core.compilecache` (cache hits/misses),
:mod:`repro.core.membudget` (wave builds, tenant high water), and
:mod:`repro.serve` (admission decisions, batch occupancy, query latency
histograms).  Unlike the tracer it is **always on**: every instrument
is a couple of arithmetic ops under a lock, cheap enough for per-wave
paths, and :func:`MetricsRegistry.snapshot` renders the whole registry
as one flat dict — the ``metrics`` block of the unified run-report
(:func:`repro.obs.export.run_report`).

Histograms use **fixed buckets** so memory stays constant in the
observation count (the property the serving latency percentiles need:
a server that has answered a million queries holds the same few dozen
ints as one that answered ten).  :meth:`Histogram.percentile`
interpolates within the selected bucket, so estimates are within one
bucket width of the exact order statistic.
"""
from __future__ import annotations

import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "metrics", "exp_bucket_edges", "latency_bucket_edges",
]


class Counter:
    """Monotonically increasing value (floats allowed: seconds, bytes)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        with self._lock:
            self._v += amount

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self):
        v = self._v
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-written value, with a tracked high-water mark."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._v = 0.0
        self._hi = float("-inf")
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._v = value
            self._hi = max(self._hi, value)

    def set_max(self, value: float) -> None:
        """Ratchet: keep the max of the current and new value."""
        with self._lock:
            self._v = max(self._v, value)
            self._hi = max(self._hi, self._v)

    @property
    def value(self) -> float:
        return self._v

    @property
    def high_water(self) -> float:
        return self._hi if self._hi != float("-inf") else 0.0

    def snapshot(self):
        v = self._v
        return int(v) if float(v).is_integer() else v


def exp_bucket_edges(lo: float, hi: float,
                     per_decade: int = 5) -> tuple[float, ...]:
    """Log-spaced bucket edges from ``lo`` to ``hi`` (inclusive),
    ``per_decade`` buckets per factor of 10 — relative resolution
    ``10**(1/per_decade)`` everywhere in range."""
    if not (0 < lo < hi):
        raise ValueError("need 0 < lo < hi")
    n = int(math.ceil(per_decade * math.log10(hi / lo)))
    edges = [lo * 10 ** (i / per_decade) for i in range(n)]
    edges.append(hi)
    return tuple(edges)


def latency_bucket_edges() -> tuple[float, ...]:
    """The default latency ladder: 10 µs … 1000 s, 5 buckets/decade
    (≈ 58% relative bucket width — p50/p95/p99 land within one bucket
    of the exact sample)."""
    return exp_bucket_edges(1e-5, 1e3, per_decade=5)


class Histogram:
    """Fixed-bucket histogram; memory constant in observation count.

    ``edges`` are the interior bucket boundaries; observations below
    ``edges[0]`` or at/above ``edges[-1]`` land in unbounded end
    buckets whose interpolation is clamped to the observed min/max, so
    :meth:`percentile` never reports a value outside the data range.
    """

    def __init__(self, name: str = "",
                 edges: tuple[float, ...] | None = None) -> None:
        self.name = name
        self.edges = tuple(float(e) for e in
                           (edges if edges is not None
                            else latency_bucket_edges()))
        if sorted(self.edges) != list(self.edges) or len(self.edges) < 2:
            raise ValueError("histogram edges must be sorted, >= 2 entries")
        # bucket i covers [edges[i-1], edges[i]); 0 = underflow,
        # len(edges) = overflow
        self._counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        b = self._bucket(value)
        with self._lock:
            self._counts[b] += 1
            self.count += 1
            self.sum += value
            self.min = min(self.min, value)
            self.max = max(self.max, value)

    def _bucket(self, value: float) -> int:
        # binary search over the fixed edges
        lo, hi = 0, len(self.edges)
        while lo < hi:
            mid = (lo + hi) // 2
            if value < self.edges[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def _bounds(self, b: int) -> tuple[float, float]:
        lo = self.edges[b - 1] if b > 0 else self.min
        hi = self.edges[b] if b < len(self.edges) else self.max
        return max(lo, self.min), min(max(hi, self.min), self.max)

    def percentile(self, q: float) -> float | None:
        """Estimate the ``q``-th percentile (0–100): pick the bucket
        holding the target rank, interpolate linearly inside it; the
        exact order statistic lies in the same bucket, so the error is
        bounded by that bucket's width."""
        if self.count == 0:
            return None
        target = max(q / 100.0 * self.count, 1e-12)
        cum = 0
        for b, c in enumerate(self._counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo, hi = self._bounds(b)
                frac = (target - cum) / c
                return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))
            cum += c
        return float(self.max)      # pragma: no cover — rounding guard

    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def snapshot(self) -> dict:
        out = dict(count=self.count, sum=self.sum)
        if self.count:
            out.update(min=self.min, max=self.max,
                       p50=self.percentile(50), p95=self.percentile(95),
                       p99=self.percentile(99))
        return out


class MetricsRegistry:
    """Named instruments, created on first use, rendered by snapshot().

    Names are dotted paths (``"stream.phase_seconds.assemble"``).
    Re-requesting a name returns the same instrument; requesting it as
    a different type raises.
    """

    def __init__(self) -> None:
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str,
                  edges: tuple[float, ...] | None = None) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, edges))

    def snapshot(self) -> dict:
        """Flat ``{name: value-or-dict}`` of every instrument."""
        with self._lock:
            items = sorted(self._instruments.items())
        return {name: inst.snapshot() for name, inst in items}

    def reset(self) -> None:
        """Drop every instrument (tests; the registry is process-wide)."""
        with self._lock:
            self._instruments.clear()


#: The process-wide registry every subsystem publishes into.
REGISTRY = MetricsRegistry()
metrics = REGISTRY
