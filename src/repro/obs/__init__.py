"""Unified telemetry: structured tracing, metrics, timeline export.

``repro.obs`` is the one low-overhead observability subsystem every
execution layer publishes into, replacing the ad-hoc
``time.perf_counter()`` deltas and disconnected stats dicts that used
to live in each module:

* **Spans** (:mod:`repro.obs.tracer`) — ``with obs.span("assemble",
  wave=k): ...`` records a named interval into a thread-safe ring
  buffer shared by the streaming executor's background staging worker
  and the main loop.  Off by default; ``REPRO_TRACE=1`` (or
  :func:`enable`) turns it on, and when off every instrumentation
  point is a single ``None``-check no-op, so traced and untraced runs
  are bit-identical and equally fast.
* **Metrics** (:mod:`repro.obs.metrics`) — the always-on process-wide
  registry (:data:`metrics`) of counters, gauges, and fixed-bucket
  histograms: phase seconds, staged/arena bytes, budget high water,
  compile/trace counts, admission decisions, batch occupancy, query
  latency.  ``obs.metrics.snapshot()`` renders it as one flat dict.
* **Exporters** (:mod:`repro.obs.export`) — Chrome-trace/Perfetto JSON
  timelines (one lane per mesh device plus the staging thread;
  per-wave ``assemble → device_put → compute → collective`` spans) and
  the schema-versioned run-report that ``BENCH_stream.json``,
  ``BENCH_serve.json``, and ``BENCH_obs.json`` share.

Quickstart::

    from repro import obs

    obs.enable()                          # or REPRO_TRACE=1 in the env
    plan.run()                            # spans record as it executes
    obs.export.write_chrome_trace("run.perfetto.json")
    obs.metrics.snapshot()                # {"stream.phase_seconds...": ...}

See ``docs/observability.md`` for the metric catalog and how to read
the exported timeline in ``ui.perfetto.dev``.
"""
from . import export
from .metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
    exp_bucket_edges, latency_bucket_edges, metrics,
)
from .tracer import (
    SpanEvent, Tracer, add_span, disable, enable, enabled, instant, span,
    tracer, tracing,
)

__all__ = [
    "span", "add_span", "instant", "enable", "disable", "enabled",
    "tracer", "tracing", "Tracer", "SpanEvent",
    "metrics", "REGISTRY", "MetricsRegistry",
    "Counter", "Gauge", "Histogram",
    "exp_bucket_edges", "latency_bucket_edges",
    "export",
]
