"""Span-based tracer behind a thread-safe ring buffer.

One process-wide :class:`Tracer` records *spans* — named, timestamped
intervals with free-form attributes — from any thread into a single
bounded ring buffer, so the streaming executor's background staging
worker (:class:`repro.core.stream._StagePipeline`) and the main loop
share one timeline.  Every span carries a *lane*: the logical track the
exporters render it on (``"main"``, ``"staging"``, or ``"device"`` —
the latter expanded to one lane per mesh device by the Chrome-trace
exporter).

Zero-cost when disabled
-----------------------
Tracing is **off** unless the ``REPRO_TRACE`` environment variable is
set truthy at import (or :func:`enable` is called).  When off,
:func:`span` returns a shared no-op context manager and
:func:`add_span`/:func:`instant` return immediately after one ``None``
check — no allocation, no lock, no clock read — so instrumented hot
paths (the per-wave pipeline) pay a single branch.  Results are
therefore bit-identical with tracing on or off: the tracer only ever
*observes* timestamps, never touches computation.

Thread safety and bounds
------------------------
Appends take one lock around a ring-buffer slot write; the buffer holds
the most recent ``capacity`` spans (default 65536) and
:attr:`Tracer.dropped` counts overwritten ones, so a long-running
server can stay traced without unbounded memory.  Per-thread span
*stacks* (plain ``threading.local``) give each span its nesting depth
and parent name, letting the exporters and tests reconstruct the span
tree.

Optional JAX bridge
-------------------
``enable(jax_annotations=True)`` (or ``REPRO_TRACE_JAX=1``) makes every
:func:`span` additionally enter a ``jax.profiler.TraceAnnotation`` of
the same name, so host spans line up with device activity in profiles
captured via ``jax.profiler.trace``.  The bridge degrades to a no-op
when the profiler is unavailable.
"""
from __future__ import annotations

import os
import threading
import time

__all__ = [
    "SpanEvent", "Tracer", "span", "add_span", "instant",
    "enable", "disable", "enabled", "tracer", "tracing",
]

_FALSY = ("", "0", "false", "off", "no")


class SpanEvent:
    """One recorded span: a closed interval on a lane.

    A plain ``__slots__`` class, not a dataclass — span records are
    constructed on the per-wave hot path, and skipping dataclass
    machinery keeps the record cost in the very-low-microsecond range
    (the obs-smoke overhead gate counts on it)."""

    __slots__ = ("name", "start_ns", "dur_ns", "lane", "depth", "parent",
                 "args")

    def __init__(self, name: str, start_ns: int, dur_ns: int, lane: str,
                 depth: int, parent: str | None, args: dict) -> None:
        self.name = name
        self.start_ns = start_ns
        self.dur_ns = dur_ns
        self.lane = lane
        self.depth = depth          # nesting depth on the recording thread
        self.parent = parent        # enclosing span's name (same thread)
        self.args = args

    @property
    def end_ns(self) -> int:
        return self.start_ns + self.dur_ns

    def __repr__(self) -> str:
        return (f"SpanEvent(name={self.name!r}, start_ns={self.start_ns}, "
                f"dur_ns={self.dur_ns}, lane={self.lane!r}, "
                f"depth={self.depth}, parent={self.parent!r}, "
                f"args={self.args!r})")


def _thread_lane() -> str:
    name = threading.current_thread().name
    if name == "MainThread":
        return "main"
    return name


class Tracer:
    """Thread-safe ring buffer of :class:`SpanEvent`\\ s."""

    def __init__(self, capacity: int = 65536, *,
                 jax_annotations: bool = False) -> None:
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = int(capacity)
        self.jax_annotations = bool(jax_annotations)
        self._buf: list[SpanEvent | None] = [None] * self.capacity
        self._n = 0                # total spans ever recorded
        self._lock = threading.Lock()
        self._tls = threading.local()

    # -- recording -----------------------------------------------------
    def record(self, name: str, start_ns: int, dur_ns: int, *,
               lane: str | None = None, depth: int = 0,
               parent: str | None = None, **args) -> None:
        ev = SpanEvent(name, int(start_ns), max(int(dur_ns), 0),
                       lane if lane is not None else _thread_lane(),
                       int(depth), parent, args)
        with self._lock:
            self._buf[self._n % self.capacity] = ev
            self._n += 1

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    # -- reading -------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Spans overwritten because the ring buffer wrapped."""
        return max(0, self._n - self.capacity)

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def events(self) -> list[SpanEvent]:
        """The retained spans, oldest first (recording order)."""
        with self._lock:
            n = self._n
            if n <= self.capacity:
                out = self._buf[:n]
            else:
                cut = n % self.capacity
                out = self._buf[cut:] + self._buf[:cut]
        return list(out)            # type: ignore[arg-type]

    def spans(self, name: str | None = None, **args) -> list[SpanEvent]:
        """Retained spans filtered by name and/or attribute equality."""
        out = []
        for ev in self.events():
            if name is not None and ev.name != name:
                continue
            if any(ev.args.get(k) != v for k, v in args.items()):
                continue
            out.append(ev)
        return out

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0


class _Span:
    """The live context manager behind :func:`span`."""

    __slots__ = ("_tracer", "_name", "_lane", "_args", "_start",
                 "_depth", "_parent", "_jax")

    def __init__(self, tracer: Tracer, name: str, lane: str | None,
                 args: dict) -> None:
        self._tracer = tracer
        self._name = name
        self._lane = lane
        self._args = args
        self._jax = None

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self._depth = len(stack)
        self._parent = stack[-1] if stack else None
        stack.append(self._name)
        if self._tracer.jax_annotations:
            self._jax = _jax_annotation(self._name)
            if self._jax is not None:
                self._jax.__enter__()
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        end = time.perf_counter_ns()
        if self._jax is not None:
            self._jax.__exit__(*exc)
        stack = self._tracer._stack()
        if stack and stack[-1] == self._name:
            stack.pop()
        self._tracer.record(self._name, self._start, end - self._start,
                            lane=self._lane, depth=self._depth,
                            parent=self._parent, **self._args)
        return False


def _jax_annotation(name: str):
    try:
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)
    except Exception:       # pragma: no cover — profiler unavailable
        return None


class _NoopSpan:
    """Shared do-nothing context manager — the disabled-tracer path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()
_tracer: Tracer | None = None


def enabled() -> bool:
    """Is tracing on?  (Metrics are always on; only spans gate.)"""
    return _tracer is not None


def tracer() -> Tracer | None:
    """The active process-wide tracer, or None when disabled."""
    return _tracer


def enable(capacity: int = 65536, *,
           jax_annotations: bool | None = None) -> Tracer:
    """Turn tracing on (idempotent); returns the active tracer.

    ``jax_annotations=None`` reads ``REPRO_TRACE_JAX`` from the
    environment; an existing tracer keeps recording (capacity and
    bridge settings apply only when a new tracer is created).
    """
    global _tracer
    if _tracer is None:
        if jax_annotations is None:
            jax_annotations = (
                os.environ.get("REPRO_TRACE_JAX", "").lower()
                not in _FALSY
            )
        _tracer = Tracer(capacity, jax_annotations=jax_annotations)
    return _tracer


def disable() -> None:
    """Turn tracing off; already-recorded spans are discarded."""
    global _tracer
    _tracer = None


class tracing:
    """``with obs.tracing() as tr: ...`` — scoped enable/restore."""

    def __init__(self, capacity: int = 65536, *,
                 jax_annotations: bool | None = None) -> None:
        self._capacity = capacity
        self._jax = jax_annotations

    def __enter__(self) -> Tracer:
        global _tracer
        self._prev = _tracer
        _tracer = None
        return enable(self._capacity, jax_annotations=self._jax)

    def __exit__(self, *exc) -> bool:
        global _tracer
        _tracer = self._prev
        return False


def span(name: str, *, lane: str | None = None, **args):
    """``with obs.span("assemble", wave=k): ...`` — record one span.

    A no-op (shared singleton, no allocation) while tracing is
    disabled.  ``lane`` overrides the thread-derived track; extra
    keyword arguments become span attributes.
    """
    t = _tracer
    if t is None:
        return _NOOP
    return _Span(t, name, lane, args)


def add_span(name: str, duration_s: float, *, lane: str | None = None,
             **args) -> None:
    """Record a synthetic span of ``duration_s`` ending now — used for
    costs measured indirectly (the mesh collective's isolated-all-reduce
    estimate) that still belong on the timeline."""
    t = _tracer
    if t is None:
        return
    end = time.perf_counter_ns()
    dur = int(duration_s * 1e9)
    t.record(name, end - dur, dur, lane=lane, **args)


def instant(name: str, *, lane: str | None = None, **args) -> None:
    """Record a zero-duration marker (e.g. ``rebalance fired``)."""
    t = _tracer
    if t is None:
        return
    t.record(name, time.perf_counter_ns(), 0, lane=lane, **args)


# honor REPRO_TRACE at import so `REPRO_TRACE=1 python app.py` traces
# without code changes.  REPRO_TRACE / REPRO_TRACE_JAX are declared in
# repro.core.knobs.KNOWN but read locally: obs must stay importable
# without repro.core (which pulls in jax), and truthy-string semantics
# cannot be malformed
if os.environ.get("REPRO_TRACE", "").lower() not in _FALSY:
    enable()
