"""Exporters: Chrome-trace/Perfetto timelines and the unified run-report.

Two artifact formats come out of the telemetry layer:

* :func:`chrome_trace` renders the tracer's span buffer as Chrome
  trace-event JSON (the format ``ui.perfetto.dev`` and
  ``chrome://tracing`` load): one process, one track ("lane") per
  logical resource — ``main``, the ``staging`` background thread, and
  ``device/0 … device/D-1`` for the mesh (a span recorded on the
  ``"device"`` lane with a ``devices=D`` attribute is mirrored onto
  every device's track, since a mesh step occupies all of them).
  Spans become complete (``ph="X"``) events with microsecond
  timestamps; lanes are labeled via metadata events.

* :func:`run_report` wraps a benchmark's payload in the one
  schema-versioned report format (``repro.obs.run_report`` v1) that
  ``BENCH_stream.json``, ``BENCH_serve.json``, and ``BENCH_obs.json``
  all share: the benchmark's own gate fields stay at the top level
  (byte-compatible with pre-schema consumers), plus ``schema``/
  ``schema_version``/``report`` headers and a ``metrics`` block
  snapshotting the process-wide registry.

:func:`validate_chrome_trace` is the loadability check the obs-smoke CI
gate (and the tests) run against an exported file: structure,
non-negative durations, per-lane monotonic timestamps, required lanes
and phase names.
"""
from __future__ import annotations

import json
from typing import Any

from .metrics import REGISTRY
from .tracer import SpanEvent, tracer

__all__ = [
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "run_report", "RUN_REPORT_SCHEMA", "RUN_REPORT_VERSION",
]

RUN_REPORT_SCHEMA = "repro.obs.run_report"
RUN_REPORT_VERSION = 1

_PID = 1


def _expand_lanes(ev: SpanEvent) -> list[str]:
    """A ``"device"``-lane span with ``devices=D`` occupies every mesh
    device's track; everything else stays on its recorded lane."""
    if ev.lane == "device":
        d = int(ev.args.get("devices", 1) or 1)
        return [f"device/{i}" for i in range(max(d, 1))]
    return [ev.lane]


def _lane_tids(events: list[SpanEvent]) -> dict[str, int]:
    """Deterministic lane → tid: main, staging, device/*, then the rest
    alphabetically — stable across runs for diffable traces."""
    lanes: set[str] = set()
    for ev in events:
        lanes.update(_expand_lanes(ev))

    def rank(lane: str):
        if lane == "main":
            return (0, 0, lane)
        if lane == "staging":
            return (1, 0, lane)
        if lane.startswith("device/"):
            try:
                return (2, int(lane.split("/", 1)[1]), lane)
            except ValueError:
                return (2, 1 << 30, lane)
        return (3, 0, lane)

    return {lane: i + 1 for i, lane in enumerate(sorted(lanes, key=rank))}


def chrome_trace(events: list[SpanEvent] | None = None) -> dict:
    """Render spans (default: the active tracer's buffer) as a Chrome
    trace-event JSON object.  Raises when tracing is disabled and no
    events are passed."""
    if events is None:
        t = tracer()
        if t is None:
            raise RuntimeError(
                "tracing is disabled (set REPRO_TRACE=1 or call "
                "repro.obs.enable()) and no events were passed")
        events = t.events()
    tids = _lane_tids(events)
    trace_events: list[dict] = [
        dict(ph="M", pid=_PID, tid=0, name="process_name",
             args=dict(name="repro")),
    ]
    for lane, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        trace_events.append(dict(ph="M", pid=_PID, tid=tid,
                                 name="thread_name", args=dict(name=lane)))
        trace_events.append(dict(ph="M", pid=_PID, tid=tid,
                                 name="thread_sort_index",
                                 args=dict(sort_index=tid)))
    spans = []
    for ev in events:
        args = {k: v for k, v in ev.args.items()
                if isinstance(v, (int, float, str, bool, type(None)))}
        if ev.parent is not None:
            args["parent"] = ev.parent
        for lane in _expand_lanes(ev):
            spans.append(dict(
                ph="X", pid=_PID, tid=tids[lane], name=ev.name,
                cat=ev.name.split(".", 1)[0],
                ts=ev.start_ns / 1e3, dur=ev.dur_ns / 1e3,
                args=args,
            ))
    spans.sort(key=lambda e: (e["ts"], e["tid"]))
    trace_events.extend(spans)
    return dict(traceEvents=trace_events, displayTimeUnit="ms")


def write_chrome_trace(path: str,
                       events: list[SpanEvent] | None = None) -> dict:
    """Serialize :func:`chrome_trace` to ``path``; returns the object."""
    obj = chrome_trace(events)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj: dict, *, require_lanes=(),
                          require_phases=()) -> dict:
    """Structural validation of a Chrome-trace object (or JSON string).

    Checks: top-level shape, every span event well-formed (``ph="X"``,
    numeric non-negative ``ts``/``dur``), start timestamps monotonic
    non-decreasing in file order (the exporter writes spans sorted by
    start — a violation means a broken export or clock), and that every
    lane in ``require_lanes`` and span name in ``require_phases``
    appears.  Returns summary stats (lanes, span counts per name);
    raises ``ValueError`` on any violation.
    """
    if isinstance(obj, (str, bytes)):
        obj = json.loads(obj)
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing traceEvents")
    lanes: dict[int, str] = {}
    names: dict[str, int] = {}
    prev_ts = float("-inf")
    for ev in obj["traceEvents"]:
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                lanes[ev["tid"]] = ev["args"]["name"]
            continue
        if ph != "X":
            raise ValueError(f"unexpected event phase {ph!r}")
        ts, dur = ev.get("ts"), ev.get("dur")
        if not (isinstance(ts, (int, float)) and ts >= 0):
            raise ValueError(f"bad ts on {ev.get('name')!r}: {ts!r}")
        if not (isinstance(dur, (int, float)) and dur >= 0):
            raise ValueError(f"bad dur on {ev.get('name')!r}: {dur!r}")
        if ts < prev_ts - 1e-9:
            raise ValueError(
                f"non-monotonic timestamps: {ev['name']} starts at {ts} "
                f"after an event at {prev_ts}")
        prev_ts = ts
        names[ev["name"]] = names.get(ev["name"], 0) + 1
    lane_names = set(lanes.values())
    for lane in require_lanes:
        if lane not in lane_names:
            raise ValueError(f"required lane {lane!r} missing "
                             f"(got {sorted(lane_names)})")
    for phase in require_phases:
        if phase not in names:
            raise ValueError(f"required phase {phase!r} missing "
                             f"(got {sorted(names)})")
    return dict(lanes=sorted(lane_names), span_counts=names,
                events=sum(names.values()))


def run_report(report: str, payload: dict, *,
               include_metrics: bool = True) -> dict:
    """Wrap a benchmark payload in the unified run-report schema.

    The payload's keys (gate fields like ``checks``/``passed``/floors)
    stay at the top level so existing consumers of
    ``BENCH_stream.json``/``BENCH_serve.json`` keep working; the
    schema headers and the registry snapshot ride alongside.  Reserved
    header keys may not collide with payload keys.
    """
    header = dict(schema=RUN_REPORT_SCHEMA,
                  schema_version=RUN_REPORT_VERSION, report=report)
    clash = set(header) & set(payload)
    if clash:
        raise ValueError(f"payload keys collide with the run-report "
                         f"header: {sorted(clash)}")
    out: dict[str, Any] = dict(header)
    out.update(payload)
    if include_metrics and "metrics" not in out:
        out["metrics"] = REGISTRY.snapshot()
    return out
