"""Training loop substrate."""
from .loop import TrainLoop, TrainConfig

__all__ = ["TrainLoop", "TrainConfig"]
