"""Fault-tolerant training loop.

Composes the substrates: data pipeline (pure function of step — replay-
safe), jitted train step (loss→grad→AdamW, optional microbatch
accumulation), checkpoint manager (atomic, auto-resume), mesh sharding
(params FSDP+TP, batch DP), and simple throughput/metric logging.

Fault tolerance: the loop is restartable at any step boundary —
``run()`` always begins with ``restore_or_init``; killing the process
at any point loses at most ``ckpt_every`` steps (covered by tests that
kill and resume mid-run).  Straggler posture: per-step work is
identical across workers (static schedule), so a slow host shifts only
the collective phase; elastic posture: restore re-shards onto the
current mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.base import ArchConfig
from ..data import TokenPipeline
from ..models import lm
from ..models.steps import make_train_step
from ..optim import adamw_init

__all__ = ["TrainLoop", "TrainConfig"]


@dataclass
class TrainConfig:
    steps: int = 200
    batch: int = 8
    seq: int = 128
    base_lr: float = 3e-4
    warmup_steps: int = 20
    microbatch: int = 0
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    use_pallas: bool = False


class TrainLoop:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig, mesh=None):
        self.cfg = cfg
        self.tc = tc
        self.mesh = mesh
        self.pipeline = TokenPipeline(tc.seed, tc.batch, tc.seq, cfg.vocab)
        self.ckpt = CheckpointManager(tc.ckpt_dir, every=tc.ckpt_every)
        self._step_fn = jax.jit(
            make_train_step(
                cfg, base_lr=tc.base_lr, total_steps=tc.steps,
                warmup_steps=tc.warmup_steps, microbatch=tc.microbatch,
                use_pallas=tc.use_pallas,
            ),
            donate_argnums=(0, 1),
        )

    def _init_state(self):
        params = lm.init_params(self.cfg, jax.random.key(self.tc.seed))
        return dict(params=params, opt=adamw_init(params))

    def run(self, *, on_step=None) -> dict:
        state, start = self.ckpt.restore_or_init(self._init_state)
        params, opt = state["params"], state["opt"]
        history = []
        t0 = time.perf_counter()
        tokens_done = 0
        for step in range(start, self.tc.steps):
            batch = jax.tree.map(jnp.asarray, self.pipeline(step))
            params, opt, metrics = self._step_fn(
                params, opt, batch, jnp.int32(step)
            )
            tokens_done += self.tc.batch * self.tc.seq
            if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                m.update(step=step, tokens_per_s=tokens_done / max(dt, 1e-9))
                history.append(m)
                if on_step:
                    on_step(m)
            self.ckpt.maybe_save(step, dict(params=params, opt=opt))
        # always leave a final checkpoint at the last step
        from ..checkpoint import save_checkpoint

        save_checkpoint(self.tc.ckpt_dir, self.tc.steps - 1,
                        dict(params=params, opt=opt))
        return dict(params=params, opt=opt, history=history)
