"""Fault-tolerant checkpointing: atomic writes, integrity-checked latest
pointer, auto-resume, elastic re-sharding."""
from .ckpt import save_checkpoint, restore_checkpoint, latest_step, CheckpointManager

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]
