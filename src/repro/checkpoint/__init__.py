"""Fault-tolerant checkpointing: atomic writes, integrity-checked latest
pointer, auto-resume, elastic re-sharding, and run-level snapshots
(:mod:`repro.checkpoint.runstate`) that make ``Plan.resume`` /
``StreamingPlan.resume`` bit-identical for integer/bool attributes."""
from .ckpt import save_checkpoint, restore_checkpoint, latest_step, CheckpointManager
from .runstate import (
    RunSnapshot, save_runstate, load_runstate, latest_runstate_step,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager", "RunSnapshot", "save_runstate",
           "load_runstate", "latest_runstate_step"]
