"""Per-iteration run snapshots for ``Plan``/``StreamingPlan`` resume.

A *run state* is everything needed to continue an iteration loop
bit-identically for integer/boolean attributes: the state pytree at an
iteration boundary, the absolute iteration counter, the loop-continue
flag the algorithm's ``after`` hook last returned, and — when the run
uses direction optimization — the :class:`DirectionController`'s latch
state and decision history (its hysteresis depends on both).  Nothing
else is RNG- or time-dependent, so the snapshot is closed under
replay: ``resume()`` from any boundary produces the same final
integers as the uninterrupted run.

The payload rides the :mod:`repro.checkpoint.ckpt` substrate (atomic
``os.replace`` writes, sha256-verified ``LATEST`` pointer), stored as
one pytree ``{"state": ..., "meta": ...}``.  Every meta field is an
array leaf with a FIXED dtype so the restore template never depends on
what was saved: variable-length history fields use zero-length arrays
as templates (restore only needs tree structure and dtypes, not
shapes).  Direction decisions are coded ``push=0 / pull=1``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from .ckpt import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["RunSnapshot", "save_runstate", "load_runstate",
           "latest_runstate_step"]

_DIR_CODES = {"push": 0, "pull": 1}
_DIR_NAMES = {v: k for k, v in _DIR_CODES.items()}


@dataclass
class RunSnapshot:
    """One restorable iteration boundary."""

    state: Any             # the state pytree at the boundary
    it: int                # iterations completed (the next one to run)
    cont: bool             # the loop-continue flag after iteration it-1
    ctrl: dict | None      # direction-controller restore dict, or None
    step: int              # checkpoint step the snapshot came from


def _meta(it: int, cont: bool, ctrl) -> dict:
    """Always-emit every field with its fixed dtype — the restore
    template is then independent of which run wrote the snapshot."""
    has_ctrl = ctrl is not None
    decisions = list(ctrl.decisions) if has_ctrl else []
    densities = list(ctrl.densities) if has_ctrl else []
    return dict(
        it=np.int64(it),
        cont=np.bool_(cont),
        has_ctrl=np.bool_(has_ctrl),
        dir_current=np.int8(
            _DIR_CODES[ctrl.current] if has_ctrl else 0),
        dir_switches=np.int64(ctrl.switches if has_ctrl else 0),
        dir_decisions=np.asarray(
            [_DIR_CODES[d] for d in decisions], np.int8),
        dir_densities=np.asarray(densities, np.float64),
    )


def _meta_template() -> dict:
    """Dtype-bearing template; zero-length arrays stand in for the
    variable-length history fields (restore is shape-free)."""
    return _meta(0, True, None)


def save_runstate(ckpt_dir: str, state, *, it: int, cont: bool,
                  ctrl=None, step: int | None = None) -> str:
    """Atomically persist one iteration boundary; returns the path.

    ``ctrl`` is a live :class:`~repro.core.direction.DirectionController`
    (or ``None`` for runs without direction optimization); only its
    replay-relevant fields are stored.  ``step`` defaults to ``it`` —
    one snapshot per boundary, later saves at the same boundary
    overwrite."""
    payload = {"state": dict(state), "meta": _meta(it, cont, ctrl)}
    return save_checkpoint(ckpt_dir, it if step is None else step, payload)


def load_runstate(ckpt_dir: str, state_template,
                  step: int | None = None) -> RunSnapshot:
    """Restore the latest (or ``step``'s) snapshot into
    ``state_template``'s structure and dtypes.

    ``state_template`` is what ``alg.init_state(store)`` returns — the
    restore casts every stored leaf back to the template dtype, so
    integer/boolean attributes round-trip exactly."""
    template = {"state": dict(state_template), "meta": _meta_template()}
    payload, got = restore_checkpoint(ckpt_dir, template, step=step)
    meta = payload["meta"]
    ctrl = None
    if bool(meta["has_ctrl"]):
        ctrl = dict(
            current=_DIR_NAMES[int(meta["dir_current"])],
            switches=int(meta["dir_switches"]),
            decisions=[_DIR_NAMES[int(d)]
                       for d in np.asarray(meta["dir_decisions"])],
            densities=[float(x)
                       for x in np.asarray(meta["dir_densities"])],
        )
    return RunSnapshot(state=payload["state"], it=int(meta["it"]),
                       cont=bool(meta["cont"]), ctrl=ctrl, step=int(got))


def latest_runstate_step(ckpt_dir: str) -> int | None:
    """Newest restorable boundary (the verified ``LATEST`` pointer)."""
    return latest_step(ckpt_dir)
