"""Checkpoint substrate.

Design goals (1000-node posture, DESIGN §7):

* **Atomic**: write to ``step_K.tmp`` then ``os.replace`` — a crash
  mid-write never corrupts the latest good checkpoint.
* **Integrity-checked latest pointer**: ``LATEST`` names the newest step
  and carries a sha256 of the payload; restore verifies it and falls
  back to the previous checkpoint on mismatch (torn-write recovery).
* **Elastic**: arrays are stored *unsharded-logical* (host numpy); on
  restore they are ``device_put`` against whatever sharding the current
  mesh dictates — the job can come back on a different device count.
* **Auto-resume**: ``CheckpointManager.restore_or_init`` is the single
  entry point the train loop calls; it returns (state, start_step).

Serialization: one ``npz`` per checkpoint with flattened pytree paths
(msgpack for the treedef/metadata).  No framework deps.
"""
from __future__ import annotations

import hashlib
import json
import os
import re

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]

_SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        a = np.asarray(jax.device_get(leaf))
        if a.dtype.kind not in "biufc":  # ml_dtypes (bf16/fp8) → kind 'V'
            a = a.astype(np.float32)  # restore casts back to template dtype
        out[key] = a
    return out


def _unflatten_into(template, arrays: dict):
    def rebuild(path, leaf):
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        a = arrays[key]
        if hasattr(leaf, "dtype") and a.dtype != leaf.dtype:
            a = a.astype(leaf.dtype)
        return a

    return jax.tree_util.tree_map_with_path(rebuild, template)


def _payload_hash(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, state) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = final + ".tmp.npz"
    np.savez(tmp.removesuffix(".npz"), **arrays)
    os.replace(tmp, final)
    meta = dict(step=step, file=os.path.basename(final),
                sha256=_payload_hash(final))
    tmp_meta = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp_meta, "w") as f:
        json.dump(meta, f)
    os.replace(tmp_meta, os.path.join(ckpt_dir, "LATEST"))
    return final


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for fn in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.npz", fn)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest restorable step, preferring the verified LATEST pointer."""
    pointer = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(pointer):
        try:
            meta = json.load(open(pointer))
            path = os.path.join(ckpt_dir, meta["file"])
            if os.path.exists(path) and _payload_hash(path) == meta["sha256"]:
                return int(meta["step"])
        except (json.JSONDecodeError, KeyError, OSError):
            pass  # torn pointer — fall back to directory scan
    steps = _list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None,
                       shardings=None):
    """Restore into ``template``'s pytree structure; optionally re-shard.

    ``shardings``: matching pytree of NamedSharding (or None) — arrays are
    device_put against it, which is what makes restore *elastic*.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    z = np.load(os.path.join(ckpt_dir, f"step_{step:08d}.npz"))
    arrays = {k: z[k] for k in z.files}
    state = _unflatten_into(template, arrays)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else a,
            state, shardings,
        )
    return state, step


class CheckpointManager:
    def __init__(self, ckpt_dir: str, *, keep: int = 3, every: int = 50):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, state) -> str | None:
        if step % self.every != 0:
            return None
        path = save_checkpoint(self.dir, step, state)
        self._gc()
        return path

    def _gc(self):
        steps = _list_steps(self.dir)
        for s in steps[: -self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.npz"))
            except OSError:
                pass

    def restore_or_init(self, init_fn, shardings=None):
        """Auto-resume: restore the newest verified checkpoint or init fresh."""
        step = latest_step(self.dir)
        if step is None:
            return init_fn(), 0
        template = jax.eval_shape(init_fn)
        state, step = restore_checkpoint(self.dir, template, step, shardings)
        return state, step + 1
