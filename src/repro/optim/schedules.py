"""LR schedules as step → lr functions (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup", "cosine_schedule"]


def linear_warmup(base_lr: float, warmup_steps: int):
    def fn(step):
        return base_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    return fn


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return fn
