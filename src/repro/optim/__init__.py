"""Hand-rolled optimizers (no optax): AdamW, momentum SGD, schedules,
and int8 gradient compression with error feedback."""
from .adamw import adamw_init, adamw_update, sgdm_init, sgdm_update
from .schedules import cosine_schedule, linear_warmup
from .compress import (
    compress_int8, decompress_int8, compressed_psum, error_feedback_init,
)

__all__ = [
    "adamw_init", "adamw_update", "sgdm_init", "sgdm_update",
    "cosine_schedule", "linear_warmup",
    "compress_int8", "decompress_int8", "compressed_psum",
    "error_feedback_init",
]
