"""int8 gradient compression with error feedback (DP all-reduce shrink).

Per-tensor symmetric quantization: g ≈ scale · q, q ∈ int8.  The
quantization error is fed back into the next step's gradient (error
feedback keeps SGD convergence).  ``compressed_psum`` is the drop-in
collective for a shard_map data-parallel loop: quantize → psum int32 →
dequantize; the wire format is 8 bits + one f32 scale per tensor, a 4×
reduction vs f32 (2× vs bf16) on the DP all-reduce bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "compressed_psum",
           "error_feedback_init"]


def compress_int8(g):
    """g: f32/bf16 array → (int8 q, f32 scale)."""
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residuals, axis_name: str):
    """Quantize grads (+residual), psum the int8 payload, return
    (dequantized mean grads, new residuals)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = compress_int8(g)
        approx = decompress_int8(q, scale)
        new_r = g - approx       # error feedback: what quantization lost
        # Each shard contributes its *quantized* payload (int8 + scale on
        # the wire); the reduction itself sums the dequantized values —
        # i.e. exactly what an int8 all-reduce with per-shard scales
        # produces.  Per-shard scales make an integer-domain psum inexact,
        # so the sum happens in f32 after dequantization.
        deq = jax.lax.psum(approx, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return deq / n, new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_r = jax.tree.unflatten(tree, [o[1] for o in outs])
    return new_g, new_r
