"""AdamW and momentum-SGD, pure pytree functions.

Moments are f32 regardless of param dtype (mixed-precision practice:
bf16 params + f32 optimizer state).  State shapes mirror params, so the
FSDP/TP param sharding rules apply verbatim to the state tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "sgdm_init", "sgdm_update"]


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=1.0):
    count = state["count"] + 1
    # global-norm clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** count)
        nu_hat = nu / (1 - b2 ** count)
        step = mu_hat / (jnp.sqrt(nu_hat) + eps)
        new_p = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, dict(mu=new_mu, nu=new_nu, count=count), gnorm


def sgdm_init(params):
    return dict(
        mom=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        count=jnp.zeros((), jnp.int32),
    )


def sgdm_update(params, grads, state, *, lr, momentum=0.9, weight_decay=0.0):
    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + g
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    out = jax.tree.map(upd, params, grads, state["mom"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mom = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, dict(mom=new_mom, count=state["count"] + 1)
