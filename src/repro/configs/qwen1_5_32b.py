"""qwen1.5-32b — dense MHA-ish (kv=40) w/ QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0, mlp_type="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B family scaled per assignment",
)

SMOKE = replace(
    CONFIG, name="qwen1.5-32b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
)
