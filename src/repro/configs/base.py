"""Architecture configuration schema + registry.

One module per assigned architecture lives next to this file; each
exposes ``CONFIG`` (the exact published configuration) and ``SMOKE``
(a reduced same-family configuration for CPU smoke tests).  The
registry maps public ids (``--arch qwen2.5-32b``) to both.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "register", "get_config", "get_smoke", "list_archs",
           "SHAPES", "shape_for"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    qkv_bias: bool = False
    head_dim: int | None = None          # default d_model // n_heads
    rope_theta: float = 1_000_000.0
    attn_window: int = 0                 # sliding-window size; 0 = full attn
    mlp_type: str = "swiglu"             # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # per-(routed/shared)-expert hidden
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    slstm_every: int = 0                 # xLSTM: every k-th block is sLSTM
    # VLM
    cross_attn_every: int = 0            # every k-th layer gets cross-attn
    vision_tokens: int = 0
    # audio (enc-dec)
    encoder_layers: int = 0
    encoder_frames: int = 0
    # numerics / training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    attn_impl: str = "full"              # full | chunked (online-softmax)
    loss_chunk: int = 512                # sequence chunk for the CE loss
    moe_dispatch_sharding: str = "auto"  # auto | ep (explicit (tp,dp) buffer)
    mamba_impl: str = "scan"             # scan | assoc (associative scan)
    remat_policy: str = "full"           # full | save_attn (selective recompute)
    attn_probs_dtype: str = ""           # "" | bfloat16 (score-chain dtype)
    mlstm_impl: str = "scan"             # scan | chunked (chunkwise parallel)
    mlstm_chunk: int = 64
    source: str = ""                     # provenance note

    @property
    def d_head(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, dh = self.d_model, self.d_head
        qkv = d * dh * (self.n_heads + 2 * self.n_kv_heads) + dh * self.n_heads * d
        if self.qkv_bias:
            qkv += dh * (self.n_heads + 2 * self.n_kv_heads)
        if self.family == "ssm":
            per_layer = 8 * d * d  # mLSTM q,k,v,o + gates approx
        else:
            if self.mlp_type == "swiglu":
                ffn = 3 * d * self.d_ff
            else:
                ffn = 2 * d * self.d_ff
            if self.n_experts:
                ffn = (
                    3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
                    + d * self.n_experts
                )
            per_layer = qkv + ffn + 2 * d
            if self.family == "hybrid":
                per_layer += 6 * d * d // 2  # mamba branch approx
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            per_layer += 2 * d * d + dh * 0  # decoder cross-attn kv+o approx
        return self.n_layers * per_layer + emb + enc

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        expert_p = 3 * self.d_model * self.moe_d_ff
        inactive = (self.n_experts - self.top_k) * expert_p * self.n_layers
        return full - inactive


# ---------------------------------------------------------------- shapes
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSpec:
    return SHAPES[name]


# -------------------------------------------------------------- registry
_REGISTRY: dict[str, str] = {
    "qwen2.5-32b": "qwen2_5_32b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen1.5-32b": "qwen1_5_32b",
    "granite-3-8b": "granite_3_8b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-base": "whisper_base",
}


def register(arch_id: str, module: str) -> None:
    _REGISTRY[arch_id] = module


def _load(arch_id: str):
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _load(arch_id).CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    return _load(arch_id).SMOKE


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
