"""qwen3-moe-235b-a22b — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, qkv_bias=False, head_dim=128,
    rope_theta=1_000_000.0, mlp_type="swiglu",
    n_experts=128, top_k=8, moe_d_ff=1536,
    source="hf:Qwen/Qwen3-30B-A3B family scaled per assignment",
)

SMOKE = replace(
    CONFIG, name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, moe_d_ff=64, vocab=256, n_experts=8, top_k=2,
)
