"""granite-3-8b — dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, qkv_bias=False,
    rope_theta=10_000_000.0, mlp_type="swiglu",
    source="hf:ibm-granite/granite-3.0-2b-base family",
)

SMOKE = replace(
    CONFIG, name="granite-3-8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
)
