"""hymba-1.5b — hybrid: parallel attention + Mamba heads per layer
[arXiv:2411.13676; hf].  Attention is sliding-window (long_500k-capable);
the SSM branch carries the global context (ssm_state=16)."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, qkv_bias=False,
    rope_theta=10_000.0, mlp_type="swiglu",
    ssm_state=16, attn_window=1024,
    source="arXiv:2411.13676",
)

SMOKE = replace(
    CONFIG, name="hymba-1.5b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    ssm_state=8, attn_window=32,
)
