"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, qkv_bias=False,
    rope_theta=10_000.0, mlp_type="swiglu",
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    source="arXiv:2401.06066",
)

SMOKE = replace(
    CONFIG, name="deepseek-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, moe_d_ff=64, vocab=256, n_experts=8, top_k=2, n_shared_experts=1,
)
