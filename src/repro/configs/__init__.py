"""Assigned-architecture configs (exact published settings) + smoke variants."""
from .base import (
    ArchConfig, get_config, get_smoke, list_archs, register, SHAPES, shape_for,
)

__all__ = ["ArchConfig", "get_config", "get_smoke", "list_archs", "register",
           "SHAPES", "shape_for"]
