"""starcoder2-7b — dense GQA w/ RoPE, GELU MLP [arXiv:2402.19173; hf]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, qkv_bias=True,
    rope_theta=1_000_000.0, mlp_type="gelu",
    source="arXiv:2402.19173",
)

SMOKE = replace(
    CONFIG, name="starcoder2-7b-smoke",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, d_ff=144, vocab=256,
)
