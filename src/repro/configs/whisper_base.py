"""whisper-base — encoder-decoder; conv audio frontend is a STUB
(precomputed frame embeddings via input_specs) [arXiv:2212.04356;
unverified]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, qkv_bias=True,
    mlp_type="gelu",
    encoder_layers=6, encoder_frames=1500,
    source="arXiv:2212.04356",
)

SMOKE = replace(
    CONFIG, name="whisper-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    encoder_layers=2, encoder_frames=32,
)
