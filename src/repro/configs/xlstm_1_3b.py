"""xlstm-1.3b — sLSTM + mLSTM blocks (d_ff=0: recurrent blocks carry the
MLP capacity) [arXiv:2405.04517; unverified]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, qkv_bias=False,
    mlp_type="gelu", slstm_every=8,
    source="arXiv:2405.04517",
)

SMOKE = replace(
    CONFIG, name="xlstm-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, vocab=256, slstm_every=2,
)
