"""llama-3.2-vision-11b — decoder w/ gated cross-attention image layers
every 5th layer; vision frontend is a STUB (precomputed patch embeddings
via input_specs) [hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from dataclasses import replace
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, qkv_bias=False,
    rope_theta=500_000.0, mlp_type="swiglu",
    cross_attn_every=5, vision_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE = replace(
    CONFIG, name="llama-vision-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    cross_attn_every=2, vision_tokens=16,
)
