"""End-to-end training driver.

On real hardware this runs under the production mesh (params FSDP+TP,
batch DP); on this CPU container it drives the same code over a local
1-device mesh.  Fault tolerance comes from the TrainLoop substrate
(atomic checkpoints + auto-resume): re-running the same command after a
crash continues from the newest verified checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
      --smoke --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke
from repro.train import TrainConfig, TrainLoop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq, base_lr=args.lr,
        microbatch=args.microbatch, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, use_pallas=args.use_pallas,
    )
    loop = TrainLoop(cfg, tc)
    out = loop.run(on_step=lambda m: print(json.dumps(m)))
    first, last = out["history"][0], out["history"][-1]
    print(
        f"done: {cfg.name} loss {first['nll']:.3f} -> {last['nll']:.3f} "
        f"({last['tokens_per_s']:.0f} tok/s on {len(jax.devices())} devices)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
