"""Batched serving driver: prefill-free cached decode of N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --batch 4 --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import lm
from repro.models.steps import make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    state = lm.init_decode_state(cfg, args.batch, args.cache_len)
    serve = jax.jit(make_serve_step(cfg))

    batch_extra = {}
    if cfg.family == "vlm":
        batch_extra["vision"] = jnp.zeros(
            (args.batch, cfg.vision_tokens, cfg.d_model), lm.Dtype(cfg.dtype).param
        )
    if cfg.is_encdec:
        batch_extra["memory"] = jnp.zeros(
            (args.batch, cfg.encoder_frames, cfg.d_model), lm.Dtype(cfg.dtype).param
        )

    toks = jnp.zeros((args.batch,), jnp.int32)
    out_tokens = []
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, state = serve(params, state, dict(tokens=toks, **batch_extra))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            toks = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            toks = jnp.argmax(logits, -1)
        toks = toks.astype(jnp.int32)
        out_tokens.append(np.asarray(toks))
    dt = time.perf_counter() - t0
    seq = np.stack(out_tokens, 1)
    print("generated token ids (first row):", seq[0][:16], "...")
    print(
        f"{args.batch} streams × {args.tokens} tokens in {dt:.2f}s "
        f"→ {args.batch * args.tokens / dt:.1f} tok/s"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
