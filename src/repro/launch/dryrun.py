import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the appropriate step function (train_step / prefill_step /
serve_step) is jitted with the production in/out shardings and
``.lower().compile()``-ed against ShapeDtypeStruct inputs — no byte of
the model is ever materialized.  The compiled artifact yields:

* ``memory_analysis()``  — proves the per-device working set fits,
* ``cost_analysis()``    — per-device FLOPs / bytes for §Roofline,
* post-optimization HLO  — the partitioner's actual collective schedule,
  summed into per-kind wire bytes.

Results are printed and (with --out) written as JSON for
benchmarks/roofline consumption.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod --out runs/
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.sharding import (
    EP_ONLY_EXPERT_RULES, MeshCtx, batch_spec, cache_spec,
    named_sharding_tree, param_specs, set_mesh_ctx,
)
from repro.models.steps import (
    abstract_decode_state, abstract_opt_state, abstract_params, input_specs,
    make_prefill_step, make_serve_step, make_train_step, supports_shape,
)
from repro.roofline import collective_bytes_from_hlo, model_flops, roofline_terms
from repro.roofline.hlo_cost import hlo_cost_model

from jax.sharding import NamedSharding, PartitionSpec as P


def _batch_shardings(ctx: MeshCtx, specs):
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, batch_spec(ctx, s.shape)), specs
    )


def _decode_state_shardings(ctx: MeshCtx, state_shapes):
    def one(path, leaf):
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        if leaf.ndim == 0:
            return NamedSharding(ctx.mesh, P())
        if name.endswith("/k") or name.endswith("/v"):
            # KV cache (L, B, T, H, D): seq axis 2
            return NamedSharding(
                ctx.mesh, cache_spec(ctx, leaf.shape, seq_axis=2)
            )
        # recurrent states (L, B, ...): batch over dp when divisible
        return NamedSharding(ctx.mesh, cache_spec(ctx, leaf.shape, seq_axis=None))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false", "True", "False"):
        return k, v.lower() == "true"
    return k, v


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True, overrides: dict | None = None) -> dict:
    from dataclasses import replace as _replace

    cfg = get_config(arch)
    if overrides:
        cfg = _replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape_name,
                    mesh="2x16x16" if multi_pod else "16x16",
                    status="skipped", reason=why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    ctx = set_mesh_ctx(mesh)
    t0 = time.perf_counter()
    try:
        with mesh:
            extra_rules = (
                EP_ONLY_EXPERT_RULES
                if cfg.moe_dispatch_sharding in ("grouped", "auto_ep", "manual")
                else None
            )
            p_shapes = abstract_params(cfg)
            p_spec = param_specs(ctx, p_shapes, extra_rules)
            p_sh = named_sharding_tree(ctx, p_spec)
            specs = input_specs(cfg, shape)
            b_sh = _batch_shardings(ctx, specs)

            if shape.kind == "train":
                o_shapes = abstract_opt_state(cfg)
                o_sh = named_sharding_tree(
                    ctx, param_specs(ctx, o_shapes, extra_rules))
                step = make_train_step(cfg)
                rep = NamedSharding(mesh, P())
                jf = jax.jit(
                    step,
                    in_shardings=(p_sh, o_sh, b_sh, rep),
                    out_shardings=(p_sh, o_sh, rep),
                )
                lowered = jf.lower(
                    p_shapes, o_shapes, specs,
                    jax.ShapeDtypeStruct((), np.int32),
                )
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                jf = jax.jit(step, in_shardings=(p_sh, b_sh))
                lowered = jf.lower(p_shapes, specs)
            else:  # decode
                s_shapes = abstract_decode_state(cfg, shape)
                s_sh = _decode_state_shardings(ctx, s_shapes)
                step = make_serve_step(cfg)
                rep = NamedSharding(mesh, P())
                logits_sh = NamedSharding(
                    mesh,
                    batch_spec(ctx, (shape.global_batch, cfg.vocab)),
                )
                jf = jax.jit(
                    step,
                    in_shardings=(p_sh, s_sh, b_sh),
                    out_shardings=(logits_sh, s_sh),
                )
                lowered = jf.lower(p_shapes, s_shapes, specs)

            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost_list = compiled.cost_analysis()
            cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
            hlo = compiled.as_text()
            # trip-count-aware cost model (cost_analysis counts each
            # lax.scan body once — see roofline/hlo_cost.py)
            hc = hlo_cost_model(hlo)
            coll = hc["coll"]
            terms = roofline_terms(
                {"flops": hc["flops"], "bytes accessed": hc["bytes"]},
                coll, chips=chips,
            )
            terms["xla_cost_analysis_flops_flat"] = float(cost.get("flops", 0.0))
            terms["cost_model_flags"] = hc["flags"]
            mf = model_flops(cfg, shape)
            hlo_total_flops = terms["hlo_flops_per_chip"] * chips
            result = dict(
                arch=arch,
                shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                status="ok",
                chips=chips,
                seconds_lower=round(t_lower, 2),
                seconds_compile=round(t_compile, 2),
                memory=dict(
                    argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                    output_bytes=getattr(mem, "output_size_in_bytes", None),
                    temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                    generated_code_bytes=getattr(
                        mem, "generated_code_size_in_bytes", None
                    ),
                ),
                roofline=terms,
                model_flops=mf,
                useful_flops_ratio=(
                    mf / hlo_total_flops if hlo_total_flops else None
                ),
                collectives=coll,
                top_traffic=hc.get("top_traffic", []),
                params=sum(
                    int(np.prod(l.shape)) for l in jax.tree.leaves(p_shapes)
                ),
            )
            if verbose:
                print(f"== {arch} × {shape_name} × {result['mesh']} ==")
                print("memory_analysis:", mem)
                print("cost_analysis flops/chip:", terms["hlo_flops_per_chip"])
                print("collectives:", json.dumps(coll["per_kind"]))
                print(
                    "roofline s: compute={t_compute:.4f} memory={t_memory:.4f}"
                    " collective={t_collective:.4f} dominant={dominant}".format(
                        **terms
                    )
                )
            return result
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        return dict(
            arch=arch, shape=shape_name,
            mesh="2x16x16" if multi_pod else "16x16",
            status="error", error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-2000:],
        )
    finally:
        set_mesh_ctx(None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VAL",
                    help="ArchConfig override(s), e.g. --set attn_impl=chunked")
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    args = ap.parse_args(argv)

    overrides = dict(_parse_override(kv) for kv in getattr(args, "set"))

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                res = dryrun_cell(arch, shape, multi_pod=mp,
                                  overrides=overrides)
                if overrides:
                    res["overrides"] = overrides
                if res["status"] == "error":
                    failures += 1
                    print(f"!! {arch} × {shape} × {res['mesh']}: "
                          f"{res['error']}", file=sys.stderr)
                elif res["status"] == "skipped":
                    print(f"-- {arch} × {shape}: skipped ({res['reason']})")
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = f"__{args.tag}" if args.tag else ""
                    fn = f"{arch}__{shape}__{res['mesh']}{tag}.json".replace("/", "_")
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
