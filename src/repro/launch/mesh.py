"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device counts are locked on first jax init,
and only the dry-run is allowed to fake 512 host devices.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices actually exist (tests, CPU driver)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
