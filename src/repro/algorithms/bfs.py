"""Direction-optimized BFS (paper §3.5, Listings 3–4) — activation mode.

The paper implements top-down in K_H (CPUs are better at it) and
bottom-up in K_D (GPUs are better at it), choosing per level.  The TPU
adaptation expresses that split through the framework's push/pull
direction capability (:mod:`repro.core.direction`):

* **push** (top-down): masked scatter over the segmented COO — every
  edge whose source is in the frontier offers itself as parent of an
  unvisited destination (min-scatter picks a deterministic parent).
  The dense-path twin runs the same scatter over the dense-routed
  edges.
* **pull** (bottom-up): for each unvisited vertex, find the smallest
  frontier neighbor.  The sparse twin is a reversed edge scatter; the
  dense twin reduces the packed bitmap tiles (optionally the Pallas
  ``frontier_tile`` kernel) — the paper's Listing 3 "if one of its
  neighbors appears in the frontier, insert and stop", as a masked
  row min-reduction (the deterministic TPU analog of the early exit).

``compile_plan(..., direction="auto")`` re-creates the paper's
per-level Beamer switch from the device-computed frontier count ``nf``
— the executor's hysteresis controller replaces the old host-side
``before`` hook and its per-state ``dir_dense`` flag, and the same
decision drives every wave, mesh shard, and host-lane unit of a level,
so pull levels stay bit-identical to push.  The default (no
``direction=``) is fixed push.  Activation is realized as masking (see
DESIGN §2): inactive edges/vertices are masked out rather than
compacted, which is the static-shape analog of composing block-lists
from blocks with non-empty queues.

Batch axis (``sources=[...]``): the state carries a leading query axis
on ``parent``/``frontier``/``dist`` (and the per-query count ``nf``),
and the level kernels vmap the single-source level function over axis 0
against the one shared graph context.  Each row runs exactly the
traversal its solo run would, so batched results are bit-identical to
single-source runs; the direction decision is per *iteration* (the
controller sums the batched ``nf`` against ``n`` per query).  The
single-source path is the unbatched code path, unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode
from ..kernels import get_kernel

__all__ = ["bfs_algorithm", "bfs"]

_UNVISITED = np.int32(2**31 - 1)  # parent sentinel


def _init_factory(source: int):
    def _init(store):
        n = store.n
        parent = jnp.full((n,), _UNVISITED, jnp.int32).at[source].set(source)
        frontier = jnp.zeros((n,), bool).at[source].set(True)
        dist = jnp.full((n,), _UNVISITED, jnp.int32).at[source].set(0)
        return dict(
            parent=parent,
            frontier=frontier,
            dist=dist,
            nf=jnp.asarray(1, jnp.int32),
        )

    return _init


def _init_multi_factory(sources):
    srcs = np.atleast_1d(np.asarray(sources, dtype=np.int64)).ravel()
    if srcs.size == 0:
        raise ValueError("sources must name at least one vertex")

    def _init(store):
        n = store.n
        if (srcs < 0).any() or (srcs >= n).any():
            raise ValueError(
                f"sources out of range for a graph with {n} vertices")
        b = srcs.size
        rows = np.arange(b)
        parent = np.full((b, n), _UNVISITED, np.int32)
        frontier = np.zeros((b, n), bool)
        dist = np.full((b, n), _UNVISITED, np.int32)
        parent[rows, srcs] = srcs.astype(np.int32)
        frontier[rows, srcs] = True
        dist[rows, srcs] = 0
        return dict(
            parent=jnp.asarray(parent),
            frontier=jnp.asarray(frontier),
            dist=jnp.asarray(dist),
            nf=jnp.ones((b,), jnp.int32),
        )

    return _init


def _top_down(ctx, state, edge_mask):
    src, dst = ctx.src, ctx.dst
    parent, frontier = state["parent"], state["frontier"]
    n = parent.shape[0]
    # visitation is judged on `dist`, which only `post` writes — so the
    # guard sees iteration-start state no matter how the level's edge
    # work is split (sparse→dense chaining in-core, waves streamed) and
    # the level's min-scatter is order-independent.
    unvisited = state["dist"] == _UNVISITED
    do = edge_mask & frontier[src] & unvisited[dst]
    tgt = jnp.where(do, dst, n)
    cand = jnp.where(do, src, _UNVISITED)
    ppad = jnp.concatenate([parent, jnp.asarray([_UNVISITED], jnp.int32)])
    return ppad.at[tgt].min(cand)[:n]


def _bottom_up_edges(ctx, state, edge_mask):
    # reversed roles: unvisited src looks for any frontier dst neighbor.
    # On the symmetrized arc multiset this scatters the same
    # (target, candidate) pairs as _top_down, so the level's min-fold
    # is bit-identical — the pull contract.
    src, dst = ctx.src, ctx.dst
    parent, frontier = state["parent"], state["frontier"]
    n = parent.shape[0]
    unvisited = state["dist"] == _UNVISITED  # see _top_down
    do = edge_mask & unvisited[src] & frontier[dst]
    tgt = jnp.where(do, src, n)
    cand = jnp.where(do, dst, _UNVISITED)
    ppad = jnp.concatenate([parent, jnp.asarray([_UNVISITED], jnp.int32)])
    return ppad.at[tgt].min(cand)[:n]


# the state leaves a level function reads; batched kernels vmap over
# exactly these so untouched leaves pass through by identity (the
# streaming executor's per-wave fold relies on that to tell written
# leaves from carried ones)
_LEVEL_KEYS = ("parent", "frontier", "dist")


def _level_kernel(level_fn):
    """Lift a per-query level function into a (ctx, state, it) kernel
    that vmaps over the batch axis when one is present."""

    def kernel(ctx, state, it):
        sub = {k: state[k] for k in _LEVEL_KEYS}
        if state["parent"].ndim == 2:
            parent = jax.vmap(lambda s: level_fn(ctx, s))(sub)
        else:
            parent = level_fn(ctx, sub)
        return dict(state, parent=parent)

    return kernel


def _bottom_up_tiles(ctx, state):
    tiles = ctx.tiles                      # (nd, T, T)
    t = ctx.tile_dim
    parent = state["parent"]
    n = parent.shape[0]
    fpad = jnp.concatenate([state["frontier"], jnp.zeros((t,), bool)])
    fcols = jax.vmap(
        lambda c0: jax.lax.dynamic_slice(fpad, (c0,), (t,))
    )(ctx.tile_col_start)                  # (nd, T)
    # per tile row: smallest local frontier column, else INT_MAX
    cand_local = get_kernel("frontier_tiles", ctx.backend)(tiles, fcols)
    cand = jnp.where(
        cand_local == _UNVISITED,
        _UNVISITED,
        cand_local + ctx.tile_col_start[:, None].astype(jnp.int32),
    )
    rows = ctx.tile_row_start[:, None] + jnp.arange(t)[None, :]
    rows = jnp.minimum(rows, n)            # tile rows past n are padding
    unvisited_pad = jnp.concatenate(
        [state["dist"] == _UNVISITED, jnp.asarray([False])]  # see _top_down
    )
    cand = jnp.where(unvisited_pad[rows], cand, _UNVISITED)
    ppad = jnp.concatenate([parent, jnp.asarray([_UNVISITED], jnp.int32)])
    return ppad.at[rows].min(cand)[:n]


_kernel_sparse = _level_kernel(
    lambda ctx, s: _top_down(ctx, s, ctx.sparse_edge_mask))
_kernel_dense = _level_kernel(
    lambda ctx, s: _top_down(ctx, s, ctx.dense_edge_mask))
_kernel_sparse_pull = _level_kernel(
    lambda ctx, s: _bottom_up_edges(ctx, s, ctx.sparse_edge_mask))
_kernel_dense_pull = _level_kernel(_bottom_up_tiles)


def _post(ctx, state, it):
    # new frontier = vertices visited this level (elementwise, so the
    # same code serves [n] and batched [b, n] states; the axis=-1 sum
    # yields a scalar nf or one per query respectively)
    newly = (state["dist"] == _UNVISITED) & (state["parent"] != _UNVISITED)
    dist = jnp.where(newly, it + 1, state["dist"])
    nf = jnp.sum(newly.astype(jnp.int32), axis=-1)
    return dict(state, frontier=newly, dist=dist, nf=nf)


def bfs_algorithm(source: int = 0, *, sources=None, max_iters: int = 10_000,
                  beta: int = 24) -> BlockAlgorithm:
    """Single-source BFS from ``source``, or — with ``sources=[...]`` —
    a batched multi-source BFS whose state carries a leading query axis
    (one independent traversal per source; see module docstring).

    ``beta`` is the Beamer cost ratio the direction controller applies
    under ``compile_plan(..., direction="auto")`` (pull once
    ``nf * beta > n``, hysteresis on the way back)."""
    def after(host, state, it):
        return state, bool(np.any(np.asarray(
            jax.device_get(state["nf"])) > 0))

    return BlockAlgorithm(
        name="bfs",
        mode=Mode.ACTIVATION,
        kernel_sparse=_kernel_sparse,
        kernel_dense=_kernel_dense,
        kernel_sparse_pull=_kernel_sparse_pull,
        kernel_dense_pull=_kernel_dense_pull,
        post=_post,
        init_state=(_init_factory(source) if sources is None
                    else _init_multi_factory(sources)),
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: dict(
            parent=np.asarray(state["parent"]),
            dist=np.asarray(state["dist"]),
        ),
        # mesh="shard": the level's parent min-scatter is judged on
        # post-written `dist`, so any edge/tile partition over mesh
        # devices pmin-folds to the identical (deterministic) parents
        metadata=dict(combine=dict(parent="min", dist="min"),
                      workspace_kernel="frontier_tiles",
                      workspace_kernel_pull="frontier_tiles",
                      direction=dict(frontier="nf", beta=float(beta)),
                      csr="none", mesh="shard", batch="query"),
    )


def bfs(store, source: int = 0, *, sources=None, **plan_kw) -> dict:
    from ..core.engine import compile_plan

    alg = bfs_algorithm(source, sources=sources,
                        max_iters=plan_kw.pop("max_iters", 10_000),
                        beta=plan_kw.pop("beta", 24))
    return compile_plan(alg, store, **plan_kw).run().result
