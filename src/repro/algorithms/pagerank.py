"""PageRank (paper §5.2.1) — single-block bulk-synchronous mode.

SpMV-style pull PageRank over the 2-D block layout.  Paper parameters:
damping 0.85, tolerance 1e-4, iteration limit 20.

* sparse path (K_H): masked segmented-COO scatter-add — every edge
  (u→v) deposits ``rank[u]/deg[u]`` into ``acc[v]``.  The paper notes
  atomics are the bottleneck here; XLA's deterministic segment-sum
  lowering plays the role of the atomic adds.
* dense path (K_D): packed bitmap tiles contract against the gathered
  rank slice on the MXU — ``acc[c0:c0+T] += A_bᵀ @ x[r0:r0+T]`` batched
  over tiles (optionally the Pallas ``spmv_tile`` kernel).
* post: damping + dangling mass + L1 delta, acc reset (runs once after
  both paths — the bulk-synchronous combine).

Personalization (``seeds=``): the restart vector ``r`` replaces the
uniform ``1/n`` teleport — mass ``1/len(seeds)`` at each seed, and
dangling mass is likewise redistributed over the seeds.  ``seeds=None``
keeps the exact uniform formula (bit-identical to the unseeded code
path).  The restart vector lives in the *state* pytree, so one compiled
step serves every seed set.

Batch axis: when the state carries a leading query axis
(``rank.ndim == 2``, built with :func:`repro.core.engine.batch_states`),
kernels and post vmap the single-query functions over axis 0 against the
one shared graph context.  Converged queries freeze — their rows stop
updating once ``delta <= tol`` — so each row of a batched run finishes
with exactly the state its solo run would have produced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode
from ..kernels import get_kernel

__all__ = ["pagerank_algorithm", "pagerank"]


def _prepare(store, sched):
    return dict(
        inv_deg=jnp.asarray(1.0 / np.maximum(store.degrees, 1).astype(np.float32)),
        dangling=jnp.asarray(store.degrees == 0),
    )


def _restart_vector(n: int, seeds) -> np.ndarray:
    s = np.atleast_1d(np.asarray(seeds, dtype=np.int64)).ravel()
    if s.size == 0:
        raise ValueError("seeds must name at least one vertex")
    if (s < 0).any() or (s >= n).any():
        raise ValueError(f"seeds out of range for a graph with {n} vertices")
    r = np.zeros(n, np.float32)
    np.add.at(r, s, np.float32(1.0 / s.size))
    return r


def _init_factory(seeds):
    def _init(store):
        n = store.n
        base = dict(
            acc=jnp.zeros((n,), jnp.float32),
            delta=jnp.asarray(jnp.inf, jnp.float32),
        )
        if seeds is None:
            return dict(base, rank=jnp.full((n,), 1.0 / n, jnp.float32))
        r = jnp.asarray(_restart_vector(n, seeds))
        return dict(base, rank=r, restart=r)

    return _init


def _scatter_sparse(ctx, rank, acc):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    contrib = rank * ctx.extras["inv_deg"]
    vals = jnp.where(msk, contrib[src], 0.0)
    return acc.at[dst].add(vals)


def _kernel_sparse(ctx, state, it):
    if state["rank"].ndim == 2:
        acc = jax.vmap(lambda r, a: _scatter_sparse(ctx, r, a))(
            state["rank"], state["acc"])
    else:
        acc = _scatter_sparse(ctx, state["rank"], state["acc"])
    return dict(state, acc=acc)


def _scatter_dense(ctx, rank, acc):
    tiles = ctx.tiles                         # (nd, T, T) 0/1 float32
    t = ctx.tile_dim
    contrib = rank * ctx.extras["inv_deg"]
    pad = jnp.zeros((t,), contrib.dtype)
    xpad = jnp.concatenate([contrib, pad])
    xs = jax.vmap(
        lambda r0: jax.lax.dynamic_slice(xpad, (r0,), (t,))
    )(ctx.tile_row_start)                     # (nd, T)
    ys = get_kernel("spmv_tiles", ctx.backend)(tiles, xs)   # (nd, T)
    idx = ctx.tile_col_start[:, None] + jnp.arange(t)[None, :]
    acc_pad = jnp.concatenate([acc, pad]).at[idx].add(ys)
    return acc_pad[: acc.shape[0]]


def _kernel_dense(ctx, state, it):
    if state["rank"].ndim == 2:
        acc = jax.vmap(lambda r, a: _scatter_dense(ctx, r, a))(
            state["rank"], state["acc"])
    else:
        acc = _scatter_dense(ctx, state["rank"], state["acc"])
    return dict(state, acc=acc)


def _post(ctx, state, it, damping=0.85):
    n = state["rank"].shape[0]
    dangling_mass = jnp.sum(jnp.where(ctx.extras["dangling"], state["rank"], 0.0))
    new_rank = (1.0 - damping) / n + damping * (state["acc"] + dangling_mass / n)
    delta = jnp.sum(jnp.abs(new_rank - state["rank"]))
    return dict(rank=new_rank, acc=jnp.zeros_like(state["acc"]), delta=delta)


def _post_seeded(ctx, state, it, damping=0.85):
    # teleport (and dangling) mass goes to the restart distribution
    # instead of 1/n — matches networkx's personalization + dangling
    r = state["restart"]
    dangling_mass = jnp.sum(jnp.where(ctx.extras["dangling"], state["rank"], 0.0))
    new_rank = (1.0 - damping) * r + damping * (state["acc"] + dangling_mass * r)
    delta = jnp.sum(jnp.abs(new_rank - state["rank"]))
    return dict(rank=new_rank, acc=jnp.zeros_like(state["acc"]), delta=delta,
                restart=r)


def pagerank_algorithm(*, damping: float = 0.85, tol: float = 1e-4,
                       max_iters: int = 20, seeds=None) -> BlockAlgorithm:
    def post(ctx, state, it):
        single = _post_seeded if "restart" in state else _post
        if state["rank"].ndim == 2:
            new = jax.vmap(lambda s: single(ctx, s, it, damping))(state)
            # freeze converged rows: a query whose previous delta is
            # already <= tol keeps the state its solo run ended with
            active = state["delta"] > tol

            def keep(old, nw):
                a = active.reshape(active.shape + (1,) * (nw.ndim - 1))
                return jnp.where(a, nw, old)
            out = {k: keep(state[k], v) for k, v in new.items()}
            out["acc"] = new["acc"]          # zeros either way
            return out
        return single(ctx, state, it, damping)

    def after(host, state, it):
        return state, bool(np.any(np.asarray(
            jax.device_get(state["delta"])) > tol))

    return BlockAlgorithm(
        name="pagerank",
        mode=Mode.BULK,
        kernel_sparse=_kernel_sparse,
        kernel_dense=_kernel_dense,
        post=post,
        prepare=_prepare,
        init_state=_init_factory(seeds),
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: np.asarray(state["rank"]),
        # mesh="shard": the rank scatter decomposes over any edge
        # partition judged from iteration-start rank; acc folds with
        # psum (exact for the iteration's summation structure up to
        # float order), everything else is post-written.
        # tol joins params because the batched post's freeze mask
        # traces against it — two tolerances must not share a step.
        # seeds stay OUT of params: personalization is state content
        # (restart leaf), so every seed set shares one compiled step.
        metadata=dict(combine="add", params=dict(damping=damping, tol=tol),
                      workspace_kernel="spmv_tiles", csr="none",
                      mesh="shard", batch="query"),
    )


def pagerank(store, **plan_kw) -> np.ndarray:
    """Convenience wrapper: compile + run PageRank on a BlockStore."""
    from ..core.engine import compile_plan

    alg = pagerank_algorithm(
        damping=plan_kw.pop("damping", 0.85),
        tol=plan_kw.pop("tol", 1e-4),
        max_iters=plan_kw.pop("max_iters", 20),
        seeds=plan_kw.pop("seeds", None),
    )
    return compile_plan(alg, store, **plan_kw).run().result
