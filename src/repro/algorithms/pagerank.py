"""PageRank (paper §5.2.1) — single-block bulk-synchronous mode.

SpMV-style pull PageRank over the 2-D block layout.  Paper parameters:
damping 0.85, tolerance 1e-4, iteration limit 20.

* sparse path (K_H): masked segmented-COO scatter-add — every edge
  (u→v) deposits ``rank[u]/deg[u]`` into ``acc[v]``.  The paper notes
  atomics are the bottleneck here; XLA's deterministic segment-sum
  lowering plays the role of the atomic adds.
* dense path (K_D): packed bitmap tiles contract against the gathered
  rank slice on the MXU — ``acc[c0:c0+T] += A_bᵀ @ x[r0:r0+T]`` batched
  over tiles (optionally the Pallas ``spmv_tile`` kernel).
* post: damping + dangling mass + L1 delta, acc reset (runs once after
  both paths — the bulk-synchronous combine).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode
from ..kernels import get_kernel

__all__ = ["pagerank_algorithm", "pagerank"]


def _prepare(store, sched):
    return dict(
        inv_deg=jnp.asarray(1.0 / np.maximum(store.degrees, 1).astype(np.float32)),
        dangling=jnp.asarray(store.degrees == 0),
    )


def _init(store):
    n = store.n
    return dict(
        rank=jnp.full((n,), 1.0 / n, jnp.float32),
        acc=jnp.zeros((n,), jnp.float32),
        delta=jnp.asarray(jnp.inf, jnp.float32),
    )


def _kernel_sparse(ctx, state, it):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    contrib = state["rank"] * ctx.extras["inv_deg"]
    vals = jnp.where(msk, contrib[src], 0.0)
    acc = state["acc"].at[dst].add(vals)
    return dict(state, acc=acc)


def _kernel_dense(ctx, state, it):
    tiles = ctx.tiles                         # (nd, T, T) 0/1 float32
    t = ctx.tile_dim
    contrib = state["rank"] * ctx.extras["inv_deg"]
    pad = jnp.zeros((t,), contrib.dtype)
    xpad = jnp.concatenate([contrib, pad])
    xs = jax.vmap(
        lambda r0: jax.lax.dynamic_slice(xpad, (r0,), (t,))
    )(ctx.tile_row_start)                     # (nd, T)
    ys = get_kernel("spmv_tiles", ctx.backend)(tiles, xs)   # (nd, T)
    idx = ctx.tile_col_start[:, None] + jnp.arange(t)[None, :]
    acc_pad = jnp.concatenate([state["acc"], pad]).at[idx].add(ys)
    return dict(state, acc=acc_pad[: state["acc"].shape[0]])


def _post(ctx, state, it, damping=0.85):
    n = state["rank"].shape[0]
    dangling_mass = jnp.sum(jnp.where(ctx.extras["dangling"], state["rank"], 0.0))
    new_rank = (1.0 - damping) / n + damping * (state["acc"] + dangling_mass / n)
    delta = jnp.sum(jnp.abs(new_rank - state["rank"]))
    return dict(rank=new_rank, acc=jnp.zeros_like(state["acc"]), delta=delta)


def pagerank_algorithm(*, damping: float = 0.85, tol: float = 1e-4,
                       max_iters: int = 20) -> BlockAlgorithm:
    def post(ctx, state, it):
        return _post(ctx, state, it, damping)

    def after(host, state, it):
        return state, bool(jax.device_get(state["delta"]) > tol)

    return BlockAlgorithm(
        name="pagerank",
        mode=Mode.BULK,
        kernel_sparse=_kernel_sparse,
        kernel_dense=_kernel_dense,
        post=post,
        prepare=_prepare,
        init_state=_init,
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: np.asarray(state["rank"]),
        # mesh="shard": the rank scatter decomposes over any edge
        # partition judged from iteration-start rank; acc folds with
        # psum (exact for the iteration's summation structure up to
        # float order), everything else is post-written
        metadata=dict(combine="add", params=dict(damping=damping),
                      workspace_kernel="spmv_tiles", csr="none",
                      mesh="shard"),
    )


def pagerank(store, **plan_kw) -> np.ndarray:
    """Convenience wrapper: compile + run PageRank on a BlockStore."""
    from ..core.engine import compile_plan

    alg = pagerank_algorithm(
        damping=plan_kw.pop("damping", 0.85),
        tol=plan_kw.pop("tol", 1e-4),
        max_iters=plan_kw.pop("max_iters", 20),
    )
    return compile_plan(alg, store, **plan_kw).run().result
