"""The paper's five graph algorithms, expressed as BlockAlgorithms."""
from .pagerank import pagerank, pagerank_algorithm
from .sv import shiloach_vishkin, sv_algorithm
from .cc import connected_components, afforest_algorithm
from .bfs import bfs, bfs_algorithm
from .tc import triangle_count, tc_algorithm, orient_dag
from .kcore import k_core, kcore_algorithm
from .hits import hits, hits_algorithm

__all__ = [
    "pagerank", "pagerank_algorithm",
    "shiloach_vishkin", "sv_algorithm",
    "connected_components", "afforest_algorithm",
    "bfs", "bfs_algorithm",
    "triangle_count", "tc_algorithm", "orient_dag",
    "k_core", "kcore_algorithm",
    "hits", "hits_algorithm",
]
