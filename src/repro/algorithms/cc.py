"""Connected components via Afforest (paper §5.2.3, Sutton et al. [54]).

GAPBS, Galois and PGAbB all implement Afforest as their "best" CC; the
paper runs the *sampling* phase on the GPU and the *finalization* on
CPUs.  Structure:

1. **Neighbor-rounds sampling** (first ``k`` rounds): round ``r`` hooks
   every vertex to its ``r``-th neighbor (a uniform, coalesced edge
   subset — why the paper gives it to the GPU).
2. **Skip detection** (host, I_B): sample vertices, find the most common
   component ``c_skip`` — the giant component.
3. **Finalization**: SV-style hooking over all edges *except* those whose
   endpoints already sit in ``c_skip`` (activation-as-masking), repeated
   with compression until no hooks fire.

All phases share the race-free min-scatter hook (see sv.py).  The
kernel does *only* the hook — a min-decomposable scatter, so the
streaming executor can fold per-wave partials exactly — while pointer
jumping (compression) and the hook counter ``H`` live in ``post``,
which runs once per iteration on the combined state.  ``C_prev``
(stashed by I_B) is the iteration-start snapshot ``post`` diffs
against; the count it produces is identical to counting changes before
compression in-kernel, which is what the pre-refactor code did.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode

__all__ = ["afforest_algorithm", "connected_components"]


def _hook(C, u, v, do):
    n = C.shape[0]
    cu, cv = C[u], C[v]
    r1 = jnp.maximum(cu, cv)
    r2 = jnp.minimum(cu, cv)
    do = do & (r1 != r2) & (C[r1] == r1)
    tgt = jnp.where(do, r1, n)
    Cp = jnp.concatenate([C, jnp.asarray([n], jnp.int32)])
    return Cp.at[tgt].min(r2)[:n]


def _compress(C):
    return jax.lax.while_loop(
        lambda c: jnp.any(c != c[c]), lambda c: c[c], C
    )


def _init(store):
    return dict(
        C=jnp.arange(store.n, dtype=jnp.int32),
        C_prev=jnp.arange(store.n, dtype=jnp.int32),
        H=jnp.asarray(0, jnp.int32),
        c_skip=jnp.asarray(-1, jnp.int32),
    )


def _make_kernel(k_rounds: int, pull: bool = False):
    def kernel(ctx, state, it):
        indptr, indices, degrees = ctx.indptr, ctx.indices, ctx.degrees
        src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
        C = state["C"]
        n = C.shape[0]

        def sample_round(C):
            # direction-agnostic: reads each vertex's own CSR row, no
            # scatter orientation to flip — shared by push and pull
            r = it.astype(indptr.dtype)
            u = jnp.arange(n, dtype=jnp.int32)
            idx = jnp.minimum(indptr[:-1] + r, jnp.maximum(indices.shape[0] - 1, 0))
            v = indices[idx]
            return _hook(C, u, v, r < degrees)

        def final_round(C):
            # the skip predicate and the root-normalizing hook are both
            # endpoint-symmetric, so the pull orientation (reversed
            # arcs) min-folds to bit-identical C on the symmetrized
            # arc multiset
            skip = (C[src] == state["c_skip"]) & (C[dst] == state["c_skip"])
            if pull:
                return _hook(C, dst, src, msk & ~skip)
            return _hook(C, src, dst, msk & ~skip)

        return dict(
            state, C=jax.lax.cond(it < k_rounds, sample_round, final_round, C)
        )

    return kernel


def _post(ctx, state, it):
    hooked = jnp.sum((state["C"] != state["C_prev"]).astype(jnp.int32))
    return dict(state, C=_compress(state["C"]), H=hooked)


def afforest_algorithm(*, k_rounds: int = 2, sample_size: int = 1024,
                       max_iters: int = 200) -> BlockAlgorithm:
    def before(host, state, it):
        state = dict(state, C_prev=state["C"])  # iteration-start snapshot
        if it == k_rounds:  # I_B: detect the giant component once
            C = np.asarray(jax.device_get(state["C"]))
            n = C.shape[0]
            rng = np.random.default_rng(0)
            samp = C[rng.integers(0, n, min(sample_size, n))]
            vals, counts = np.unique(samp, return_counts=True)
            state = dict(state, c_skip=jnp.asarray(vals[np.argmax(counts)], jnp.int32))
        return state

    def after(host, state, it):
        if it < k_rounds:
            return state, True
        return state, bool(jax.device_get(state["H"]) > 0)

    return BlockAlgorithm(
        name="afforest",
        mode=Mode.BULK,
        kernel_sparse=_make_kernel(k_rounds),
        kernel_sparse_pull=_make_kernel(k_rounds, pull=True),
        post=_post,
        init_state=_init,
        before=before,
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: np.asarray(state["C"]),
        metadata=dict(
            combine=dict(C="min", C_prev="min", H="add", c_skip="max"),
            params=dict(k_rounds=k_rounds),
            # H counts hooks per round — high right after sampling
            # (pull), decaying as finalization converges (push)
            direction=dict(frontier="H"),
            # sampling rounds read only each vertex's first k_rounds
            # neighbors — the streaming executor runs one representative
            # wave for them against the first-k prefix CSR; the
            # finalization rounds are pure COO scatters, so nothing
            # edge-proportional need stay device-resident
            edge_free_iterations=k_rounds,
            csr="none",
            # mesh="shard": finalization hooks judge roots on
            # iteration-start C (pmin over any edge partition); the
            # edge-free sampling rounds read no per-device data, so the
            # mesh executor runs them replicated without collectives
            mesh="shard",
        ),
    )


def connected_components(store, **plan_kw) -> np.ndarray:
    from ..core.engine import compile_plan

    return compile_plan(afforest_algorithm(), store, **plan_kw).run().result
