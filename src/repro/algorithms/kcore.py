"""k-core decomposition — beyond the paper's five, but squarely in its
taxonomy: Fig. 1 lists peeling-based algorithms (kTruss) under
*activation-based* execution.  Peeling is iterative deactivation:
vertices whose alive-degree drops below k leave the subgraph, which
re-activates their neighbors' blocks.

Activation-as-masking (DESIGN §2): the alive mask plays the block-queue
role; I_A stops when an iteration peels nobody.  The kernel is a pure
alive-degree scatter-add into the ``deg`` scratch attribute (exactly
add-decomposable across streamed waves); the ``deg >= k`` threshold,
the peel counter, and the scratch reset run once per iteration in
``post`` — splitting them would otherwise let a vertex whose degree is
spread over several waves be peeled spuriously.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode

__all__ = ["kcore_algorithm", "k_core"]


def _init(store):
    n = store.n
    return dict(
        alive=jnp.ones((n,), bool),
        deg=jnp.zeros((n,), jnp.int32),
        peeled=jnp.asarray(1, jnp.int32),
    )


def _kernel(ctx, state, it):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    alive = state["alive"]
    contrib = (msk & alive[src] & alive[dst]).astype(jnp.int32)
    return dict(state, deg=state["deg"].at[dst].add(contrib))


def _kernel_pull(ctx, state, it):
    # pull orientation: each vertex accumulates over its out-arcs
    # (``src`` side) instead of receiving on its in-arcs.  The edge
    # predicate is symmetric and the arc multiset is symmetrized, so the
    # add-fold lands bit-identical degrees — contributions just arrive
    # grouped by owner, the gather-friendly shape.
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    alive = state["alive"]
    contrib = (msk & alive[src] & alive[dst]).astype(jnp.int32)
    return dict(state, deg=state["deg"].at[src].add(contrib))


def _make_post(k: int):
    def post(ctx, state, it):
        alive = state["alive"]
        new_alive = alive & (state["deg"] >= k)
        return dict(
            alive=new_alive,
            deg=jnp.zeros_like(state["deg"]),
            peeled=jnp.sum((alive & ~new_alive).astype(jnp.int32)),
        )

    return post


def kcore_algorithm(k: int, *, max_iters: int = 10_000) -> BlockAlgorithm:
    def after(host, state, it):
        return state, bool(jax.device_get(state["peeled"]) > 0)

    return BlockAlgorithm(
        name=f"kcore_{k}",
        mode=Mode.ACTIVATION,
        kernel_sparse=_kernel,
        kernel_sparse_pull=_kernel_pull,
        post=_make_post(k),
        init_state=_init,
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: np.asarray(state["alive"]),
        # mesh="shard": alive-neighbor degree counting is a scatter-add
        # from iteration-start alive — psum over any edge partition;
        # alive/peeled are post-written
        metadata=dict(combine=dict(deg="add", alive="min", peeled="add"),
                      # nearly everything is alive early, so "auto" pulls
                      # until peeling thins the subgraph out
                      direction=dict(frontier="alive"),
                      csr="none", mesh="shard"),
    )


def k_core(store, k: int, **plan_kw) -> np.ndarray:
    """Boolean membership mask of the k-core."""
    from ..core.engine import compile_plan

    return compile_plan(kcore_algorithm(k), store, mode="sparse_only",
                        **plan_kw).run().result
