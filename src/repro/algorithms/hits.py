"""HITS (hubs & authorities) — paper Fig. 1 lists it under single-block
bulk-synchronous execution next to PageRank.

Per iteration: a ← Aᵀh, h ← A·a, both L2-normalized; converges to the
principal singular vectors.  Same segmented-COO scatter structure as
PageRank's sparse path; the dense tile path reuses ``spmv_tiles``-style
contractions (hybrid mode supported through the same scheduler).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode

__all__ = ["hits_algorithm", "hits"]


def _init(store):
    n = store.n
    v = jnp.full((n,), 1.0 / np.sqrt(n), jnp.float32)
    return dict(hub=v, auth=v, delta=jnp.asarray(jnp.inf, jnp.float32))


def _kernel_sparse(ctx, state, it):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    hub, auth = state["hub"], state["auth"]
    # authority update: a[v] += h[u] over edges u→v
    a_new = jnp.zeros_like(auth).at[dst].add(jnp.where(msk, hub[src], 0.0))
    a_new = a_new / jnp.maximum(jnp.linalg.norm(a_new), 1e-12)
    # hub update: h[u] += a_new[v]
    h_new = jnp.zeros_like(hub).at[src].add(jnp.where(msk, a_new[dst], 0.0))
    h_new = h_new / jnp.maximum(jnp.linalg.norm(h_new), 1e-12)
    delta = jnp.sum(jnp.abs(a_new - auth)) + jnp.sum(jnp.abs(h_new - hub))
    return dict(hub=h_new, auth=a_new, delta=delta)


def hits_algorithm(*, tol: float = 1e-8, max_iters: int = 100) -> BlockAlgorithm:
    def after(host, state, it):
        return state, bool(jax.device_get(state["delta"]) > tol)

    return BlockAlgorithm(
        name="hits",
        mode=Mode.BULK,
        kernel_sparse=_kernel_sparse,
        init_state=_init,
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: dict(
            hub=np.asarray(state["hub"]), auth=np.asarray(state["auth"])
        ),
        metadata=dict(combine=dict(hub="add", auth="add", delta="max")),
    )


def hits(store, **plan_kw) -> dict:
    from ..core.engine import compile_plan

    return compile_plan(hits_algorithm(), store, mode="sparse_only",
                        **plan_kw).run().result
