"""HITS (hubs & authorities) — paper Fig. 1 lists it under single-block
bulk-synchronous execution next to PageRank.

a ← Aᵀh, h ← A·a, both L2-normalized; converges to the principal
singular vectors.  The update is phase-split across engine iterations
(even: authority scatter, odd: hub scatter — the same parity trick as
Shiloach–Vishkin's hook/link), with the normalization in ``post``:

* **kernel** (K_H): one masked segmented-COO scatter-add into the
  ``acc`` scratch attribute — a pure edge-decomposable reduction, which
  is what lets the streaming executor fold per-wave partials with the
  declared ``add`` combine and reproduce the in-core result.
* **post**: L2-normalize ``acc`` into ``auth`` (even) / ``hub`` (odd),
  accumulate the L1 delta, reset ``acc`` — runs once per iteration on
  the combined state.

``delta`` therefore carries the full |Δa|+|Δh| of one mathematical
HITS iteration only after the odd phase; ``after`` checks it there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode

__all__ = ["hits_algorithm", "hits"]


def _init(store):
    n = store.n
    v = jnp.full((n,), 1.0 / np.sqrt(n), jnp.float32)
    return dict(
        hub=v,
        auth=v,
        acc=jnp.zeros((n,), jnp.float32),
        delta_a=jnp.asarray(0.0, jnp.float32),
        delta=jnp.asarray(jnp.inf, jnp.float32),
    )


def _kernel_sparse(ctx, state, it):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    hub, auth = state["hub"], state["auth"]
    acc = jax.lax.cond(
        it % 2 == 0,
        # authority phase: a[v] += h[u] over edges u→v
        lambda a: a.at[dst].add(jnp.where(msk, hub[src], 0.0)),
        # hub phase: h[u] += a[v] (auth already updated last iteration)
        lambda a: a.at[src].add(jnp.where(msk, auth[dst], 0.0)),
        state["acc"],
    )
    return dict(state, acc=acc)


def _post(ctx, state, it):
    def auth_phase(s):
        a_new = s["acc"] / jnp.maximum(jnp.linalg.norm(s["acc"]), 1e-12)
        return dict(
            s, auth=a_new,
            delta_a=jnp.sum(jnp.abs(a_new - s["auth"])),
            acc=jnp.zeros_like(s["acc"]),
        )

    def hub_phase(s):
        h_new = s["acc"] / jnp.maximum(jnp.linalg.norm(s["acc"]), 1e-12)
        return dict(
            s, hub=h_new,
            delta=s["delta_a"] + jnp.sum(jnp.abs(h_new - s["hub"])),
            acc=jnp.zeros_like(s["acc"]),
        )

    return jax.lax.cond(it % 2 == 0, auth_phase, hub_phase, state)


def hits_algorithm(*, tol: float = 1e-8, max_iters: int = 100) -> BlockAlgorithm:
    def after(host, state, it):
        if it % 2 == 0:
            return state, True  # always finish the iteration's hub phase
        return state, bool(jax.device_get(state["delta"]) > tol)

    return BlockAlgorithm(
        name="hits",
        mode=Mode.BULK,
        kernel_sparse=_kernel_sparse,
        post=_post,
        init_state=_init,
        after=after,
        max_iterations=2 * max_iters,
        finalize=lambda store, state: dict(
            hub=np.asarray(state["hub"]), auth=np.asarray(state["auth"])
        ),
        # mesh="shard": both phases are pure scatter-adds into acc from
        # iteration-start hub/auth — psum over any edge partition
        metadata=dict(combine=dict(acc="add"), csr="none", mesh="shard"),
    )


def hits(store, **plan_kw) -> dict:
    from ..core.engine import compile_plan

    return compile_plan(hits_algorithm(), store, mode="sparse_only",
                        **plan_kw).run().result
