"""Triangle counting (paper §3.6, §5.4) — multi-block pattern-based mode.

The 2-D block TC of Yaşar et al. [46]: after degree ordering and DAG
orientation (u < v), a block-list is a triple ``L = (B_ij, B_ik, B_jk)``
with ``i ≤ j ≤ k`` — for every edge (u, v) in B_ij, the common neighbors
of u (from B_ik) and v (from B_jk) that land in stripe k are counted.
Conformal partitioning guarantees exactly three blocks per task (paper
§1/§4.3) and that each partial adjacency is a *contiguous slice* of the
global CSR row (``row_block_ptr``).

* sparse path: per-(edge, stripe-k) items, bucketed by the padded length
  of the gathered (shorter) list; the membership test is a vectorized
  binary search on the other slice.  Buckets keep the work within 2× of
  the true wedge count while every shape stays static.
* dense path: for tile-resident triples, ``nt += Σ (A_ik · A_jkᵀ) ∘ A_ij``
  — a masked matmul on the MXU (optionally the Pallas ``tc_tile`` kernel).

The paper's observation that "sparse tasks are more bandwidth-bound and
belong on CPUs, dense tasks on the GPU" (§5.4) is exactly this split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.blocks import build_block_store
from ..core.functors import BlockAlgorithm, Mode
from ..core.graph import Graph, degree_order, from_edges
from ..kernels import get_kernel

__all__ = ["tc_algorithm", "triangle_count", "orient_dag"]


def orient_dag(g: Graph) -> Graph:
    """Degree-order (ascending) + keep only u<v edges → DAG whose wedge
    count is near-minimal (paper enables degree ordering for all systems)."""
    go, _ = degree_order(g, ascending=True)
    src, dst = go.coo()
    keep = src < dst
    return from_edges(src[keep], dst[keep], n=go.n, symmetrize=False,
                      name=g.name + "+dag")


def _make_blocklists(store):
    p = store.p
    nonempty = np.diff(store.block_ptr) > 0
    out = []
    for i in range(p):
        for j in range(i, p):
            if not nonempty[i * p + j]:
                continue
            for k in range(j, p):
                if nonempty[i * p + k] and nonempty[j * p + k]:
                    out.append((i * p + j, i * p + k, j * p + k))
    if not out:
        return np.zeros((0, 3), np.int64)
    return np.asarray(out, dtype=np.int64)


def _sparse_items(store, bls, dense_mask):
    """(sg, lg, sb, lb) membership-test items of every sparse task.

    Lengths come from differences of ``row_block_ptr`` rows, so they are
    invariant under the per-wave/per-device CSR rebasing — which is what
    lets :func:`_stage_plan` derive the bucket ladder from the *global*
    store while each wave's ``prepare`` fills it from its local view.
    """
    p = store.p
    rbp = store.row_block_ptr
    sg_all, lg_all, sb_all, lb_all = [], [], [], []
    for t in range(bls.shape[0]):
        if dense_mask[t]:
            continue
        b_ij, b_ik, b_jk = (int(x) for x in bls[t])
        k = b_ik % p
        s, e = store.block_ptr[b_ij], store.block_ptr[b_ij + 1]
        u = store.src[s:e].astype(np.int64)
        v = store.dst[s:e].astype(np.int64)
        su, lu = rbp[u, k], rbp[u, k + 1] - rbp[u, k]
        sv, lv = rbp[v, k], rbp[v, k + 1] - rbp[v, k]
        keep = (lu > 0) & (lv > 0)
        su, lu, sv, lv = su[keep], lu[keep], sv[keep], lv[keep]
        # gather the shorter side, binary-search the longer one
        swap = lu > lv
        sg_all.append(np.where(swap, sv, su))
        lg_all.append(np.where(swap, lv, lu))
        sb_all.append(np.where(swap, su, sv))
        lb_all.append(np.where(swap, lu, lv))
    if not sg_all:
        z = np.zeros(0, np.int64)
        return z, z, z, z
    return (np.concatenate(sg_all), np.concatenate(lg_all),
            np.concatenate(sb_all), np.concatenate(lb_all))


def _bucket_ids(lg: np.ndarray) -> np.ndarray:
    return np.ceil(np.log2(np.maximum(lg, 1))).astype(np.int64)


def _stage_plan(store, sched):
    """The cross-wave ``BucketPlan``: one dp/steps ladder for the plan.

    Computed ONCE from the full store and schedule (the executor calls
    it before any per-wave ``prepare``): the union of every sparse
    item's dp bucket, with ``steps`` the global max search depth per
    bucket.  Every wave then emits exactly these buckets — item arrays
    padded up the power-of-two count ladder with neutral items — so the
    streamed step's static structure is wave-invariant and jit traces
    once per distinct bucket *shape*, not once per wave (the TC retrace
    that used to dominate high-wave-count runs).
    """
    _, lg, _, lb = _sparse_items(store, sched.blocklists,
                                 sched.dense_task_mask)
    if not lg.size:
        return dict(dp_steps=())
    ids = _bucket_ids(lg)
    ladder = []
    for b in np.unique(ids):
        sel = ids == b
        dp = int(max(1, 2 ** b))
        steps = int(max(1, np.ceil(np.log2(float(lb[sel].max()) + 1)))) + 1
        ladder.append((dp, steps))
    return dict(dp_steps=tuple(ladder))


def _prepare(store, sched, plan=None):
    """Bucketed sparse items + tile triple indices (host side, one-time).

    Returns ``Context.extras``: the bucket dicts mix traced arrays
    (``sg``/``lg``/``sb``/``lb``) with static ints (``dp``/``steps``
    drive shapes/unroll) — the typed Context keeps that split.

    With a ``plan`` (the executor always passes the shared
    :func:`_stage_plan` output), the emitted buckets follow the plan's
    dp/steps ladder exactly: buckets this wave has no items for still
    appear (one neutral item), and item counts pad up the power-of-two
    ladder with neutral items (``lg = lb = 0`` — the mask and the
    lower-bound check both reject them, so padding counts nothing).
    """
    from ..core.membudget import bucket_size
    from ..kernels.registry import workspace_bytes

    bls = sched.blocklists
    dense_mask = sched.dense_task_mask

    # ---- sparse items: (edge, k) pairs from sparse tasks --------------
    sg, lg, sb, lb = _sparse_items(store, bls, dense_mask)
    buckets = []
    scratch = 0
    ids = _bucket_ids(lg) if lg.size else np.zeros(0, np.int64)
    if plan is not None:
        for dp, steps in plan["dp_steps"]:
            sel = ids == (int(dp).bit_length() - 1)
            cnt = int(sel.sum())
            padded = bucket_size(cnt, minimum=1)
            arrs = {}
            for key, col in (("sg", sg), ("lg", lg), ("sb", sb), ("lb", lb)):
                a = np.zeros(padded, np.int64)
                a[:cnt] = col[sel]
                arrs[key] = jnp.asarray(a)
            buckets.append(dict(dp=int(dp), steps=int(steps), **arrs))
            scratch += workspace_bytes("csr_bucket_search",
                                       items=padded, depth=int(dp))
    elif lg.size:
        for b in np.unique(ids):
            sel = ids == b
            dp = int(max(1, 2 ** b))
            steps = int(max(1, np.ceil(np.log2(float(lb[sel].max()) + 1)))) + 1
            buckets.append(
                dict(
                    dp=dp,
                    steps=steps,
                    sg=jnp.asarray(sg[sel]),
                    lg=jnp.asarray(lg[sel]),
                    sb=jnp.asarray(sb[sel]),
                    lb=jnp.asarray(lb[sel]),
                )
            )
            scratch += workspace_bytes("csr_bucket_search",
                                       items=int(sel.sum()), depth=dp)
    # device scratch of the membership test, declared so the streaming
    # executor prices it against the budget (stripped before staging)
    extras = {"tc_buckets": buckets, "__workspace_bytes__": scratch}

    # ---- dense triples: tile index per block ---------------------------
    if dense_mask.any():
        tid_of_block = {int(b): t for t, b in enumerate(store.tile_block_ids)}
        triples = np.asarray(
            [[tid_of_block[int(b)] for b in row] for row in bls[dense_mask]],
            dtype=np.int32,
        )
        if plan is not None:
            # pad triple rows up the count ladder with -1 (masked by
            # _kernel_dense) so dense waves share shapes too
            padded = bucket_size(triples.shape[0], minimum=1)
            full = np.full((padded, 3), -1, np.int32)
            full[: triples.shape[0]] = triples
            triples = full
        extras["tc_tiles_idx"] = jnp.asarray(triples)
    else:
        extras["tc_tiles_idx"] = None
    return extras


def _bucket_count(indices, bucket):
    """Σ over items of |gathered-slice ∩ searched-slice| (binary search)."""
    sg, lg, sb, lb = bucket["sg"], bucket["lg"], bucket["sb"], bucket["lb"]
    dp, steps = bucket["dp"], bucket["steps"]
    m = indices.shape[0]
    pos = sg[:, None] + jnp.arange(dp, dtype=sg.dtype)[None, :]
    vals = indices[jnp.minimum(pos, m - 1)]
    mask = jnp.arange(dp)[None, :] < lg[:, None]
    lo = jnp.broadcast_to(sb[:, None], vals.shape)
    hi = jnp.broadcast_to((sb + lb)[:, None], vals.shape)
    for _ in range(steps):
        mid = (lo + hi) // 2
        mv = indices[jnp.minimum(mid, m - 1)]
        go = mv < vals          # lower bound: search right half
        lo = jnp.where(go, mid + 1, lo)
        hi = jnp.where(go, hi, mid)
    end = (sb + lb)[:, None]
    found = (lo < end) & (indices[jnp.minimum(lo, m - 1)] == vals) & mask
    return jnp.sum(found.astype(jnp.int32))


def _kernel_sparse(ctx, state, it):
    nt = state["nt"]
    for bucket in ctx.extras["tc_buckets"]:
        nt = nt + _bucket_count(ctx.indices, bucket)
    return dict(state, nt=nt)


def _mesh_pack(extras_list):
    """Pack per-device ``_prepare`` outputs for ``shard_map`` staging.

    Per-device bucket ladders are data-dependent (a device only has the
    dp values its items produced), so the structures cannot be stacked
    directly.  Unify to the union ladder: a bucket absent on a device
    contributes zero items, and item arrays pad to the per-bucket max
    with neutral items (``lg = lb = 0`` — the membership test's mask and
    lower-bound check both reject them, so padding counts nothing).
    ``steps`` takes the per-bucket max so one unrolled binary search
    serves every device.  Dense triples pad with ``-1`` rows, which
    ``_kernel_dense`` masks out.  Array leaves come back with a leading
    device axis, as the mesh executor's contract requires.

    The returned tree re-declares ``__workspace_bytes__`` for the
    *unified* shapes: every entry now runs every bucket at the padded
    count, so the per-entry membership-test scratch is the sum over the
    union ladder — the executor prices that against the budget instead
    of the per-entry pre-unification declarations (which can
    under-count when different entries define different buckets' caps).
    """
    from ..kernels.registry import workspace_bytes

    d = len(extras_list)
    dps = sorted({int(b["dp"]) for e in extras_list for b in e["tc_buckets"]})
    buckets = []
    scratch = 0
    for dp in dps:
        per_dev = [
            next((b for b in e["tc_buckets"] if int(b["dp"]) == dp), None)
            for e in extras_list
        ]
        steps = max(int(b["steps"]) for b in per_dev if b is not None)
        cnt = max(
            (int(np.asarray(b["sg"]).shape[0])
             for b in per_dev if b is not None),
            default=0,
        ) or 1
        arrs = {k: np.zeros((d, cnt), np.int64)
                for k in ("sg", "lg", "sb", "lb")}
        for i, b in enumerate(per_dev):
            if b is None:
                continue
            for k in ("sg", "lg", "sb", "lb"):
                v = np.asarray(b[k], dtype=np.int64)
                arrs[k][i, : v.shape[0]] = v
        buckets.append(dict(dp=dp, steps=steps, **arrs))
        scratch += workspace_bytes("csr_bucket_search", items=cnt, depth=dp)
    out = {"tc_buckets": buckets, "__workspace_bytes__": scratch}
    idxs = [e.get("tc_tiles_idx") for e in extras_list]
    if any(x is not None for x in idxs):
        tmax = max(
            (int(np.asarray(x).shape[0]) for x in idxs if x is not None),
            default=0,
        ) or 1
        stacked = np.full((d, tmax, 3), -1, np.int32)
        for i, x in enumerate(idxs):
            if x is None:
                continue
            v = np.asarray(x, dtype=np.int32)
            stacked[i, : v.shape[0]] = v
        out["tc_tiles_idx"] = stacked
    else:
        out["tc_tiles_idx"] = None
    return out


def _kernel_dense(ctx, state, it):
    idx = ctx.extras["tc_tiles_idx"]
    if idx is None:
        return state
    tiles = ctx.tiles
    # rows of -1 are mesh_pack padding (devices with fewer triples than
    # the per-wave max): zero their A_ij mask so they count nothing
    valid = (idx[:, 0] >= 0)
    safe = jnp.maximum(idx, 0)
    a_ij = tiles[safe[:, 0]] * valid[:, None, None].astype(tiles.dtype)
    a_ik = tiles[safe[:, 1]]
    a_jk = tiles[safe[:, 2]]
    cnt = get_kernel("tc_tiles", ctx.backend)(a_ik, a_jk, a_ij)
    return dict(state, nt=state["nt"] + cnt.astype(jnp.int32))


def tc_algorithm() -> BlockAlgorithm:
    return BlockAlgorithm(
        name="triangle_counting",
        mode=Mode.PATTERN,
        blocklist_size=3,
        make_blocklists=_make_blocklists,
        kernel_sparse=_kernel_sparse,
        kernel_dense=_kernel_dense,
        prepare=_prepare,
        stage_plan=_stage_plan,
        mesh_pack=_mesh_pack,
        init_state=lambda store: dict(nt=jnp.asarray(0, jnp.int32)),
        max_iterations=1,
        finalize=lambda store, state: int(jax.device_get(state["nt"])),
        # csr="slice": the membership test reads ctx.indices, with every
        # position computed by _prepare from the (per-wave or per-device
        # rebased) row_block_ptr — so each streamed wave stages only the
        # conformal CSR row ranges its triples touch.  mesh="shard":
        # triples partition cleanly over devices (each triple's count is
        # independent and psums), with mesh_pack unifying the
        # data-dependent bucket ladders across devices
        metadata=dict(combine="add", workspace_kernel="tc_tiles",
                      csr="slice", mesh="shard"),
    )


def triangle_count(g: Graph, p: int = 8, **plan_kw) -> int:
    """End-to-end TC: degree order → DAG orient → block store → plan."""
    from ..core.engine import compile_plan

    dag = orient_dag(g)
    store = build_block_store(dag, p)
    return compile_plan(tc_algorithm(), store, **plan_kw).run().result
