"""Shiloach–Vishkin connected components (paper §3.4, Listing 2).

Bulk-synchronous mode; iterations alternate Hook → Link exactly as the
paper's design ("during the even iterations we do the hooking and during
the odd iterations we do the linking").

* **Hook** (even ``it``): for every edge, if the roots of the endpoints
  differ, hook the greater root onto the smaller.  The paper's guarded
  CAS loop becomes a race-free min-scatter: ``C.at[r1].min(r2)`` applied
  only where ``C[r1] == r1`` (r1 is a root).  ``H`` counts changes.
* **Link** (odd ``it``): pointer jumping ``C[u] ← C[C[u]]`` to a local
  fixpoint (bounded ``lax.while_loop``).

The paper runs hooking on the GPU and linking on CPUs, synchronizing C
between them.  Both steps here are scatter/gather (VPU) shaped, so the
TPU adaptation keeps them on the sparse path; the heterogeneous split
survives as the *step* split rather than a device split (see DESIGN §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functors import BlockAlgorithm, Mode

__all__ = ["sv_algorithm", "shiloach_vishkin"]


def _init(store):
    n = store.n
    return dict(
        C=jnp.arange(n, dtype=jnp.int32),
        H=jnp.asarray(0, jnp.int32),
    )


def _hook(ctx, state):
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    C = state["C"]
    n = C.shape[0]
    cu, cv = C[src], C[dst]
    r1 = jnp.maximum(cu, cv)
    r2 = jnp.minimum(cu, cv)
    is_root = C[r1] == r1
    do = msk & (r1 != r2) & is_root
    tgt = jnp.where(do, r1, n)            # sentinel row n = no-op
    C_pad = jnp.concatenate([C, jnp.asarray([n], jnp.int32)])
    C_new_pad = C_pad.at[tgt].min(r2)
    C_new = C_new_pad[:n]
    h = jnp.sum((C_new != C).astype(jnp.int32))
    return dict(C=C_new, H=state["H"] + h)


def _link(state):
    def body(C):
        return C[C]

    def cond(C):
        return jnp.any(C != C[C])

    C = jax.lax.while_loop(cond, body, state["C"])
    return dict(C=C, H=state["H"])


def _kernel_sparse(ctx, state, it):
    return jax.lax.cond(
        it % 2 == 0,
        lambda s: _hook(ctx, s),
        lambda s: _link(s),
        state,
    )


def _hook_pull(ctx, state):
    # pull orientation: each vertex inspects its reversed arcs
    # (dst, src) instead of (src, dst).  The hook normalizes both
    # endpoints through max/min before scattering, so on the
    # symmetrized arc multiset the min-fold lands bit-identical C —
    # which is exactly the pull contract.
    src, dst, msk = ctx.src, ctx.dst, ctx.sparse_edge_mask
    C = state["C"]
    n = C.shape[0]
    cu, cv = C[dst], C[src]
    r1 = jnp.maximum(cu, cv)
    r2 = jnp.minimum(cu, cv)
    is_root = C[r1] == r1
    do = msk & (r1 != r2) & is_root
    tgt = jnp.where(do, r1, n)            # sentinel row n = no-op
    C_pad = jnp.concatenate([C, jnp.asarray([n], jnp.int32)])
    C_new_pad = C_pad.at[tgt].min(r2)
    C_new = C_new_pad[:n]
    h = jnp.sum((C_new != C).astype(jnp.int32))
    return dict(C=C_new, H=state["H"] + h)


def _kernel_sparse_pull(ctx, state, it):
    return jax.lax.cond(
        it % 2 == 0,
        lambda s: _hook_pull(ctx, s),
        lambda s: _link(s),
        state,
    )


def sv_algorithm(*, max_iters: int = 200) -> BlockAlgorithm:
    def before(host, state, it):
        if it % 2 == 0:  # I_B: reset H before each hooking iteration
            state = dict(state, H=jnp.asarray(0, jnp.int32))
        return state

    def after(host, state, it):
        if it % 2 == 0:
            return state, True  # always follow a hook with a link
        # I_A after the link: continue iff the preceding hook did work
        return state, bool(jax.device_get(state["H"]) > 0)

    return BlockAlgorithm(
        name="shiloach_vishkin",
        mode=Mode.BULK,
        kernel_sparse=_kernel_sparse,
        kernel_sparse_pull=_kernel_sparse_pull,
        init_state=_init,
        before=before,
        after=after,
        max_iterations=max_iters,
        finalize=lambda store, state: np.asarray(state["C"]),
        # mesh="shard": hooks judge roots on iteration-start C, so the
        # min-scatter pmin-folds over any edge partition; H psums the
        # per-device hook counts (same fold streaming already uses)
        metadata=dict(combine=dict(C="min", H="add"),
                      # H counts hooks: large early (pull), tapering to
                      # zero as components settle (back to push)
                      direction=dict(frontier="H"),
                      csr="none", mesh="shard"),
    )


def shiloach_vishkin(store, **plan_kw) -> np.ndarray:
    from ..core.engine import compile_plan

    return compile_plan(sv_algorithm(), store, **plan_kw).run().result
