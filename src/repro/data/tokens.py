"""Deterministic synthetic LM data pipeline.

Produces seeded, reproducible token streams with enough structure that a
model can visibly learn (Zipfian unigrams + a first-order Markov chain),
sharded by (host, step) so every data-parallel worker draws a disjoint
deterministic slice — restart-safe: batch(step) is a pure function, so
resuming from a checkpoint at step k replays the exact stream.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["TokenPipeline", "synthetic_batch"]


def synthetic_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                    *, shard: int = 0, num_shards: int = 1):
    """One (tokens, labels) batch — pure function of (seed, step, shard)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, num_shards])
    )
    b = batch // num_shards
    # Zipf unigram base + Markov "grammar": next ≈ (cur * a + c) mod vocab
    base = rng.zipf(1.3, size=(b, seq + 1)) % vocab
    a = 31
    markov = (base[:, :1] * a + np.cumsum(base, axis=1)[:, :-1]) % vocab
    mix = rng.random((b, seq)) < 0.7
    toks = np.where(mix, markov[:, :seq], base[:, :seq]).astype(np.int32)
    labels = np.where(mix[:, 1:], markov[:, 1:seq], base[:, 1:seq])
    labels = np.concatenate([labels, base[:, seq:seq + 1]], 1).astype(np.int32)
    return dict(tokens=toks, labels=labels)


@dataclass
class TokenPipeline:
    seed: int
    batch: int
    seq: int
    vocab: int
    shard: int = 0
    num_shards: int = 1

    def __call__(self, step: int) -> dict:
        return synthetic_batch(
            self.seed, step, self.batch, self.seq, self.vocab,
            shard=self.shard, num_shards=self.num_shards,
        )

    def iterate(self, start_step: int = 0):
        step = start_step
        while True:
            yield self(step)
            step += 1
