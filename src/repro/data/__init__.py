"""Data substrates: deterministic synthetic token pipeline + graph datasets."""
from .tokens import TokenPipeline, synthetic_batch
from .graphs import benchmark_suite

__all__ = ["TokenPipeline", "synthetic_batch", "benchmark_suite"]
