"""Graph benchmark suite — synthetic stand-ins for the paper's 44-graph
dataset (SuiteSparse/Konect/SNAP classes), scaled to this container."""
from __future__ import annotations

from ..core.graph import Graph, erdos_renyi, grid_road, rmat, star_skew

__all__ = ["benchmark_suite"]


def benchmark_suite(scale: str = "small") -> dict[str, Graph]:
    """Graphs keyed by the paper's seven detailed classes.

    scale: "small" (tests, ~1e4 edges), "bench" (benchmarks, ~1e6 edges).
    """
    if scale == "small":
        return {
            "social": rmat(10, 8, seed=1, name="social"),        # orkut-ish
            "twitter": star_skew(2048, hubs=4, seed=2, name="twitter"),
            "web": rmat(10, 6, a=0.45, b=0.25, c=0.2, seed=3, name="web"),
            "gene": erdos_renyi(4096, 3.0, seed=4, name="gene"),  # kmer-ish
            "road": grid_road(48, name="road"),                   # eu_osm-ish
            "synthA": rmat(9, 16, seed=5, name="myciel-ish"),
            "kron": rmat(10, 16, seed=6, name="kron"),
        }
    return {
        "social": rmat(15, 16, seed=1, name="social"),
        "twitter": star_skew(1 << 15, hubs=6, seed=2, name="twitter"),
        "web": rmat(15, 12, a=0.45, b=0.25, c=0.2, seed=3, name="web"),
        "gene": erdos_renyi(1 << 16, 3.0, seed=4, name="gene"),
        "road": grid_road(256, name="road"),
        "synthA": rmat(14, 24, seed=5, name="myciel-ish"),
        "kron": rmat(15, 16, seed=6, name="kron"),
    }
