"""Common neural building blocks (pure-functional, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "layer_norm", "rope", "apply_rope", "dense_init", "zeros_init",
    "swiglu", "gelu_mlp", "Dtype", "cast",
]


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * r).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def rope(positions, d_head: int, theta: float):
    """Rotary embedding tables for the given positions: (..., d_head/2)."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D). cos/sin: (S, D/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast (S, half) over head dim
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else (1.0 / np.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up) + b_up)
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down


class Dtype:
    """Compute/param dtype policy."""

    def __init__(self, name: str):
        self.param = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]
        self.compute = self.param
        self.accum = jnp.float32
