"""Attention: GQA self-attention (full / sliding-window / causal),
single-token decode against a KV cache, and cross-attention.

All functions are pure; weights come in as a dict produced by
``init_attn``.  The XLA einsum path is the default (used by the dry-run
and CPU tests); the Pallas flash kernel is switchable for TPU runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rope, zeros_init

__all__ = ["init_attn", "self_attention", "decode_attention", "cross_attention",
           "init_cross_attn"]


def init_attn(key, d_model, n_heads, n_kv_heads, d_head, *, bias, dtype):
    ks = jax.random.split(key, 4)
    p = dict(
        wq=dense_init(ks[0], (d_model, n_heads * d_head), dtype),
        wk=dense_init(ks[1], (d_model, n_kv_heads * d_head), dtype),
        wv=dense_init(ks[2], (d_model, n_kv_heads * d_head), dtype),
        wo=dense_init(ks[3], (n_heads * d_head, d_model), dtype),
    )
    if bias:
        p.update(
            bq=jnp.zeros((n_heads * d_head,), dtype),
            bk=jnp.zeros((n_kv_heads * d_head,), dtype),
            bv=jnp.zeros((n_kv_heads * d_head,), dtype),
        )
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, d_head):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, d_head)
    k = k.reshape(b, s, n_kv_heads, d_head)
    v = v.reshape(b, s, n_kv_heads, d_head)
    return q, k, v


def _chunked_sdpa(q, k, v, *, causal, window, block_k: int = 512):
    """Online-softmax attention over kv chunks (flash-attention recurrence
    at the XLA level).  The (S_q × S_k) score matrix never exists as one
    buffer: each scan step produces only an (S_q × block_k) tile whose
    softmax partials fold into running (m, l, acc) — XLA fuses the tile
    chain, so the HBM traffic drops from O(S_q·S_k) score bytes to the
    O(S_q·d) carry (the §Perf memory-term optimization; see
    EXPERIMENTS.md).  Semantics identical to _sdpa (same masking rules).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    bk = min(block_k, t)
    assert t % bk == 0, (t, bk)
    nk = t // bk
    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    kc = k.reshape(b, nk, bk, hkv, d)
    vc = v.reshape(b, nk, bk, hkv, d)
    qpos = jnp.arange(s)[:, None] + (t - s if causal else 0)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, j = inp                                 # (b,bk,hkv,d) ×2
        logits = jnp.einsum(
            "bshgd,bthd->bhgst", qg, kb.astype(jnp.float32)
        )                                               # (b,hkv,g,s,bk)
        kpos = j * bk + jnp.arange(bk)[None, :]
        mask = jnp.ones((s, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m_prev, logits.max(-1))
        p = jnp.where(logits > -1e29, jnp.exp(logits - m_new[..., None]), 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_new = alpha * l_prev + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(nk)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d).astype(q.dtype)


def _sdpa(q, k, v, *, causal, window, q_pos0=0, probs_dtype=None):
    """q: (B,S,H,D); k,v: (B,T,Hkv,D) — grouped to H. Returns (B,S,H,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, s, hkv, group, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= d ** -0.5
    qpos = q_pos0 + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    if probs_dtype is not None:
        # bf16 score chain: halves the dominant (…,S,S) buffer traffic;
        # the softmax max/sum reductions still run in f32 (§Perf lever)
        logits = logits.astype(probs_dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    probs = probs.astype(probs_dtype or v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


def self_attention(p, x, *, n_heads, n_kv_heads, d_head, rope_theta,
                   causal=True, window=0, use_pallas=False, impl="full",
                   probs_dtype=None):
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head)
    if rope_theta:
        cos, sin = rope(jnp.arange(s), d_head, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if use_pallas and not window and d_head % 64 == 0 and s % 128 == 0:
        from ..kernels import ops

        group = n_heads // n_kv_heads
        kr = jnp.repeat(k, group, axis=2)
        vr = jnp.repeat(v, group, axis=2)
        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
            vr.transpose(0, 2, 1, 3), causal=causal,
        ).transpose(0, 2, 1, 3)
    elif impl == "chunked" and s > 512:
        out = _chunked_sdpa(q, k, v, causal=causal, window=window)
    else:
        out = _sdpa(q, k, v, causal=causal, window=window,
                    probs_dtype=probs_dtype)
    out = out.reshape(b, s, n_heads * d_head)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def decode_attention(p, x, cache_k, cache_v, pos, *, n_heads, n_kv_heads,
                     d_head, rope_theta, window=0):
    """One-token decode. x: (B,1,d); cache: (B,T,Hkv,D); pos: scalar index.

    Returns (out (B,1,d), new_cache_k, new_cache_v).  For sliding-window
    layers the cache is a ring buffer of size ``window``.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head)
    if rope_theta:
        cos, sin = rope(pos[None], d_head, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    t = cache_k.shape[1]
    slot = jnp.where(window, pos % jnp.maximum(t, 1), pos)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    hkv = cache_k.shape[2]
    group = n_heads // hkv
    qg = q.reshape(b, 1, hkv, group, d_head)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, cache_k).astype(jnp.float32)
    logits *= d_head ** -0.5
    kpos = jnp.arange(t)
    if window:
        # ring buffer: valid slots are the last `window` positions
        valid = (kpos <= slot) | (pos >= t)
    else:
        valid = kpos <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, cache_v)
    out = out.reshape(b, 1, n_heads * d_head)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), cache_k, cache_v


def init_cross_attn(key, d_model, n_heads, n_kv_heads, d_head, *, dtype):
    p = init_attn(key, d_model, n_heads, n_kv_heads, d_head, bias=False,
                  dtype=dtype)
    p["gate"] = jnp.zeros((), dtype)  # tanh-gated (Llama-3.2-Vision style)
    return p


def cross_attention(p, x, kv_feats, *, n_heads, n_kv_heads, d_head,
                    gated=True):
    """x: (B,S,d) queries; kv_feats: (B,T,d) encoder/vision features."""
    b, s, _ = x.shape
    t = kv_feats.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, n_heads, d_head)
    k = jnp.einsum("btd,dh->bth", kv_feats, p["wk"]).reshape(b, t, n_kv_heads, d_head)
    v = jnp.einsum("btd,dh->bth", kv_feats, p["wv"]).reshape(b, t, n_kv_heads, d_head)
    out = _sdpa(q, k, v, causal=False, window=0)
    out = out.reshape(b, s, n_heads * d_head)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out
