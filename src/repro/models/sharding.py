"""Sharding rules: DP/FSDP/TP/EP/SP over the production mesh.

Mesh axes: ``("data","model")`` single-pod, ``("pod","data","model")``
multi-pod.  Policy:

* **Parameters** — tensor-parallel over ``model`` (attention heads, FFN
  hidden, MoE experts, vocab) and FSDP over ``data`` (the remaining
  large dim).  Across ``pod`` parameters are *replicated* (DP between
  pods, FSDP+TP within a pod) — inter-pod links are the slowest, so
  only gradient all-reduce crosses them.
* **Activations** — batch over (``pod``, ``data``); the residual stream
  is sequence-sharded over ``model`` between blocks (Megatron-SP style:
  norms/elementwise run sequence-parallel, attention/FFN gather what
  they need — GSPMD inserts those collectives from the annotations).
* **Decode caches** — batch over ``data`` when batch ≥ axis, otherwise
  the KV sequence dim is sharded (sequence-parallel decode, used by
  ``long_500k``).

Functions degrade to no-ops without a mesh context, so the same model
code runs single-device tests untouched.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshCtx", "set_mesh_ctx", "get_mesh_ctx", "constrain",
    "param_specs", "named_sharding_tree", "batch_spec", "cache_spec",
]

_CTX: "MeshCtx | None" = None


class MeshCtx:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        self.tp = "model" if "model" in names else None
        self.fsdp = tuple(a for a in ("data",) if a in names)
        self.dp = tuple(a for a in ("pod", "data") if a in names)

    def size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self.mesh.shape[a] for a in axis]))
        return int(self.mesh.shape[axis])


def set_mesh_ctx(mesh: Mesh | None) -> MeshCtx | None:
    global _CTX
    _CTX = MeshCtx(mesh) if mesh is not None else None
    return _CTX


def get_mesh_ctx() -> "MeshCtx | None":
    return _CTX


def _logical_to_axis(ctx: MeshCtx, name):
    if name is None:
        return None
    if name == "dp":
        return ctx.dp if len(ctx.dp) > 1 else (ctx.dp[0] if ctx.dp else None)
    if name == "fsdp":
        return ctx.fsdp if len(ctx.fsdp) > 1 else (ctx.fsdp[0] if ctx.fsdp else None)
    if name == "tp":
        return ctx.tp
    if name == "dp+tp":
        axes = tuple(a for a in (*ctx.dp, ctx.tp) if a)
        return axes
    raise ValueError(name)


def _fits(ctx: MeshCtx, dim: int, axis) -> bool:
    return axis is not None and dim % ctx.size(axis) == 0


def logical_spec(ctx: MeshCtx, shape, logical) -> P:
    """Map logical axis names to mesh axes, dropping non-divisible ones."""
    out = []
    for dim, name in zip(shape, logical):
        ax = _logical_to_axis(ctx, name)
        out.append(ax if _fits(ctx, dim, ax) else None)
    return P(*out)


def constrain(x, logical):
    """with_sharding_constraint with logical names; no-op without a mesh."""
    ctx = _CTX
    if ctx is None:
        return x
    spec = logical_spec(ctx, x.shape, logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )


# ---------------------------------------------------------------- params

# (regex on the flattened param path, logical spec per trailing dims).
# Paths look like "layers/attn/wq", "encoder/layers/mlp/w_up", "embed"...
# Leading stack dims (layer / group) are always unsharded (None).
_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$", ("tp", "fsdp")),              # (V, d)
    (r"(^|/)lm_head$", ("fsdp", "tp")),            # (d, V)
    (r"(^|/)pos_embed$", (None, "fsdp")),          # (S, d)
    (r"/(wq|wk|wv|w_gate|w_up|wz|in_proj|x_proj|ogate|wo_gate|sh_gate|sh_up)$",
     ("fsdp", "tp")),                              # (d, h)
    (r"/(wo|w_down|out_proj|dt_proj|sh_down)$", ("tp", "fsdp")),  # (h, d)
    (r"/router$", ("fsdp", "tp")),                 # (d, E)
    (r"/moe/(w_gate|w_up|w_down)$", ("tp", "fsdp", None)),  # (E, d, f) EP
    (r"/(bq|bk|bv|b_up|ln.*|.*norm.*|gate|dt_bias|d_skip|bf|bi)$", None),
    (r"/(conv_w|a_log)$", None),
    (r"/(wi|wf)$", (None, None)),
    (r"/rz$", (None, None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_param(ctx: MeshCtx, path: str, shape, extra_rules=None) -> P:
    logical = None
    for pat, rule in (list(extra_rules or []) + _RULES):
        if re.search(pat, path):
            logical = rule
            break
    if logical is None:
        # fallback: shard the largest divisible dim over tp, next over fsdp
        if len(shape) == 0:
            return P()
        order = np.argsort(shape)[::-1]
        axes = [None] * len(shape)
        for cand, name in zip(order, ("tp", "fsdp")):
            ax = _logical_to_axis(ctx, name)
            if _fits(ctx, shape[cand], ax):
                axes[cand] = ax
        return P(*axes)
    if len(shape) > len(logical):  # leading stack dims
        logical = (None,) * (len(shape) - len(logical)) + tuple(logical)
    else:
        logical = tuple(logical[-len(shape):]) if len(shape) else ()
    out = []
    for dim, name in zip(shape, logical):
        ax = _logical_to_axis(ctx, name)
        out.append(ax if _fits(ctx, dim, ax) else None)
    return P(*out)


def param_specs(ctx: MeshCtx, params_shapes: Any, extra_rules=None):
    """PartitionSpec tree for a param (or optimizer-state) shape tree.

    ``extra_rules`` prepend to the table (e.g. grouped-MoE makes expert
    weights EP-only: replicated over data, E over model).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(ctx, _path_str(path), leaf.shape,
                                          extra_rules),
        params_shapes,
    )


EP_ONLY_EXPERT_RULES = [
    # grouped-MoE: expert weights are EP-sharded only (E over model),
    # replicated across data — expert einsums become collective-free
    (r"/moe/(w_gate|w_up|w_down)$", ("tp", None, None)),
]


def named_sharding_tree(ctx: MeshCtx, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------- inputs


def batch_spec(ctx: MeshCtx, shape) -> P:
    """Token batches: (B, S) or embedding stubs (B, T, d) — batch over dp."""
    ax = _logical_to_axis(ctx, "dp")
    if not _fits(ctx, shape[0], ax):
        # small-batch fallback: try data only, else replicate
        ax = ctx.fsdp[0] if ctx.fsdp and shape[0] % ctx.size(ctx.fsdp[0]) == 0 else None
    return P(*([ax] + [None] * (len(shape) - 1)))


def cache_spec(ctx: MeshCtx, shape, *, seq_axis: int, batch_axis: int = 1) -> P:
    """KV caches (L, B, T, H, D) or recurrent states (L, B, ...).

    Shard batch over dp when divisible; otherwise shard the sequence axis
    (sequence-parallel decode for long_500k).  Heads over tp if divisible,
    else the sequence axis picks up tp too.
    """
    axes: list = [None] * len(shape)
    dp_ax = _logical_to_axis(ctx, "dp")
    used_tp = False
    if _fits(ctx, shape[batch_axis], dp_ax):
        axes[batch_axis] = dp_ax
    elif seq_axis is not None and _fits(ctx, shape[seq_axis], dp_ax):
        axes[seq_axis] = dp_ax
    # heads (dim -2) over tp
    if len(shape) >= 2 and ctx.tp and shape[-2] % ctx.size(ctx.tp) == 0:
        axes[-2] = ctx.tp
        used_tp = True
    if not used_tp and seq_axis is not None and axes[seq_axis] is None and _fits(
        ctx, shape[seq_axis], ctx.tp
    ):
        axes[seq_axis] = ctx.tp
    elif not used_tp and seq_axis is not None and axes[seq_axis] == dp_ax:
        both = _logical_to_axis(ctx, "dp+tp")
        if _fits(ctx, shape[seq_axis], both):
            axes[seq_axis] = both
    return P(*axes)
