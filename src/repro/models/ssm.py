"""Recurrent sequence mixers: Mamba (S6) for the hybrid family and
mLSTM / sLSTM for the xLSTM family.

All three expose a *sequence* form (used in training/prefill; a
``lax.scan`` over time with ``jax.checkpoint`` chunking so the backward
pass stores only chunk-boundary states) and a *step* form (single-token
decode with explicit carried state — these models have O(1) decode
state, which is what makes the ``long_500k`` shape tractable).

The recurrent scan form is the paper-faithful baseline; the chunkwise
matmul-parallel form of mLSTM is a §Perf iteration (see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = [
    "init_mamba", "mamba_seq", "mamba_step", "mamba_init_state",
    "init_mlstm", "mlstm_seq", "mlstm_step", "mlstm_init_state",
    "init_slstm", "slstm_seq", "slstm_step", "slstm_init_state",
]

_CHUNK = 256  # remat chunk for sequence scans


def _chunked_scan(step_fn, state, xs, length):
    """scan over time with jax.checkpoint per chunk (bounded backward mem)."""
    if length <= _CHUNK or length % _CHUNK != 0:
        return jax.lax.scan(step_fn, state, xs)

    @jax.checkpoint
    def chunk(state, xs_chunk):
        return jax.lax.scan(step_fn, state, xs_chunk)

    n_chunks = length // _CHUNK
    xs_c = jax.tree.map(
        lambda a: a.reshape((n_chunks, _CHUNK) + a.shape[1:]), xs
    )
    state, ys = jax.lax.scan(chunk, state, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((length,) + a.shape[2:]), ys)
    return state, ys


# ======================================================================
# Mamba (S6) — selective state space, diagonal A
# ======================================================================


def init_mamba(key, d_model, d_state, d_conv, *, dtype):
    d_in = d_model  # hybrid branch keeps d_inner == d_model (DESIGN §5)
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    a = np.tile(np.arange(1, d_state + 1, dtype=np.float32), (d_in, 1))
    return dict(
        in_proj=dense_init(ks[0], (d_model, 2 * d_in), dtype),
        conv_w=dense_init(ks[1], (d_conv, d_in), dtype, scale=0.5),
        x_proj=dense_init(ks[2], (d_in, dt_rank + 2 * d_state), dtype),
        dt_proj=dense_init(ks[3], (dt_rank, d_in), dtype),
        dt_bias=jnp.zeros((d_in,), jnp.float32) + 0.5,
        a_log=jnp.asarray(np.log(a)),                 # (d_in, N) f32
        d_skip=jnp.ones((d_in,), jnp.float32),
        out_proj=dense_init(ks[4], (d_in, d_model), dtype),
    )


def _mamba_inputs(p, x, d_state):
    """Shared projections: x (B,S,d) → (u, z, delta, bmat, cmat)."""
    d_in = p["dt_proj"].shape[1]
    dt_rank = p["dt_proj"].shape[0]
    uz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    u, z = jnp.split(uz, 2, axis=-1)                  # (B,S,d_in) each
    # depthwise causal conv over seq
    w = p["conv_w"]                                   # (K, d_in)
    k = w.shape[0]
    upad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        upad[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    u = jax.nn.silu(conv)
    proj = jnp.einsum("bsd,de->bse", u, p["x_proj"])
    dt, b, c = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )                                                  # (B,S,d_in) f32
    return u, z, delta, b.astype(jnp.float32), c.astype(jnp.float32)


def mamba_init_state(batch, d_model, d_state):
    return jnp.zeros((batch, d_model, d_state), jnp.float32)


def mamba_seq(p, x, *, d_state):
    """x: (B,S,d) → (B,S,d); recurrent scan over S."""
    u, z, delta, b, c = _mamba_inputs(p, x, d_state)
    a = -jnp.exp(p["a_log"])                           # (d_in, N)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                     # (B,d_in),(B,d_in),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a[None])       # (B,d_in,N)
        h = da * h + (dt_t * u_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        u.transpose(1, 0, 2),
        delta.transpose(1, 0, 2),
        b.transpose(1, 0, 2),
        c.transpose(1, 0, 2),
    )
    h0 = mamba_init_state(x.shape[0], a.shape[0], d_state)
    _, ys = _chunked_scan(step, h0, xs, x.shape[1])
    y = ys.transpose(1, 0, 2).astype(x.dtype)         # (B,S,d_in)
    y = y + u * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba_seq_assoc(p, x, *, d_state):
    """Mamba via ``lax.associative_scan`` (§Perf iteration for hybrid).

    The diagonal SSM recurrence h_t = a_t ⊙ h_{t-1} + b_t is associative
    in (a, b), so a Blelchloch scan computes all states in O(log S)
    parallel passes over (B,S,d,N) tensors — the per-timestep state
    round-trips of the sequential scan (the dominant HBM term in the
    baseline roofline) collapse into a few full-tensor sweeps, and the
    sequence axis becomes shardable.  Exact same math as ``mamba_seq``.
    """
    u, z, delta, bmat, cmat = _mamba_inputs(p, x, d_state)
    a = -jnp.exp(p["a_log"])                            # (d_in, N)
    da = jnp.exp(delta[..., None] * a[None, None])      # (B,S,d,N)
    bu = (delta * u.astype(jnp.float32))[..., None] * bmat[:, :, None, :]

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(comb, (da, bu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat).astype(x.dtype)
    y = y + u * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba_step(p, x, h, conv_buf, *, d_state):
    """Single-token decode. x: (B,1,d); h: (B,d_in,N); conv_buf: (B,K-1,d_in)."""
    d_in = p["dt_proj"].shape[1]
    dt_rank = p["dt_proj"].shape[0]
    uz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    u, z = jnp.split(uz, 2, axis=-1)
    w = p["conv_w"]
    k = w.shape[0]
    seq = jnp.concatenate([conv_buf, u[:, 0:1, :].astype(conv_buf.dtype)], 1)
    conv = jnp.einsum("bkd,kd->bd", seq[:, -k:, :], w)
    new_buf = seq[:, 1:, :]
    u1 = jax.nn.silu(conv)                             # (B,d_in)
    proj = jnp.einsum("bd,de->be", u1, p["x_proj"])
    dt, b, c = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("br,rd->bd", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(delta[..., None] * a[None])
    h = da * h + (delta * u1.astype(jnp.float32))[..., None] * b.astype(
        jnp.float32
    )[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c.astype(jnp.float32)).astype(x.dtype)
    y = y + u1 * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0])
    return jnp.einsum("bd,de->be", y, p["out_proj"])[:, None, :], h, new_buf


# ======================================================================
# mLSTM — matrix memory with exponential gating (xLSTM)
# ======================================================================


def init_mlstm(key, d_model, n_heads, *, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 6)
    return dict(
        wq=dense_init(ks[0], (d_model, d_model), dtype),
        wk=dense_init(ks[1], (d_model, d_model), dtype),
        wv=dense_init(ks[2], (d_model, d_model), dtype),
        wi=dense_init(ks[3], (d_model, n_heads), jnp.float32, scale=0.01),
        wf=dense_init(ks[4], (d_model, n_heads), jnp.float32, scale=0.01),
        bf=jnp.ones((n_heads,), jnp.float32) * 3.0,   # open forget gates
        bi=jnp.zeros((n_heads,), jnp.float32),
        wo=dense_init(ks[5], (d_model, d_model), dtype),
        ogate=dense_init(jax.random.fold_in(key, 7), (d_model, d_model), dtype),
    )


def mlstm_init_state(batch, n_heads, dh):
    return dict(
        c=jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        n=jnp.zeros((batch, n_heads, dh), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


def _mlstm_gates(p, x):
    i_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"]) + p["bi"]
    f_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"]) + p["bf"]
    return i_pre, f_pre


def _mlstm_qkv(p, x, n_heads):
    b, s, d = x.shape
    dh = d // n_heads
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, n_heads, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, n_heads, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, n_heads, dh)
    return q, k * (dh ** -0.5), v


def _mlstm_cell(state, q_t, k_t, v_t, i_pre, f_pre):
    """One timestep of the stabilized mLSTM recurrence (f32)."""
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(f_pre + m, i_pre)              # log-space stabilizer
    i_g = jnp.exp(i_pre - m_new)[..., None]            # (B,H,1)
    f_g = jnp.exp(f_pre + m - m_new)[..., None]
    n = f_g * n + i_g * k_t
    c = f_g[..., None] * c + i_g[..., None] * (
        v_t[..., :, None] * k_t[..., None, :]
    )                                                  # (B,H,dv,dk)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), jnp.exp(-m_new)
    )[..., None]
    h = jnp.einsum("bhvk,bhk->bhv", c, q_t) / denom
    return dict(c=c, n=n, m=m_new), h


def mlstm_seq_chunked(p, x, *, n_heads, chunk: int = 64):
    """Chunkwise-parallel stabilized mLSTM (§Perf iteration for xlstm).

    The per-timestep recurrence materializes the (B,H,dh,dh) matrix state
    every step — O(S·dh²) HBM traffic that dominated the baseline roofline
    (memory term ~2500s for xlstm×train_4k).  The chunkwise form keeps the
    state only at chunk boundaries and computes intra-chunk interactions
    as (W×dh)·(dh×W) matmuls with a log-space decay mask — O(S·dh²/W)
    state traffic and MXU-shaped compute.  Numerically equivalent to
    ``mlstm_seq`` (same stabilization; tested to ~1e-5).
    """
    b, s, d = x.shape
    h_ = n_heads
    dh = d // h_
    w = min(chunk, s)
    assert s % w == 0
    nc = s // w
    q, k, v = _mlstm_qkv(p, x, n_heads)
    i_pre, f_pre = _mlstm_gates(p, x)                  # (B,S,H) f32

    # chunk views: (nc, B, H, W, dh) / (nc, B, H, W)
    def cview(a):
        if a.ndim == 4:
            return a.reshape(b, nc, w, h_, -1).transpose(1, 0, 3, 2, 4)
        return a.reshape(b, nc, w, h_).transpose(1, 0, 3, 2)

    qc, kc, vc = cview(q.astype(jnp.float32)), cview(k.astype(jnp.float32)), \
        cview(v.astype(jnp.float32))
    ic, fc = cview(i_pre), cview(f_pre)

    def chunk_step(carry, inp):
        c_hat, n_hat, m = carry                       # C·e^{-m}; (B,H,dh,dh)
        qw, kw, vw, iw, fw = inp                      # (B,H,W,*)
        csum = jnp.cumsum(fw, axis=-1)                # F_t within chunk
        ftot = csum[..., -1:]                         # (B,H,1)
        # D[t,τ] = F_t - F_τ + i_τ  (τ ≤ t), else -inf
        dmat = csum[..., :, None] - csum[..., None, :] + iw[..., None, :]
        tri = jnp.tril(jnp.ones((w, w), bool))
        dmat = jnp.where(tri, dmat, -1e30)
        m_intra = dmat.max(-1)                        # (B,H,W)
        m_inter = m[..., None] + csum                 # (B,H,W)
        m_t = jnp.maximum(m_intra, m_inter)
        # intra-chunk scores
        scores = jnp.einsum("bhtd,bhsd->bhts", qw, kw)
        wmat = jnp.where(tri, jnp.exp(dmat - m_t[..., None]), 0.0)
        intra = jnp.einsum("bhts,bhsd->bhtd", scores * wmat, vw)
        intra_n = jnp.sum(scores * wmat, -1)          # (B,H,W)
        # inter-chunk (carry) contribution
        lam = jnp.exp(m_inter - m_t)                  # (B,H,W)
        inter = jnp.einsum("bhvk,bhtk->bhtv", c_hat, qw) * lam[..., None]
        inter_n = jnp.einsum("bhk,bhtk->bht", n_hat, qw) * lam
        denom = jnp.maximum(jnp.abs(inter_n + intra_n), jnp.exp(-m_t))
        h_out = (inter + intra) / denom[..., None]
        # boundary state update
        m_new = jnp.maximum(m + ftot[..., 0],
                            (ftot - csum + iw).max(-1))
        wgt = jnp.exp(ftot - csum + iw - m_new[..., None])   # (B,H,W)
        c_new = (
            jnp.exp(m + ftot[..., 0] - m_new)[..., None, None] * c_hat
            + jnp.einsum("bhtv,bhtk->bhvk", vw * wgt[..., None], kw)
        )
        n_new = (
            jnp.exp(m + ftot[..., 0] - m_new)[..., None] * n_hat
            + jnp.einsum("bht,bhtk->bhk", wgt, kw)
        )
        return (c_new, n_new, m_new), h_out

    c0 = jnp.zeros((b, h_, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h_, dh), jnp.float32)
    m0 = jnp.full((b, h_), -1e30, jnp.float32)
    (_, _, _), hs = jax.lax.scan(chunk_step, (c0, n0, m0), (qc, kc, vc, ic, fc))
    # (nc,B,H,W,dh) → (B,S,d)
    hseq = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["ogate"]))
    return jnp.einsum("bsd,de->bse", hseq * o, p["wo"])


def mlstm_seq(p, x, *, n_heads):
    b, s, d = x.shape
    dh = d // n_heads
    q, k, v = _mlstm_qkv(p, x, n_heads)
    i_pre, f_pre = _mlstm_gates(p, x)

    def step(state, inp):
        q_t, k_t, v_t, ip, fp = inp
        state, h = _mlstm_cell(
            state, q_t.astype(jnp.float32), k_t.astype(jnp.float32),
            v_t.astype(jnp.float32), ip, fp,
        )
        return state, h

    xs = tuple(
        a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
        for a in (q, k, v, i_pre, f_pre)
    )
    _, hs = _chunked_scan(step, mlstm_init_state(b, n_heads, dh), xs, s)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["ogate"]))
    return jnp.einsum("bsd,de->bse", h * o, p["wo"])


def mlstm_step(p, x, state, *, n_heads):
    """x: (B,1,d) single-token decode."""
    b, _, d = x.shape
    q, k, v = _mlstm_qkv(p, x, n_heads)
    i_pre, f_pre = _mlstm_gates(p, x)
    state, h = _mlstm_cell(
        state, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), i_pre[:, 0], f_pre[:, 0],
    )
    h = h.reshape(b, 1, d).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["ogate"]))
    return jnp.einsum("bsd,de->bse", h * o, p["wo"]), state


# ======================================================================
# sLSTM — scalar memory, per-head recurrent connection (xLSTM)
# ======================================================================


def init_slstm(key, d_model, n_heads, *, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 6)
    return dict(
        wz=dense_init(ks[0], (d_model, d_model), dtype),
        wi=dense_init(ks[1], (d_model, n_heads), jnp.float32, scale=0.01),
        wf=dense_init(ks[2], (d_model, n_heads), jnp.float32, scale=0.01),
        wo_gate=dense_init(ks[3], (d_model, d_model), dtype),
        rz=dense_init(ks[4], (n_heads, dh, dh), jnp.float32, scale=0.1),
        bf=jnp.ones((n_heads,), jnp.float32) * 3.0,
        bi=jnp.zeros((n_heads,), jnp.float32),
        wo=dense_init(ks[5], (d_model, d_model), dtype),
    )


def slstm_init_state(batch, n_heads, dh):
    return dict(
        c=jnp.zeros((batch, n_heads, dh), jnp.float32),
        n=jnp.zeros((batch, n_heads, dh), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
        h=jnp.zeros((batch, n_heads, dh), jnp.float32),
    )


def _slstm_cell(p, state, z_in, i_pre, f_pre):
    c, n, m, h_prev = state["c"], state["n"], state["m"], state["h"]
    z = jnp.tanh(z_in + jnp.einsum("bhk,hkj->bhj", h_prev, p["rz"]))
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)[..., None]
    f_g = jnp.exp(f_pre + m - m_new)[..., None]
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h = c / jnp.maximum(n, 1e-6)
    return dict(c=c, n=n, m=m_new, h=h), h


def slstm_seq(p, x, *, n_heads):
    b, s, d = x.shape
    dh = d // n_heads
    z_in = jnp.einsum("bsd,de->bse", x, p["wz"]).reshape(b, s, n_heads, dh)
    i_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"]) + p["bi"]
    f_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"]) + p["bf"]

    def step(state, inp):
        z_t, ip, fp = inp
        return _slstm_cell(p, state, z_t.astype(jnp.float32), ip, fp)

    xs = (z_in.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    _, hs = _chunked_scan(step, slstm_init_state(b, n_heads, dh), xs, s)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"]))
    return jnp.einsum("bsd,de->bse", h * o, p["wo"])


def slstm_step(p, x, state, *, n_heads):
    b, _, d = x.shape
    dh = d // n_heads
    z_in = jnp.einsum("bsd,de->bse", x, p["wz"]).reshape(b, n_heads, dh)
    i_pre = (jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"]) + p["bi"])[:, 0]
    f_pre = (jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"]) + p["bf"])[:, 0]
    state, h = _slstm_cell(p, state, z_in.astype(jnp.float32), i_pre, f_pre)
    h = h.reshape(b, 1, d).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"]))
    return jnp.einsum("bsd,de->bse", h * o, p["wo"]), state
