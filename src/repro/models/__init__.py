"""LM substrate: unified config-driven model covering all assigned families."""
from . import lm, steps, sharding
from .steps import (
    make_train_step, make_serve_step, make_prefill_step, input_specs,
    abstract_params, abstract_opt_state, abstract_decode_state, supports_shape,
)

__all__ = [
    "lm", "steps", "sharding",
    "make_train_step", "make_serve_step", "make_prefill_step", "input_specs",
    "abstract_params", "abstract_opt_state", "abstract_decode_state",
    "supports_shape",
]
