"""Mixture-of-Experts FFN with capacity-based sort-free dispatch.

Top-k routing with a static per-expert capacity C = ceil(T·K/E · cf):
each (token, k) slot computes its position within its expert via a
cumulative count and is scattered into an (E·C, d) buffer; expert FFNs
run as one batched einsum over the expert-sharded buffer; results gather
back weighted by the (renormalized) gates.  Overflowing tokens drop
(standard capacity semantics) — the residual stream carries them.

Under pjit the buffer is sharded (E over 'model', i.e. expert parallel);
the scatter/gather lower to all-to-alls on TPU.  An aux load-balance
loss (Switch-style) and router z-loss are returned for the train step.

This is also the one honest touch point with the paper's scheduling
story: tokens are "tasks", the router's gate is the workload estimate,
and capacity is the cut-off that keeps any single expert (device) from
becoming the bottleneck straggler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from .sharding import constrain

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, d_model, n_experts, moe_d_ff, n_shared, *, dtype):
    ks = jax.random.split(key, 7)
    p = dict(
        router=dense_init(ks[0], (d_model, n_experts), jnp.float32),
        w_gate=dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype),
        w_up=dense_init(ks[2], (n_experts, d_model, moe_d_ff), dtype),
        w_down=dense_init(ks[3], (n_experts, moe_d_ff, d_model), dtype),
    )
    if n_shared:
        f = moe_d_ff * n_shared
        p.update(
            sh_gate=dense_init(ks[4], (d_model, f), dtype),
            sh_up=dense_init(ks[5], (d_model, f), dtype),
            sh_down=dense_init(ks[6], (f, d_model), dtype),
        )
    return p


def _grouped_moe(p, xf, *, top_k, capacity_factor):
    """Switch-style grouped-local dispatch (§Perf round 3).

    The global-cumsum dispatch scatters every dp shard's tokens into ONE
    shared (E·C, d) buffer — GSPMD merges the per-shard partials with an
    all-reduce of the whole capacity buffer every layer (measured 10.5 TB
    per chip on qwen3-moe×train_4k).  Grouped dispatch gives each data
    shard its own capacity slice: positions are a per-group cumsum, the
    scatter/gather are shard-local, and expert weights live EP-only
    (E over 'model', replicated over 'data'), so the expert einsums are
    collective-free; only the token-sized reshard crosses the mesh.
    """
    from .sharding import get_mesh_ctx

    t, d = xf.shape
    e = p["router"].shape[1]
    ctx = get_mesh_ctx()
    g_sz = 1
    if ctx is not None and ctx.dp:
        g_sz = ctx.size(ctx.dp if len(ctx.dp) > 1 else ctx.dp[0])
    if t % g_sz:
        g_sz = 1
    tg = t // g_sz
    xg = constrain(xf.reshape(g_sz, tg, d), ("dp", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)               # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, round(tg * top_k / e * capacity_factor)))
    flat_e = idx.transpose(0, 2, 1).reshape(g_sz, -1)          # (G, K*Tg)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot                  # per-group
    my_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = my_pos < cap
    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)

    xk = jnp.tile(xg, (1, top_k, 1))                           # (G,K*Tg,d)
    gi = jnp.arange(g_sz)[:, None]
    buf = jnp.zeros((g_sz, e * cap + 1, d), xf.dtype).at[gi, slot].add(xk)
    buf = buf[:, :-1].reshape(g_sz, e, cap, d)
    buf = constrain(buf, ("dp", "tp", None, None))

    gg = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gg) * uu, p["w_down"])
    y = constrain(y, ("dp", "tp", None, None))

    yf = y.reshape(g_sz, e * cap, d)
    yf = jnp.concatenate([yf, jnp.zeros((g_sz, 1, d), y.dtype)], axis=1)
    gathered = yf[gi, slot]                                    # (G,K*Tg,d)
    w = (gate_vals.transpose(0, 2, 1).reshape(g_sz, -1) * keep).astype(xf.dtype)
    out = (gathered * w[..., None]).reshape(g_sz, top_k, tg, d).sum(1)
    out = out.reshape(t, d)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0].reshape(-1), e, dtype=jnp.float32), 0
    )
    frac_probs = probs.reshape(-1, e).mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return out, dict(load_balance=aux, z_loss=zloss)


def _manual_moe(p, xf, *, top_k, capacity_factor):
    """Manual-collective EP dispatch via shard_map (§Perf round 5).

    Every GSPMD-annotation attempt (rounds 2–4) was refuted: the SPMD
    partitioner resolves the capacity-buffer redistribution into
    whole-buffer all-gathers/all-reduces (measured 12–78 TB/chip wire
    bytes).  This path takes the collectives out of GSPMD's hands:

    * tokens are dp-sharded, **replicated over 'model'**, so every model
      shard computes the same routing locally (no dispatch communication
      at all — the paper-scheduler analogy: every worker sees the same
      task list and claims its own slice);
    * each model shard owns E/tp experts (EP-only weights) and builds
      the capacity buffer for *its* experts from *its* dp-local tokens —
      a purely local scatter;
    * expert FFNs run local; the only cross-shard traffic is ONE psum
      over 'model' of the token-sized combine (+ the usual grad sync).

    Requires a mesh context; falls back to "auto" without one.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .sharding import get_mesh_ctx

    ctx = get_mesh_ctx()
    t, d = xf.shape
    e = p["router"].shape[1]
    if ctx is None or ctx.tp is None or e % ctx.size(ctx.tp):
        return None  # caller falls back
    dp_axes = ctx.dp if len(ctx.dp) > 1 else (ctx.dp[0] if ctx.dp else None)
    dp_sz = ctx.size(dp_axes)
    tp = ctx.tp
    tp_sz = ctx.size(tp)
    e_local = e // tp_sz
    if t % dp_sz:
        return None
    t_local = t // dp_sz
    cap = int(max(1, round(t_local * top_k / e * capacity_factor)))

    def local_fn(x_loc, router, wg, wu, wd):
        tl = x_loc.shape[0]
        logits = jnp.einsum("td,de->te", x_loc.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
        flat_e = idx.T.reshape(-1)                       # (K*tl,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = my_pos < cap
        m_idx = jax.lax.axis_index(tp)
        mine = (flat_e // e_local) == m_idx              # expert on this shard
        le = flat_e % e_local
        slot = jnp.where(keep & mine, le * cap + my_pos, e_local * cap)
        xk = jnp.tile(x_loc, (top_k, 1))
        buf = jnp.zeros((e_local * cap + 1, d), x_loc.dtype).at[slot].add(xk)
        buf = buf[:-1].reshape(e_local, cap, d)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        yf = jnp.concatenate(
            [y.reshape(e_local * cap, d), jnp.zeros((1, d), y.dtype)]
        )
        gathered = yf[slot]                              # zeros off-shard
        w = (gate_vals.T.reshape(-1) * keep).astype(x_loc.dtype)
        out = (gathered * w[:, None]).reshape(top_k, tl, d).sum(0)
        out = jax.lax.psum(out, tp)                      # combine experts
        frac_tokens = jnp.mean(
            jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), 0
        )
        aux = e * jnp.sum(frac_tokens * probs.mean(0))
        zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
        if dp_axes is not None:
            aux = jax.lax.pmean(aux, dp_axes)
            zloss = jax.lax.pmean(zloss, dp_axes)
        return out, aux, zloss

    out, aux, zloss = shard_map(
        local_fn,
        mesh=ctx.mesh,
        in_specs=(P(dp_axes, None), P(), P(tp, None, None),
                  P(tp, None, None), P(tp, None, None)),
        out_specs=(P(dp_axes, None), P(), P()),
        check_rep=False,
    )(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, dict(load_balance=aux, z_loss=zloss)


def moe_ffn(p, x, *, top_k, capacity_factor=1.25, dispatch_sharding="auto"):
    """x: (B, S, d) → (y, aux) with aux = load-balance + z losses."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    if dispatch_sharding == "manual":
        res = _manual_moe(p, xf, top_k=top_k, capacity_factor=capacity_factor)
        if res is not None:
            out, aux = res
            if "sh_gate" in p:
                gs = jnp.einsum("td,df->tf", xf, p["sh_gate"])
                us = jnp.einsum("td,df->tf", xf, p["sh_up"])
                out = out + jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us,
                                       p["sh_down"])
            return out.reshape(b, s, d), aux
        dispatch_sharding = "auto"  # no mesh → fall through
    if dispatch_sharding == "grouped":
        out, aux = _grouped_moe(p, xf, top_k=top_k,
                                capacity_factor=capacity_factor)
        if "sh_gate" in p:
            gs = jnp.einsum("td,df->tf", xf, p["sh_gate"])
            us = jnp.einsum("td,df->tf", xf, p["sh_up"])
            out = out + jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us,
                                   p["sh_down"])
        return out.reshape(b, s, d), aux
    if dispatch_sharding == "tokens_dp":
        # untangle SP: token dim purely data-parallel, d replicated — the
        # dispatch scatter/gather become dp-local and the expert einsum
        # contracts an UNsharded d (kills the per-layer all-reduce; the
        # token↔expert movement becomes one all-to-all). See §Perf.
        xf = constrain(xf, ("dp", None))
    e = p["router"].shape[1]

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, top_k)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )                                                          # renormalize

    cap = int(max(1, round(t * top_k / e * capacity_factor)))
    if dispatch_sharding == "ep" and cap > 256:
        cap = ((cap + 255) // 256) * 256  # divisible for (tp, dp) sharding
    # position of each (t, k) inside its expert: cumulative count over the
    # flattened (k-major) slot order
    flat_e = idx.T.reshape(-1)                                  # (K*T,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (K*T, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # count before me
    my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < cap
    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)      # sentinel drop

    xk = jnp.tile(xf, (top_k, 1))                               # (K*T, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(xk)
    buf = buf[:-1].reshape(e, cap, d)
    if dispatch_sharding == "ep":
        # experts over the TP axis, capacity rows over DP: the scatter
        # becomes one all-to-all instead of gather+all-reduce chains
        buf = constrain(buf, ("tp", "dp", None))
    elif dispatch_sharding == "tokens_dp":
        buf = constrain(buf, ("tp", None, None))  # pure EP on experts

    # expert FFN (SwiGLU) — expert-parallel einsum
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])

    yf = y.reshape(e * cap, d)
    yf = jnp.concatenate([yf, jnp.zeros((1, d), y.dtype)])      # sentinel row
    gathered = yf[slot]                                         # (K*T, d)
    w = (gate_vals.T.reshape(-1) * keep).astype(x.dtype)        # (K*T,)
    out = (gathered * w[:, None]).reshape(top_k, t, d).sum(0)

    if "sh_gate" in p:
        gs = jnp.einsum("td,df->tf", xf, p["sh_gate"])
        us = jnp.einsum("td,df->tf", xf, p["sh_up"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us, p["sh_down"])

    # aux losses: Switch load-balance + router z-loss
    frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), 0)
    frac_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return out.reshape(b, s, d), dict(load_balance=aux, z_loss=zloss)
