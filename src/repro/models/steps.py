"""Train / serve steps + input_specs — the dry-run and driver contract.

``make_train_step(cfg)`` returns a pure function
``(params, opt_state, batch, step) -> (params, opt_state, metrics)``
(loss → grad → AdamW, all inside one jit).  ``make_serve_step(cfg)``
returns ``(params, state, tokens[, stubs]) -> (logits, state)``.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for
every model input of an (arch × shape) cell — weak-type-correct,
shardable, no device allocation — and ``abstract_params``/
``abstract_opt_state``/``abstract_decode_state`` give the state trees
the same way (via ``jax.eval_shape``), so a full production-mesh
``lower().compile()`` never materializes a byte.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from ..optim import adamw_init, adamw_update, cosine_schedule
from . import lm
from .common import Dtype

__all__ = [
    "make_train_step", "make_serve_step", "input_specs",
    "abstract_params", "abstract_opt_state", "abstract_decode_state",
    "supports_shape",
]


def supports_shape(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: which (arch × shape) cells are defined."""
    if shape.name == "long_500k":
        if cfg.family not in ("hybrid", "ssm"):
            return False, (
                "long_500k needs sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (see DESIGN §5)"
            )
    return True, ""


# ---------------------------------------------------------------- inputs


def _token_spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    dt = Dtype(cfg.dtype).param
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = dict(
            tokens=_token_spec((b, s)),
            labels=_token_spec((b, s)),
        )
        if cfg.family == "vlm":
            batch["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.d_model), dt
            )
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), dt
            )
        return batch
    # decode: one new token against a seq_len-deep cache
    out = dict(tokens=_token_spec((b,)))
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct((b, cfg.vision_tokens, cfg.d_model), dt)
    if cfg.is_encdec:
        out["memory"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), dt)
    return out


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.key(0))
    )


def abstract_opt_state(cfg: ArchConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(adamw_init, params)


def abstract_decode_state(cfg: ArchConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: lm.init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )


# ----------------------------------------------------------------- steps


def make_train_step(cfg: ArchConfig, *, base_lr=3e-4, total_steps=10_000,
                    warmup_steps=200, use_pallas=False, grad_compress=False,
                    microbatch: int = 0):
    sched = cosine_schedule(base_lr, warmup_steps, total_steps)

    def loss_fn(params, batch):
        return lm.forward_loss(cfg, params, batch, use_pallas=use_pallas)

    def train_step(params, opt_state, batch, step):
        if microbatch and microbatch > 1:
            # gradient accumulation over microbatches via scan
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatch, b // microbatch) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(acc, mbatch):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch
                )
                acc_g, acc_l = acc
                return (
                    jax.tree.map(jnp.add, acc_g, g),
                    acc_l + l,
                ), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zero_g, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = lsum / microbatch
            metrics = dict(loss=loss, nll=loss)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        lr = sched(step)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr=lr
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, use_pallas=False):
    """Forward-only loss eval at prefill shape (inference-prefill cell)."""

    def prefill_step(params, batch):
        loss, metrics = lm.forward_loss(cfg, params, batch,
                                        use_pallas=use_pallas)
        return metrics

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, batch):
        logits, state = lm.decode_step(
            cfg, params, state, batch["tokens"],
            memory=batch.get("memory"), vision=batch.get("vision"),
        )
        return logits, state

    return serve_step
