"""Unified LM: one config-driven model covering all six assigned families.

Layer stacks are *stacked pytrees* scanned with ``lax.scan`` (compact
HLO, O(1) compile cost in depth) and rematerialized per layer
(``jax.checkpoint``).  Families:

* dense   — pre-RMSNorm GQA + (SwiGLU | GELU) MLP, RoPE, optional QKV bias
* moe     — GQA + capacity-routed MoE FFN (+ optional shared experts)
* hybrid  — Hymba macro: parallel sliding-window attention + Mamba branch
            sharing the layer input, then MLP
* ssm     — xLSTM: mLSTM blocks with every ``slstm_every``-th an sLSTM
* vlm     — dense decoder; every ``cross_attn_every``-th layer carries a
            gated cross-attention to (stub) vision patch embeddings.
            Implemented as a two-level scan (groups × sublayers) so only
            cross layers own cross-attn parameters.
* audio   — Whisper enc-dec: bidirectional encoder over (stub) frame
            embeddings, causal decoder with per-layer cross-attention.

Training loss is computed in sequence chunks (never materializes the
full (B,S,V) logits).  Decode carries per-layer caches/states stacked on
a leading layer dim; recurrent families have O(1) decode state, which is
what makes ``long_500k`` feasible (see DESIGN §5).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from jax.ad_checkpoint import checkpoint_name

from ..configs.base import ArchConfig
from .attention import (
    cross_attention, decode_attention, init_attn, init_cross_attn,
    self_attention,
)
from .common import Dtype, dense_init, gelu_mlp, layer_norm, rms_norm, swiglu
from .moe import init_moe, moe_ffn
from .sharding import constrain
from .ssm import (
    init_mamba, init_mlstm, init_slstm,
    mamba_init_state, mamba_seq, mamba_seq_assoc, mamba_step,
    mlstm_init_state, mlstm_seq, mlstm_seq_chunked, mlstm_step,
    slstm_init_state, slstm_seq, slstm_step,
)

__all__ = ["init_params", "forward_loss", "init_decode_state", "decode_step"]

LOSS_CHUNK = 512


# ======================================================================
# init
# ======================================================================


def _init_mlp(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    if cfg.mlp_type == "swiglu":
        k3 = jax.random.fold_in(key, 3)
        return dict(
            w_gate=dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
            w_up=dense_init(ks[1], (cfg.d_model, cfg.d_ff), dtype),
            w_down=dense_init(k3, (cfg.d_ff, cfg.d_model), dtype),
        )
    return dict(
        w_up=dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
        b_up=jnp.zeros((cfg.d_ff,), dtype),
        w_down=dense_init(ks[1], (cfg.d_ff, cfg.d_model), dtype),
        b_down=jnp.zeros((cfg.d_model,), dtype),
    )


def _init_layer(key, cfg: ArchConfig, dtype):
    """One decoder layer's params (without VLM cross-attn)."""
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = dict(ln1=jnp.ones((cfg.d_model,), dtype))
    if cfg.family == "ssm":
        p["mlstm"] = init_mlstm(ks[0], cfg.d_model, cfg.n_heads, dtype=dtype)
        p["slstm"] = init_slstm(ks[1], cfg.d_model, cfg.n_heads, dtype=dtype)
        return p
    p["attn"] = init_attn(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
        bias=cfg.qkv_bias, dtype=dtype,
    )
    p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "hybrid":
        p["mamba"] = init_mamba(ks[1], cfg.d_model, cfg.ssm_state,
                                cfg.ssm_conv, dtype=dtype)
    if cfg.n_experts:
        p["moe"] = init_moe(ks[2], cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                            cfg.n_shared_experts, dtype=dtype)
    else:
        p["mlp"] = _init_mlp(ks[3], cfg, dtype)
    return p


def _stack(fn, keys):
    return jax.vmap(fn)(keys)


def init_params(cfg: ArchConfig, key) -> dict:
    dt = Dtype(cfg.dtype)
    dtype = dt.param
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = dict(
        embed=dense_init(keys[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        final_norm=jnp.ones((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab), dtype
        )

    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.n_layers // g
        lkeys = jax.random.split(keys[2], n_groups * g).reshape(n_groups, g)
        params["layers"] = jax.vmap(
            lambda gk: jax.vmap(lambda k: _init_layer(k, cfg, dtype))(gk)
        )(lkeys)
        xkeys = jax.random.split(keys[3], n_groups)
        params["xattn"] = jax.vmap(
            lambda k: dict(
                ln=jnp.ones((cfg.d_model,), dtype),
                attn=init_cross_attn(k, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.d_head, dtype=dtype),
            )
        )(xkeys)
    else:
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = _stack(lambda k: _init_layer(k, cfg, dtype), lkeys)

    if cfg.is_encdec:
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return dict(
                ln1=jnp.ones((cfg.d_model,), dtype),
                ln1_b=jnp.zeros((cfg.d_model,), dtype),
                attn=init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.d_head, bias=cfg.qkv_bias, dtype=dtype),
                ln2=jnp.ones((cfg.d_model,), dtype),
                ln2_b=jnp.zeros((cfg.d_model,), dtype),
                mlp=_init_mlp(k2, cfg, dtype),
            )

        params["encoder"] = _stack(enc_layer, ekeys)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["enc_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
        params["enc_pos"] = dense_init(
            keys[5], (cfg.encoder_frames, cfg.d_model), dtype, scale=0.02
        )
        xkeys = jax.random.split(keys[6], cfg.n_layers)
        params["dec_xattn"] = _stack(
            lambda k: dict(
                ln=jnp.ones((cfg.d_model,), dtype),
                attn=init_cross_attn(k, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.d_head, dtype=dtype),
            ),
            xkeys,
        )
    return params


# ======================================================================
# layer application
# ======================================================================


def _apply_mlp(cfg, p, x):
    if cfg.mlp_type == "swiglu":
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return gelu_mlp(x, p["w_up"], p["b_up"], p["w_down"], p["b_down"])


def _decoder_layer(cfg: ArchConfig, p, h, aux, *, use_pallas, layer_flag=None):
    """One decoder layer (train/prefill form). Returns (h, aux)."""
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               d_head=cfg.d_head, rope_theta=cfg.rope_theta)
    if cfg.family == "ssm":
        x = rms_norm(h, p["ln1"])

        def do_mlstm(x):
            if cfg.mlstm_impl == "chunked":
                return mlstm_seq_chunked(p["mlstm"], x, n_heads=cfg.n_heads,
                                         chunk=cfg.mlstm_chunk)
            return mlstm_seq(p["mlstm"], x, n_heads=cfg.n_heads)

        def do_slstm(x):
            return slstm_seq(p["slstm"], x, n_heads=cfg.n_heads)

        out = jax.lax.cond(layer_flag, do_slstm, do_mlstm, x)
        return h + out, aux

    x = rms_norm(h, p["ln1"])
    attn_out = self_attention(
        p["attn"], x, causal=True, window=cfg.attn_window,
        use_pallas=use_pallas, impl=cfg.attn_impl,
        probs_dtype=jnp.bfloat16 if cfg.attn_probs_dtype == "bfloat16" else None,
        **akw,
    )
    # selective recompute: optionally keep attention outputs across the
    # backward pass so the O(S²) score chain runs once, not twice
    attn_out = checkpoint_name(attn_out, "attn_out")
    if cfg.family == "hybrid":
        mamba_fn = mamba_seq_assoc if cfg.mamba_impl == "assoc" else mamba_seq
        attn_out = attn_out + mamba_fn(p["mamba"], x, d_state=cfg.ssm_state)
        attn_out = attn_out * 0.5  # Hymba mean-fuses the parallel branches
    h = h + attn_out
    h = constrain(h, ("dp", "tp", None))
    x = rms_norm(h, p["ln2"])
    if cfg.n_experts:
        y, moe_aux = moe_ffn(p["moe"], x, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             dispatch_sharding=cfg.moe_dispatch_sharding)
        aux = jax.tree.map(lambda a, b: a + b, aux, moe_aux) if aux else moe_aux
    else:
        y = _apply_mlp(cfg, p["mlp"], x)
    h = h + y
    return constrain(h, ("dp", "tp", None)), aux


def _zero_aux(cfg):
    if cfg.n_experts:
        return dict(load_balance=jnp.zeros((), jnp.float32),
                    z_loss=jnp.zeros((), jnp.float32))
    return None


def _remat(cfg, fn):
    if cfg.remat_policy == "save_attn":
        policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_decoder(cfg: ArchConfig, params, h, *, vision=None, memory=None,
                 use_pallas=False):
    """Scan the decoder stack. h: (B,S,d) embeddings."""
    aux0 = _zero_aux(cfg)

    if cfg.family == "vlm":
        def group_body(carry, layer):
            h, aux = carry
            gp, xp = layer
            x = rms_norm(h, xp["ln"])
            h = h + cross_attention(
                xp["attn"], x, vision, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            )

            def sub_body(carry, lp):
                h, aux = carry
                h, aux = _decoder_layer(cfg, lp, h, aux, use_pallas=use_pallas)
                return (h, aux), None

            (h, aux), _ = jax.lax.scan(sub_body, (h, aux), gp)
            return (h, aux), None

        body = _remat(cfg, group_body)
        (h, aux), _ = jax.lax.scan(
            body, (h, aux0), (params["layers"], params["xattn"])
        )
        return h, aux

    if cfg.is_encdec:
        def dec_body(carry, layer):
            h, aux = carry
            lp, xp = layer
            h, aux = _decoder_layer(cfg, lp, h, aux, use_pallas=use_pallas)
            x = rms_norm(h, xp["ln"])
            h = h + cross_attention(
                xp["attn"], x, memory, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, gated=False,
            )
            return (h, aux), None

        body = _remat(cfg, dec_body)
        (h, aux), _ = jax.lax.scan(
            body, (h, aux0), (params["layers"], params["dec_xattn"])
        )
        return h, aux

    flags = None
    if cfg.family == "ssm":
        k = max(cfg.slstm_every, 1)
        flags = jnp.asarray(
            [(i % k == k - 1) and cfg.slstm_every > 0
             for i in range(cfg.n_layers)]
        )

    def body(carry, layer):
        h, aux = carry
        if flags is not None:
            lp, flag = layer
            h, aux = _decoder_layer(cfg, lp, h, aux, use_pallas=use_pallas,
                                    layer_flag=flag)
        else:
            h, aux = _decoder_layer(cfg, layer, h, aux, use_pallas=use_pallas)
        return (h, aux), None

    body = _remat(cfg, body)
    xs = (params["layers"], flags) if flags is not None else params["layers"]
    (h, aux), _ = jax.lax.scan(body, (h, aux0), xs)
    return h, aux


def _run_encoder(cfg: ArchConfig, params, frames):
    """Whisper encoder over (stub) frame embeddings (B,F,d)."""
    h = frames + params["enc_pos"][None, : frames.shape[1]]

    def body(h, lp):
        x = layer_norm(h, lp["ln1"], lp["ln1_b"])
        h = h + self_attention(
            lp["attn"], x, causal=False, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, rope_theta=0.0,
            impl=cfg.attn_impl,
        )
        x = layer_norm(h, lp["ln2"], lp["ln2_b"])
        return h + _apply_mlp(cfg, lp["mlp"], x), None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, params["encoder"])
    return layer_norm(h, params["enc_norm"], params["enc_norm_b"])


# ======================================================================
# training forward: chunked cross-entropy
# ======================================================================


def _lm_head(cfg, params):
    return (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )


def _chunked_loss(cfg, params, h, labels):
    """h: (B,S,d), labels: (B,S) → mean NLL without full logits."""
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk if cfg.loss_chunk > 0 else LOSS_CHUNK, s)
    n_chunks = s // chunk
    head = _lm_head(cfg, params)
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        hx, lx = inp                                   # (B,chunk,d),(B,chunk)
        logits = jnp.einsum("bsd,dv->bsv", hx, head).astype(jnp.float32)
        logits = constrain(logits, ("dp", None, "tp"))
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)


def forward_logits(cfg: ArchConfig, params, batch, *, use_pallas=False):
    """Full (B,S,V) logits — test/eval only (training uses chunked loss)."""
    tokens = batch["tokens"]
    emb = jnp.take(params["embed"], tokens, axis=0)
    memory = None
    if cfg.is_encdec:
        memory = _run_encoder(cfg, params, batch["frames"])
    h, _ = _run_decoder(
        cfg, params, emb, vision=batch.get("vision"), memory=memory,
        use_pallas=use_pallas,
    )
    h = rms_norm(h, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", h, _lm_head(cfg, params)).astype(jnp.float32)


def forward_loss(cfg: ArchConfig, params, batch, *, use_pallas=False):
    """batch: tokens (B,S), labels (B,S) [+ vision/frames stubs].

    Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    emb = jnp.take(params["embed"], tokens, axis=0)
    emb = constrain(emb, ("dp", "tp", None))
    memory = None
    if cfg.is_encdec:
        memory = _run_encoder(cfg, params, batch["frames"])
    h, aux = _run_decoder(
        cfg, params, emb, vision=batch.get("vision"), memory=memory,
        use_pallas=use_pallas,
    )
    h = rms_norm(h, params["final_norm"])
    loss = _chunked_loss(cfg, params, h, batch["labels"])
    metrics = dict(nll=loss)
    if aux:
        loss = loss + 0.01 * aux["load_balance"] + 0.001 * aux["z_loss"]
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


# ======================================================================
# decode (single-token serve step)
# ======================================================================


def _layer_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int):
    """Per-layer decode cache/state ShapeDtypeStructs (leading L stacked)."""
    dt = Dtype(cfg.dtype).param
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    t = min(cfg.attn_window, seq_len) if cfg.attn_window else seq_len
    c: dict[str, Any] = {}
    if cfg.family == "ssm":
        dhh = cfg.d_model // cfg.n_heads
        c["mlstm"] = dict(
            c=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, dhh, dhh), jnp.float32),
            n=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, dhh), jnp.float32),
            m=jnp.full((cfg.n_layers, batch, cfg.n_heads), -1e30, jnp.float32),
        )
        c["slstm"] = dict(
            c=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, dhh), jnp.float32),
            n=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, dhh), jnp.float32),
            m=jnp.full((cfg.n_layers, batch, cfg.n_heads), -1e30, jnp.float32),
            h=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, dhh), jnp.float32),
        )
        return c
    c["k"] = jnp.zeros((cfg.n_layers, batch, t, hkv, dh), dt)
    c["v"] = jnp.zeros((cfg.n_layers, batch, t, hkv, dh), dt)
    if cfg.family == "hybrid":
        c["mamba_h"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.d_model, cfg.ssm_state), jnp.float32
        )
        c["mamba_conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_model), dt
        )
    return c


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    return dict(
        cache=_layer_cache_shapes(cfg, batch, seq_len),
        pos=jnp.zeros((), jnp.int32),
    )


def _decode_layer(cfg, p, h, cache_l, pos, *, layer_flag=None):
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               d_head=cfg.d_head, rope_theta=cfg.rope_theta)
    new_cache = dict(cache_l)
    if cfg.family == "ssm":
        x = rms_norm(h, p["ln1"])

        def do_mlstm(args):
            x, st = args
            out, ns = mlstm_step(p["mlstm"], x, st["mlstm"], n_heads=cfg.n_heads)
            return out, dict(st, mlstm=ns)

        def do_slstm(args):
            x, st = args
            out, ns = slstm_step(p["slstm"], x, st["slstm"], n_heads=cfg.n_heads)
            return out, dict(st, slstm=ns)

        out, new_cache = jax.lax.cond(layer_flag, do_slstm, do_mlstm,
                                      (x, cache_l))
        return h + out, new_cache

    x = rms_norm(h, p["ln1"])
    attn_out, k, v = decode_attention(
        p["attn"], x, cache_l["k"], cache_l["v"], pos,
        window=cfg.attn_window, **akw,
    )
    new_cache["k"], new_cache["v"] = k, v
    if cfg.family == "hybrid":
        m_out, mh, mconv = mamba_step(
            p["mamba"], x, cache_l["mamba_h"], cache_l["mamba_conv"],
            d_state=cfg.ssm_state,
        )
        new_cache["mamba_h"], new_cache["mamba_conv"] = mh, mconv
        attn_out = (attn_out + m_out) * 0.5
    h = h + attn_out
    x = rms_norm(h, p["ln2"])
    if cfg.n_experts:
        y, _ = moe_ffn(p["moe"], x, top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor,
                       dispatch_sharding=cfg.moe_dispatch_sharding)
    else:
        y = _apply_mlp(cfg, p["mlp"], x)
    return h + y, new_cache


def decode_step(cfg: ArchConfig, params, state, tokens, *, memory=None,
                vision=None):
    """One decode step. tokens: (B,) int32 → (logits (B,V), new state)."""
    pos = state["pos"]
    h = jnp.take(params["embed"], tokens[:, None], axis=0)
    h = constrain(h, ("dp", None, None))

    if cfg.family == "vlm":
        g = cfg.cross_attn_every

        def group_body(h, layer):
            gp, xp, gcache = layer
            x = rms_norm(h, xp["ln"])
            h = h + cross_attention(
                xp["attn"], x, vision, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            )

            def sub(h, sub_layer):
                lp, lcache = sub_layer
                h, nc = _decode_layer(cfg, lp, h, lcache, pos)
                return h, nc

            h, new_gcache = jax.lax.scan(sub, h, (gp, gcache))
            return h, new_gcache

        cache = state["cache"]
        n_groups = cfg.n_layers // g
        gcaches = jax.tree.map(
            lambda a: a.reshape((n_groups, g) + a.shape[1:]), cache
        )
        h, new_gc = jax.lax.scan(
            group_body, h, (params["layers"], params["xattn"], gcaches)
        )
        new_cache = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_gc
        )
    elif cfg.is_encdec:
        def body(h, layer):
            lp, xp, lcache = layer
            h, nc = _decode_layer(cfg, lp, h, lcache, pos)
            x = rms_norm(h, xp["ln"])
            h = h + cross_attention(
                xp["attn"], x, memory, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, gated=False,
            )
            return h, nc

        h, new_cache = jax.lax.scan(
            body, h, (params["layers"], params["dec_xattn"], state["cache"])
        )
    else:
        flags = None
        if cfg.family == "ssm":
            k = max(cfg.slstm_every, 1)
            flags = jnp.asarray(
                [(i % k == k - 1) and cfg.slstm_every > 0
                 for i in range(cfg.n_layers)]
            )

        def body(h, layer):
            if flags is not None:
                lp, lcache, flag = layer
                h, nc = _decode_layer(cfg, lp, h, lcache, pos, layer_flag=flag)
            else:
                lp, lcache = layer
                h, nc = _decode_layer(cfg, lp, h, lcache, pos)
            return h, nc

        xs = (
            (params["layers"], state["cache"], flags)
            if flags is not None
            else (params["layers"], state["cache"])
        )
        h, new_cache = jax.lax.scan(body, h, xs)

    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, _lm_head(cfg, params))
    logits = constrain(logits, ("dp", None, "tp"))
    return logits[:, 0].astype(jnp.float32), dict(cache=new_cache, pos=pos + 1)
