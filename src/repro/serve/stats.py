"""Serving observability: admission counters, batch occupancy, latency.

One :class:`ServingStats` instance per :class:`~repro.serve.graphserve.
GraphServer` accumulates the server's whole history; its
:meth:`ServingStats.snapshot` dict is what the server exposes as
``server.stats()`` and injects into every batch's
``schedule_stats["serving"]`` block — queue depth, cumulative
admitted/rejected/queued counts, batch occupancy (real rows over padded
bucket rows), executed step counts, footprint high water vs budget, and
end-to-end p50/p95/p99 latency percentiles.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ServingStats"]


class ServingStats:
    """Mutable counters; ``snapshot()`` renders the serving stats block."""

    def __init__(self) -> None:
        self.admitted = 0            # queries admitted (incl. from queue)
        self.rejected = 0            # queries refused outright
        self.queued = 0              # queue *events* (a query that waits)
        self.queue_depth = 0         # currently waiting
        self.completed = 0
        self.batches = 0             # device batches executed
        self.steps_executed = 0      # compiled step invocations (Σ iters×waves)
        self.footprint_high_water_bytes = 0
        self.budget_bytes: int | None = None
        self._occupancy: list[tuple[int, int]] = []   # (real, padded)
        self._latencies: list[float] = []

    # -- recording -----------------------------------------------------
    def record_admit(self) -> None:
        self.admitted += 1

    def record_reject(self) -> None:
        self.rejected += 1

    def record_queue(self) -> None:
        self.queued += 1

    def record_batch(self, real: int, padded: int, steps: int) -> None:
        self.batches += 1
        self.steps_executed += int(steps)
        self._occupancy.append((int(real), int(padded)))

    def record_latency(self, seconds: float) -> None:
        self.completed += 1
        self._latencies.append(float(seconds))

    # -- reporting -----------------------------------------------------
    def latency_percentiles(self) -> dict:
        if not self._latencies:
            return dict(p50=None, p95=None, p99=None)
        lat = np.asarray(self._latencies, dtype=np.float64)
        p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
        return dict(p50=float(p50), p95=float(p95), p99=float(p99))

    def batch_occupancy(self) -> float | None:
        """Mean fraction of bucket rows occupied by real queries."""
        if not self._occupancy:
            return None
        return float(np.mean([r / p for r, p in self._occupancy if p > 0]))

    def snapshot(self) -> dict:
        return dict(
            queue_depth=self.queue_depth,
            admitted=self.admitted,
            rejected=self.rejected,
            queued=self.queued,
            completed=self.completed,
            batches=self.batches,
            steps_executed=self.steps_executed,
            batch_occupancy=self.batch_occupancy(),
            batch_sizes=[r for r, _ in self._occupancy],
            bucket_sizes=[p for _, p in self._occupancy],
            latency_s=self.latency_percentiles(),
            footprint_high_water_bytes=self.footprint_high_water_bytes,
            budget_bytes=self.budget_bytes,
        )
