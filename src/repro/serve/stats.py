"""Serving observability: admission counters, batch occupancy, latency.

One :class:`ServingStats` instance per :class:`~repro.serve.graphserve.
GraphServer` accumulates the server's whole history; its
:meth:`ServingStats.snapshot` dict is what the server exposes as
``server.stats()`` and injects into every batch's
``schedule_stats["serving"]`` block — queue depth, cumulative
admitted/rejected/queued counts, batch occupancy (real rows over padded
bucket rows), executed step counts, footprint high water vs budget, and
end-to-end p50/p95/p99 latency percentiles.

Latencies land in a **bounded** :class:`repro.obs.metrics.Histogram`
(the process-wide ``serve.latency_seconds`` instrument on the default
log-spaced ladder), not an unbounded list: a server that has answered a
million queries holds the same few dozen bucket counts as one that
answered ten, and the reported p50/p95/p99 are within one bucket width
of the exact order statistics.  Admission decisions and batch occupancy
are mirrored into the registry too, so the unified run-report sees them
without asking the server.
"""
from __future__ import annotations

import numpy as np

from .. import obs

__all__ = ["ServingStats"]


class ServingStats:
    """Mutable counters; ``snapshot()`` renders the serving stats block."""

    def __init__(self) -> None:
        self.admitted = 0            # queries admitted (incl. from queue)
        self.rejected = 0            # queries refused outright
        self.queued = 0              # queue *events* (a query that waits)
        self.queue_depth = 0         # currently waiting
        self.completed = 0
        self.deadline_exceeded = 0   # queries expired before execution
        self.cancelled = 0           # queries withdrawn by the caller
        self.batch_failures = 0      # device batches that raised
        self.retry_after_rejections = 0   # queue-full rejections (hinted)
        self.batches = 0             # device batches executed
        self.steps_executed = 0      # compiled step invocations (Σ iters×waves)
        self.footprint_high_water_bytes = 0
        self.budget_bytes: int | None = None
        self._occupancy: list[tuple[int, int]] = []   # (real, padded)
        # per-server view of the shared bounded latency instrument:
        # constant memory in query count, percentile error ≤ one bucket
        self._latency = obs.Histogram("serve.latency_seconds")

    # -- recording -----------------------------------------------------
    def record_admit(self) -> None:
        self.admitted += 1
        obs.metrics.counter("serve.admitted").inc()

    def record_reject(self) -> None:
        self.rejected += 1
        obs.metrics.counter("serve.rejected").inc()

    def record_queue(self) -> None:
        self.queued += 1
        obs.metrics.counter("serve.queued").inc()

    def record_deadline_exceeded(self) -> None:
        self.deadline_exceeded += 1
        obs.metrics.counter("serve.deadline_exceeded").inc()

    def record_cancel(self) -> None:
        self.cancelled += 1
        obs.metrics.counter("serve.cancelled").inc()

    def record_batch_failure(self) -> None:
        self.batch_failures += 1
        obs.metrics.counter("serve.batch_failures").inc()

    def record_retry_after(self) -> None:
        self.retry_after_rejections += 1
        obs.metrics.counter("serve.retry_after").inc()

    def record_batch(self, real: int, padded: int, steps: int) -> None:
        self.batches += 1
        self.steps_executed += int(steps)
        self._occupancy.append((int(real), int(padded)))
        m = obs.metrics
        m.counter("serve.batches").inc()
        m.counter("serve.steps_executed").inc(int(steps))
        if padded > 0:
            m.histogram("serve.batch_occupancy",
                        edges=tuple(i / 10 for i in range(11))
                        ).observe(real / padded)

    def record_latency(self, seconds: float) -> None:
        self.completed += 1
        self._latency.observe(float(seconds))
        obs.metrics.histogram("serve.latency_seconds").observe(float(seconds))

    # -- reporting -----------------------------------------------------
    def latency_percentiles(self) -> dict:
        if not self._latency.count:
            return dict(p50=None, p95=None, p99=None)
        return dict(p50=self._latency.percentile(50),
                    p95=self._latency.percentile(95),
                    p99=self._latency.percentile(99))

    def retry_after_hint(self) -> float:
        """Seconds a queue-full-rejected caller should wait before
        resubmitting: the observed median end-to-end latency (one
        in-flight batch typically retires by then), floored so a cold
        server still hints something actionable."""
        p50 = (self._latency.percentile(50)
               if self._latency.count else None)
        return max(float(p50), 0.05) if p50 is not None else 0.05

    def batch_occupancy(self) -> float | None:
        """Mean fraction of bucket rows occupied by real queries."""
        if not self._occupancy:
            return None
        return float(np.mean([r / p for r, p in self._occupancy if p > 0]))

    def snapshot(self) -> dict:
        return dict(
            queue_depth=self.queue_depth,
            admitted=self.admitted,
            rejected=self.rejected,
            queued=self.queued,
            completed=self.completed,
            deadline_exceeded=self.deadline_exceeded,
            cancelled=self.cancelled,
            batch_failures=self.batch_failures,
            retry_after_rejections=self.retry_after_rejections,
            batches=self.batches,
            steps_executed=self.steps_executed,
            batch_occupancy=self.batch_occupancy(),
            batch_sizes=[r for r, _ in self._occupancy],
            bucket_sizes=[p for _, p in self._occupancy],
            latency_s=self.latency_percentiles(),
            footprint_high_water_bytes=self.footprint_high_water_bytes,
            budget_bytes=self.budget_bytes,
        )
