"""Multi-tenant graph-query serving: resident plans, admission, batching.

The long-lived layer between compiled :class:`~repro.core.engine.Plan`\\ s
and many concurrent callers — the serving analogue of the paper's
scheduler: queries are tasks, the device budget is the resource bound,
and the server multiplexes heterogeneous work (PageRank from many
seeds, multi-source BFS, k-core, CC) through a few hot graphs.

Three mechanisms compose:

* **Resident plans** — ``register_graph`` holds a graph's
  :class:`~repro.core.blocks.BlockStore`; the first query of each
  (algorithm, params) builds a plan once and keeps it hot.  Plans are
  fetched through the process-wide compiled-step cache, and in-core
  plans are additionally shared across *same-shape* graphs via
  ``plan.run(other_store)`` — a second graph binds the existing jitted
  step with zero new compiles.  Graphs registered with a
  ``memory_budget=`` get a budgeted streaming plan instead (bound to
  their store).
* **Admission control** — every query is priced under the
  :mod:`repro.core.membudget` footprint model (one state row ×
  ``STATE_COPIES``) and checked against the serving budget and its
  tenant's cap (:mod:`repro.serve.admission`): admit, queue, or reject.
* **Cross-query batching** — compatible admitted queries (same graph,
  same algorithm key, batchable state) are stacked along a leading
  batch axis (:func:`repro.core.engine.batch_states`), padded to a
  power-of-two bucket (:func:`repro.core.membudget.bucket_size`) so the
  step traces once per bucket, and executed as ONE device step per
  iteration — levanter's one-compiled-step-serves-many-homogeneous-
  requests idiom applied to graph queries.  Results are sliced back per
  query and finalized individually; batching is semantics-preserving
  (bit-identical int/bool attributes vs solo runs).

The batch axis is orthogonal to the block axis: under ``mesh=`` the
batched state replicates like any other state and per-wave partials
fold leaf-wise, so batch × mesh is the 2-D (block × query) mesh
substrate.

Not to be confused with :mod:`repro.serve.engine`, the LM slot-batching
decode engine — that one serves token streams, this one serves graph
queries.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

from .. import obs
from ..algorithms.bfs import bfs_algorithm
from ..algorithms.cc import afforest_algorithm
from ..algorithms.kcore import kcore_algorithm
from ..algorithms.pagerank import pagerank_algorithm
from ..core.engine import batch_states, compile_plan, unbatch_state
from ..core.faults import FaultPlan
from ..core.membudget import (
    TenantLedger, batch_state_bytes, bucket_size, tree_array_bytes,
)
from .admission import ADMIT, QUEUE, REJECT, AdmissionController
from .stats import ServingStats

__all__ = ["GraphServer", "Query"]


@dataclass
class Query:
    """One graph query: ``Query("web", "pagerank", dict(seeds=[3]))``.

    ``params`` are algorithm arguments (``seeds``/``damping``/``tol``
    for pagerank, ``source`` for bfs, ``k`` for kcore, none for cc).
    The server fills ``uid``/``status``/``result``/``latency_s``;
    ``status`` moves ``new → queued|admitted → done`` (or
    ``rejected``/``expired``/``cancelled``/``failed``, with
    ``reason``).

    ``deadline_s`` is a per-query execution deadline measured from
    submission: a query still waiting (queued or admitted) when it
    elapses is expired instead of executed.  A query already inside a
    running device batch completes — the execution model is
    synchronous, so deadlines bound *waiting*, not compute.
    ``retry_after_s`` is filled on queue-full rejections: how long the
    caller should wait before resubmitting.
    """

    graph: str
    algorithm: str
    params: dict = field(default_factory=dict)
    tenant: str = "default"
    deadline_s: float | None = None
    uid: int = -1
    status: str = "new"
    reason: str | None = None
    submitted_s: float = 0.0
    latency_s: float | None = None
    retry_after_s: float | None = None
    result: Any = None
    schedule_stats: dict | None = None
    priced_bytes: int = 0


@dataclass(frozen=True)
class _AlgEntry:
    """How one query kind maps onto plans and batches.

    ``key`` identifies plan/batch compatibility (trace-affecting params
    plus the state-structure marker); ``shared_alg`` builds the
    resident plan (no per-query params — the compiled step is shared);
    ``query_alg`` carries the query's own ``init_state``."""

    key: tuple
    shared_alg: Any
    query_alg: Any
    batchable: bool


def _reject_extras(kind: str, leftovers: dict) -> None:
    if leftovers:
        raise ValueError(
            f"unknown {kind} query params: {sorted(leftovers)}")


def _resolve(kind: str, params: dict) -> _AlgEntry:
    p = dict(params or {})
    if kind == "pagerank":
        damping = float(p.pop("damping", 0.85))
        tol = float(p.pop("tol", 1e-4))
        mi = int(p.pop("max_iters", 20))
        seeds = p.pop("seeds", None)
        _reject_extras(kind, p)
        mk = lambda s: pagerank_algorithm(damping=damping, tol=tol,
                                          max_iters=mi, seeds=s)
        # seeds stay out of the key (state content shares one step) but
        # their *presence* is structural: seeded/unseeded states have
        # different pytrees and must not share a batch
        return _AlgEntry(key=("pagerank", damping, tol, mi, seeds is None),
                         shared_alg=mk(None), query_alg=mk(seeds),
                         batchable=True)
    if kind == "bfs":
        beta = int(p.pop("beta", 24))
        mi = int(p.pop("max_iters", 10_000))
        source = int(p.pop("source", 0))
        _reject_extras(kind, p)
        return _AlgEntry(
            key=("bfs", beta, mi),
            shared_alg=bfs_algorithm(0, max_iters=mi, beta=beta),
            query_alg=bfs_algorithm(source, max_iters=mi, beta=beta),
            batchable=True,
        )
    if kind == "kcore":
        k = int(p.pop("k"))
        mi = int(p.pop("max_iters", 10_000))
        _reject_extras(kind, p)
        alg = kcore_algorithm(k, max_iters=mi)
        return _AlgEntry(key=("kcore", k, mi), shared_alg=alg,
                         query_alg=alg, batchable=False)
    if kind == "cc":
        kr = int(p.pop("k_rounds", 2))
        ss = int(p.pop("sample_size", 1024))
        _reject_extras(kind, p)
        alg = afforest_algorithm(k_rounds=kr, sample_size=ss)
        return _AlgEntry(key=("cc", kr, ss), shared_alg=alg,
                         query_alg=alg, batchable=False)
    raise ValueError(
        f"unknown query algorithm {kind!r} "
        "(known: pagerank, bfs, kcore, cc)")


class GraphServer:
    """Serve concurrent graph queries over registered graphs.

    ``memory_budget`` bounds the priced device footprint (resident
    plans + in-flight query state); ``None`` serves unbounded.
    ``tenant_budgets``/``default_tenant_budget`` cap per-tenant
    in-flight bytes.  ``max_batch`` caps how many compatible queries
    one device batch carries.

    Synchronous execution model: :meth:`submit` prices and admits (or
    queues/rejects), :meth:`step` forms and runs one batch to
    completion, :meth:`drain` steps until everything submitted is done.
    """

    def __init__(self, *, memory_budget: "int | str | None" = None,
                 max_batch: int = 8,
                 tenant_budgets: dict | None = None,
                 default_tenant_budget: "int | str | None" = None,
                 max_queue: int | None = None,
                 faults: "str | FaultPlan | None" = None) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        # the serving seam of the fault-injection registry
        # (repro.core.faults): "serve.query" fires per device batch —
        # exercised by the chaos tests; None is a no-op
        self._faults = FaultPlan.parse(faults)
        self.admission = AdmissionController(
            memory_budget,
            tenants=TenantLedger(tenant_budgets,
                                 default_budget=default_tenant_budget),
            max_queue=max_queue,
        )
        self._stats = ServingStats()
        if self.admission.budget is not None:
            self._stats.budget_bytes = self.admission.budget.total_bytes
        self._graphs: dict[str, tuple[Any, dict]] = {}
        self._plans: dict[tuple, Any] = {}
        self._charged: set[tuple] = set()   # (plan_key, graph) residents
        self._queue: list[Query] = []       # waiting admission, FIFO
        self._admitted: list[Query] = []    # awaiting a batch slot
        self._done: dict[int, Query] = {}
        self._uid = 0
        self.last_schedule_stats: dict | None = None

    # -- registration --------------------------------------------------
    def register_graph(self, name: str, store, **plan_kw) -> None:
        """Hold ``store`` for serving under ``name``.

        ``plan_kw`` forwards to :func:`repro.core.engine.compile_plan`
        for every plan built over this graph — pass ``memory_budget=``
        here to serve the graph through the budgeted streaming executor
        (that budget is the plan's *wave* budget, distinct from the
        server's admission budget).
        """
        if name in self._graphs:
            raise ValueError(f"graph {name!r} already registered")
        self._graphs[name] = (store, dict(plan_kw))

    def _plan_key(self, name: str, entry: _AlgEntry) -> tuple:
        store, plan_kw = self._graphs[name]
        kw_key = repr(sorted(plan_kw.items()))
        if plan_kw.get("memory_budget") is not None:
            # streaming plans are bound to their store
            return (name, entry.key, kw_key)
        # in-core plans key on shapes so same-shape graphs share one
        # plan object (and its jitted step) via plan.run(other_store)
        return ("__shape__", store.n, store.m, store.p, entry.key, kw_key)

    def plan_for(self, name: str, algorithm: str,
                 params: dict | None = None):
        """The resident plan serving ``(graph, algorithm, params)`` —
        built (and charged to the budget) on first use."""
        entry = _resolve(algorithm, params or {})
        return self._plan_of(name, entry)

    def _plan_of(self, name: str, entry: _AlgEntry):
        store, plan_kw = self._graphs[name]
        key = self._plan_key(name, entry)
        plan = self._plans.get(key)
        if plan is None:
            plan = compile_plan(entry.shared_alg, store, **plan_kw)
            self._plans[key] = plan
        if (key, name) not in self._charged:
            if plan.store is store:
                nbytes = plan.resident_device_bytes
            else:
                # cross-graph reuse: this graph's binding adds its own
                # context arrays next to the original graph's
                nbytes = tree_array_bytes(plan.bind(store).context)
            self.admission.add_resident(nbytes)
            self._charged.add((key, name))
        return plan

    # -- submission ----------------------------------------------------
    def submit(self, query: Query) -> int:
        """Price, admit (or queue/reject) one query; returns its uid."""
        if query.graph not in self._graphs:
            raise KeyError(f"graph {query.graph!r} not registered")
        entry = _resolve(query.algorithm, query.params)
        store, _ = self._graphs[query.graph]
        # plans go resident before queries price against the remainder
        self._plan_of(query.graph, entry)
        state = entry.query_alg.init_state(store)
        query._entry = entry
        query._state_bytes = tree_array_bytes(state)
        query.priced_bytes = batch_state_bytes(query._state_bytes, 1)
        query.uid = self._uid
        self._uid += 1
        query.submitted_s = time.perf_counter()
        decision = self.admission.decide(query.tenant, query.priced_bytes)
        if decision == REJECT:
            query.status = "rejected"
            query.reason = (
                f"priced footprint {query.priced_bytes} bytes can never be "
                "admitted (resident plans + query exceed the serving budget, "
                "or the query alone exceeds its tenant cap)"
            )
            query._init_state = None
            self._stats.record_reject()
            self._done[query.uid] = query
        elif decision == QUEUE:
            if self.admission.queue_full(len(self._queue)):
                # shed instead of buffering without bound; the hint is
                # the observed median latency — roughly one in-flight
                # batch's worth of wait
                query.status = "rejected"
                query.retry_after_s = self._stats.retry_after_hint()
                query.reason = (
                    f"queue full ({self.admission.max_queue} waiting); "
                    f"retry after {query.retry_after_s:.3f}s"
                )
                query._init_state = None
                self._stats.record_reject()
                self._stats.record_retry_after()
                self._done[query.uid] = query
            else:
                query.status = "queued"
                query._init_state = state
                self._stats.record_queue()
                self._queue.append(query)
        else:
            self.admission.admit(query.tenant, query.priced_bytes)
            query.status = "admitted"
            query._init_state = state
            self._stats.record_admit()
            self._admitted.append(query)
        self._stats.queue_depth = len(self._queue)
        return query.uid

    def _promote(self) -> None:
        """Re-decide queued queries in FIFO order as capacity frees up."""
        still: list[Query] = []
        for q in self._queue:
            decision = self.admission.decide(q.tenant, q.priced_bytes)
            if decision == ADMIT:
                self.admission.admit(q.tenant, q.priced_bytes)
                q.status = "admitted"
                self._stats.record_admit()
                self._admitted.append(q)
            elif decision == REJECT:
                # capacity shrank since queueing (new resident plan)
                q.status = "rejected"
                q.reason = "serving capacity shrank while queued"
                q._init_state = None
                self._stats.record_reject()
                self._done[q.uid] = q
            else:
                still.append(q)
        self._queue = still
        self._stats.queue_depth = len(self._queue)

    def _expire(self) -> None:
        """Expire waiting queries whose deadline has elapsed.

        Applies to queued AND admitted queries — anything not yet
        inside a running batch.  Expired-while-admitted queries release
        their charged bytes so the headroom they held frees up."""
        now = time.perf_counter()

        def overdue(q: Query) -> bool:
            return (q.deadline_s is not None
                    and now - q.submitted_s > q.deadline_s)

        for pool, admitted in ((self._queue, False),
                               (self._admitted, True)):
            for q in [q for q in pool if overdue(q)]:
                pool.remove(q)
                if admitted:
                    self.admission.release(q.tenant, q.priced_bytes)
                q.status = "expired"
                q.reason = (f"deadline {q.deadline_s}s elapsed before "
                            "execution")
                q._init_state = None
                self._stats.record_deadline_exceeded()
                self._done[q.uid] = q
        self._stats.queue_depth = len(self._queue)

    def cancel(self, uid: int) -> bool:
        """Withdraw a waiting query (queued or admitted); returns True
        when it was cancelled, False when it was not waiting (already
        done, rejected, or never submitted)."""
        for pool, admitted in ((self._queue, False),
                               (self._admitted, True)):
            for q in pool:
                if q.uid == uid:
                    pool.remove(q)
                    if admitted:
                        self.admission.release(q.tenant, q.priced_bytes)
                    q.status = "cancelled"
                    q.reason = "cancelled by caller"
                    q._init_state = None
                    self._stats.record_cancel()
                    self._done[q.uid] = q
                    self._stats.queue_depth = len(self._queue)
                    return True
        return False

    # -- execution -----------------------------------------------------
    def step(self) -> int:
        """Form and run ONE device batch; returns queries completed.

        A batch that raises is isolated, not fatal to the server: a
        multi-query batch's members are re-admitted to run **solo** (one
        poisoned query cannot sink its cohort — the others complete on
        their own), and a failing singleton is marked ``failed`` with
        the error as its ``reason``.
        """
        self._expire()
        self._promote()
        if not self._admitted:
            return 0
        head = self._admitted[0]
        batch_key = (head.graph, head._entry.key)
        group = [q for q in self._admitted
                 if (q.graph, q._entry.key) == batch_key]
        entry = head._entry
        pad_reserved = 0
        if getattr(head, "_solo", False):
            # failure isolation: this query's previous batch raised —
            # run it alone so a cohort failure pinpoints the culprit
            group = [head]
            bucket = 1
        elif entry.batchable:
            group = group[: self.max_batch]
            bucket = bucket_size(len(group), minimum=1)
            pad_rows = bucket - len(group)
            if pad_rows:
                pad_reserved = batch_state_bytes(head._state_bytes, pad_rows)
                if not self.admission.reserve(pad_reserved):
                    # padding rows don't fit: shrink to the largest
                    # power-of-two batch (no padding needed)
                    pad_reserved = 0
                    k = 1 << (len(group).bit_length() - 1)
                    group = group[:k]
                    bucket = k
        else:
            group = group[:1]
            bucket = 1
        for q in group:
            self._admitted.remove(q)

        store, _ = self._graphs[head.graph]
        plan = self._plan_of(head.graph, entry)
        try:
            with obs.span("serve.batch", lane="main", graph=head.graph,
                          alg=entry.key[0] if entry.key else "?",
                          real=len(group), bucket=bucket):
                if self._faults is not None:
                    self._faults.fire("serve.query", graph=head.graph,
                                      uid=head.uid, batch=len(group))
                if entry.batchable:
                    state = batch_states([q._init_state for q in group],
                                         pad_to=bucket)
                else:
                    state = group[0]._init_state
                res = plan.run(store=store, state=state)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            return self._fail_batch(group, e)
        finally:
            if pad_reserved:
                self.admission.unreserve(pad_reserved)
        end = time.perf_counter()

        steps = res.iterations * getattr(plan, "num_waves", 1)
        self._stats.record_batch(real=len(group), padded=bucket, steps=steps)
        for i, q in enumerate(group):
            sliced = (unbatch_state(res.state, i) if entry.batchable
                      else res.state)
            q.result = (plan.alg.finalize(store, sliced)
                        if plan.alg.finalize else sliced)
            q.status = "done"
            q.latency_s = end - q.submitted_s
            q._init_state = None
            self._stats.record_latency(q.latency_s)
            self.admission.release(q.tenant, q.priced_bytes)
            self._done[q.uid] = q
        self._stats.footprint_high_water_bytes = (
            self.admission.high_water_bytes)
        obs.metrics.gauge("serve.footprint_high_water_bytes").set_max(
            self.admission.high_water_bytes)
        res.schedule_stats["serving"] = self.stats()
        self.last_schedule_stats = res.schedule_stats
        for q in group:
            q.schedule_stats = res.schedule_stats
        self._promote()
        return len(group)

    def _fail_batch(self, group: list[Query], exc: Exception) -> int:
        """Isolate one raised device batch; returns queries completed
        (0 — the server stays up either way)."""
        self._stats.record_batch_failure()
        obs.instant("batch_failure", lane="resilience",
                    error=type(exc).__name__, real=len(group))
        if len(group) == 1:
            q = group[0]
            q.status = "failed"
            q.reason = f"{type(exc).__name__}: {exc}"
            q.latency_s = time.perf_counter() - q.submitted_s
            q._init_state = None
            self.admission.release(q.tenant, q.priced_bytes)
            self._done[q.uid] = q
            return 0
        # a cohort failed: any member might be the poison — re-admit
        # each to run solo (their bytes stay charged; they are still
        # admitted work).  A query whose solo run also raises lands in
        # the singleton branch above and is marked failed.
        for q in group:
            q._solo = True
        self._admitted[:0] = group
        return 0

    def drain(self) -> dict[int, Query]:
        """Run batches until every submitted query is done/rejected."""
        while self._admitted or self._queue:
            if self.step() == 0 and not self._admitted and self._queue:
                # _promote() either admits or rejects every queued
                # query once nothing is in flight; reaching this means
                # the accounting is inconsistent — fail loudly.  (A
                # step that completed nothing because its batch failed
                # or expired leaves nothing admitted and nothing queued
                # — that's a clean, empty server, not a stall.)
                raise RuntimeError(
                    f"{len(self._queue)} queued queries cannot be admitted "
                    "with no work in flight")
        return dict(self._done)

    # -- introspection -------------------------------------------------
    def result(self, uid: int) -> Query | None:
        return self._done.get(uid)

    def stats(self) -> dict:
        """The serving stats block (also injected into each batch's
        ``schedule_stats["serving"]``)."""
        return self._stats.snapshot()
