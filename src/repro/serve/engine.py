"""Slot-based batched LM serving engine (token streams).

This is the *language-model* serving engine — a fixed decode batch of
slots serving token-generation requests.  Graph-query serving (resident
plans, admission control, cross-query batching) is the separate
:mod:`repro.serve.graphserve`.

A fixed-capacity decode batch of B slots serves a request queue in
*waves*: a wave admits up to B requests, step-decodes them together
through one compiled ``decode_step`` (prompt tokens are teacher-forced
through the same cached path, then generation continues), retires
finished slots by masking, and starts the next wave when the batch
drains.  Wave admission keeps every slot at the same cache position, so
a single scalar-position decode step (the same one the dry-run lowers)
serves the whole stream — the continuous-batching upgrade (per-slot
positions) is a serving-layer change, not a model change, and is noted
as future work.

The scheduler analogy to the paper: requests are tasks, slots are
executors; the queue keeps executors busy and masking retires stragglers
without stalling the wave.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False
    # True when the wave's cache filled before the request reached
    # max_new_tokens/EOS — done, but with fewer tokens than asked for
    truncated: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 4,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.cache_len = cache_len
        self._pending: list[Request] = []
        self.finished: list[Request] = []
        self.steps_executed = 0

        def step_fn(params, state, tokens):
            logits, state = lm.decode_step(cfg, params, state, tokens)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

        self._step = jax.jit(step_fn)

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    # ------------------------------------------------------------------
    def _run_wave(self, wave: list[Request]) -> None:
        state = lm.init_decode_state(self.cfg, self.b, self.cache_len)
        tokens = np.zeros(self.b, np.int32)
        cursor = np.zeros(self.b, np.int64)   # position in prompt
        active = np.zeros(self.b, bool)
        for i, req in enumerate(wave):
            tokens[i] = req.prompt[0] if req.prompt else 0
            active[i] = True

        while active.any() and int(np.max(cursor)) < self.cache_len - 1:
            next_tok, state = self._step(
                self.params, state, jnp.asarray(tokens)
            )
            self.steps_executed += 1
            next_np = np.asarray(next_tok)
            for i, req in enumerate(wave):
                if not active[i]:
                    continue
                cursor[i] += 1
                if cursor[i] < len(req.prompt):
                    tokens[i] = req.prompt[int(cursor[i])]  # teacher-force
                    continue
                tok = int(next_np[i])
                req.output.append(tok)
                tokens[i] = tok
                if (
                    len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                ):
                    active[i] = False
                    req.done = True
                    self.finished.append(req)
        for i, req in enumerate(wave):  # cache-length retirement
            if active[i]:
                req.done = True
                req.truncated = True
                self.finished.append(req)

    def run_until_drained(self) -> list[Request]:
        while self._pending:
            wave = self._pending[: self.b]
            self._pending = self._pending[self.b:]
            self._run_wave(wave)
        return self.finished
