"""Serving substrate.

Two engines live here:

* :class:`GraphServer` (``graphserve``) — multi-tenant graph-query
  serving: resident plans, membudget admission control, cross-query
  batching along a leading batch axis.
* :class:`ServeEngine` (``engine``) — the LM slot-batching decode
  engine (token streams through a fixed decode batch).
"""
from .admission import AdmissionController
from .engine import ServeEngine, Request
from .graphserve import GraphServer, Query
from .stats import ServingStats

__all__ = ["ServeEngine", "Request", "GraphServer", "Query",
           "AdmissionController", "ServingStats"]
