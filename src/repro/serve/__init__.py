"""Serving substrate: slot-based batched decode engine."""
from .engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
