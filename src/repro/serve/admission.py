"""Admission control for the graph-query server (membudget pricing).

The controller answers one question per query: does admitting it keep
the *priced* device footprint under the serving budget?  The footprint
model is the streaming executor's (:mod:`repro.core.membudget`), lifted
to serving granularity:

    total = Σ resident plan bytes            (graphs held hot)
          + Σ in-flight query state bytes    (admitted, per tenant)
          + batch padding reservations       (bucket rows − real rows)

* resident plan bytes — ``plan.resident_device_bytes``: the in-core
  context, or for streamed plans the cross-wave residents plus the
  double-buffered worst wave.
* query state bytes — :func:`repro.core.membudget.batch_state_bytes`
  of one ``init_state`` row (``STATE_COPIES`` live copies).

Decisions are three-valued: **admit** (charge now), **queue** (would
fit alone but not right now — wait for in-flight work to retire), and
**reject** (could *never* fit: resident + query exceeds the budget, or
the query alone exceeds its tenant's cap).  Tenant caps are enforced by
a :class:`~repro.core.membudget.TenantLedger`, so one tenant's burst
queues behind its own cap instead of starving the rest.

``max_queue`` bounds the wait line itself: a query that would QUEUE
when the line is already full is rejected instead — load-shedding with
a ``retry_after_s`` hint (the server attaches the observed median
latency) rather than unbounded buildup.
"""
from __future__ import annotations

from ..core.membudget import MemoryBudget, TenantLedger

__all__ = ["AdmissionController", "ADMIT", "QUEUE", "REJECT"]

ADMIT = "admit"
QUEUE = "queue"
REJECT = "reject"


class AdmissionController:
    """Prices queries against one device budget plus per-tenant caps.

    ``budget=None`` disables the global bound (everything admits);
    tenant caps still apply.  All byte accounting is host-side model
    pricing — the controller never touches device memory itself.
    """

    def __init__(self, budget: "int | str | MemoryBudget | None" = None, *,
                 tenants: TenantLedger | None = None,
                 max_queue: int | None = None) -> None:
        self.budget = MemoryBudget.of(budget) if budget is not None else None
        if max_queue is not None and int(max_queue) < 0:
            raise ValueError(f"max_queue must be >= 0; got {max_queue!r}")
        self.max_queue = int(max_queue) if max_queue is not None else None
        self.tenants = tenants if tenants is not None else TenantLedger()
        self.resident_bytes = 0      # hot plans
        self.in_flight_bytes = 0     # admitted query rows
        self.reserved_bytes = 0      # bucket padding rows
        self.high_water_bytes = 0

    # -- accounting ----------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return self.resident_bytes + self.in_flight_bytes + self.reserved_bytes

    def headroom(self) -> float:
        if self.budget is None:
            return float("inf")
        return self.budget.total_bytes - self.total_bytes

    def _mark(self) -> None:
        self.high_water_bytes = max(self.high_water_bytes, self.total_bytes)

    def add_resident(self, nbytes: int) -> None:
        """Charge a newly hot plan.  Raises when the resident set alone
        would exceed the budget — serving cannot proceed at all then,
        and a loud failure beats admitting nothing forever."""
        nbytes = int(nbytes)
        if (self.budget is not None
                and self.resident_bytes + nbytes > self.budget.total_bytes):
            raise ValueError(
                f"resident plans would hold {self.resident_bytes + nbytes} "
                f"bytes > serving budget {self.budget.total_bytes}; raise "
                "memory_budget or register fewer/smaller graphs"
            )
        self.resident_bytes += nbytes
        self._mark()

    # -- decisions -----------------------------------------------------
    def decide(self, tenant: str, nbytes: int) -> str:
        """ADMIT / QUEUE / REJECT for a query pricing ``nbytes``."""
        nbytes = int(nbytes)
        # could it EVER fit? (ignore transient in-flight/reserved work)
        if (self.budget is not None
                and self.resident_bytes + nbytes > self.budget.total_bytes):
            return REJECT
        if not self.tenants.fits(tenant, nbytes):
            return REJECT
        if self.budget is not None and nbytes > self.headroom():
            return QUEUE
        if not self.tenants.can_charge(tenant, nbytes):
            return QUEUE
        return ADMIT

    def queue_full(self, queue_depth: int) -> bool:
        """Whether a would-QUEUE query must be shed instead: the wait
        line already holds ``max_queue`` queries.  (Promotion from an
        existing queue slot is never shed — only new arrivals.)"""
        return (self.max_queue is not None
                and int(queue_depth) >= self.max_queue)

    def admit(self, tenant: str, nbytes: int) -> None:
        self.tenants.charge(tenant, nbytes)
        self.in_flight_bytes += int(nbytes)
        self._mark()

    def release(self, tenant: str, nbytes: int) -> None:
        self.tenants.release(tenant, nbytes)
        self.in_flight_bytes = max(0, self.in_flight_bytes - int(nbytes))

    # padding rows belong to no tenant; the batch former reserves them
    # for the duration of one device batch
    def reserve(self, nbytes: int) -> bool:
        nbytes = int(nbytes)
        if self.budget is not None and nbytes > self.headroom():
            return False
        self.reserved_bytes += nbytes
        self._mark()
        return True

    def unreserve(self, nbytes: int) -> None:
        self.reserved_bytes = max(0, self.reserved_bytes - int(nbytes))
