"""Trip-count-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` counts a ``while`` body (every ``lax.scan``
— i.e. every layer stack here) ONCE, so FLOPs, bytes and in-body
collectives are undercounted by ~n_layers.  This walker parses the HLO
text, builds the computation call graph, multiplies ``while`` bodies by
their ``known_trip_count`` (emitted by XLA in ``backend_config``), and
accumulates:

* **dot FLOPs** — 2 · |out| · K per dot (the MXU term),
* **buffer bytes** — Σ (operands + output) of every top-level
  instruction after fusion, i.e. the post-fusion HBM traffic model,
* **collective wire bytes** — per collective kind with ring factors,
  now correctly multiplied for collectives inside scanned layers.

Nested whiles (e.g. a Mamba sequence scan inside the layer scan)
multiply through.  Unknown trip counts fall back to 1 with a flag.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

__all__ = ["hlo_cost_model"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
# header params may contain nested parens (tuple-typed params) — only
# anchor on "name (" and require the trailing "{" + "->" presence
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_COLL_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "add-dependency", "iota",
    "partition-id", "replica-id",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


@dataclass
class _Instr:
    name: str
    shape_str: str
    op: str
    rest: str          # everything after the opening paren (operands + attrs)

    @property
    def operands(self) -> list[str]:
        # operand names appear before the closing paren of the call
        depth = 1
        out = []
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = self.rest[:i]
                    out = re.findall(r"%([\w.\-]+)", head)
                    break
        return out

    @property
    def attrs(self) -> str:
        return self.rest


@dataclass
class _Comp:
    name: str
    instrs: list[_Instr] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # instr name -> shape str


def _parse(hlo: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry: str | None = None
    for line in hlo.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{") and "->" in line:
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            name, shape_str, op, rest = m.groups()
            cur.instrs.append(_Instr(name, shape_str.strip(), op, rest))
            cur.shapes[name] = shape_str.strip()
        else:
            # parameter lines inside header parens are already skipped;
            # handle "%p = f32[2] parameter(0)" matched above anyway
            pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+parameter\(",
                          line)
            if pm:
                cur.shapes[pm.group(1)] = pm.group(2).strip()
    return comps, entry


def _dot_flops(comp: _Comp, ins: _Instr) -> float:
    out_elems, _ = _shape_elems_bytes(ins.shape_str)
    lhs = ins.operands[0] if ins.operands else None
    lhs_shape = comp.shapes.get(lhs, "")
    dims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    sm = _SHAPE_RE.search(lhs_shape)
    if not dims_m or not sm:
        return 2.0 * out_elems  # conservative fallback
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for idx in dims_m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _traffic_walk(comp_name: str, comps: dict[str, _Comp], traffic: dict,
                  mult: float = 1.0, depth: int = 0) -> None:
    """Non-memoized walk recording per-(op, shape) buffer bytes with trip
    multipliers — the §Perf diagnosis view ('what dominates HBM traffic')."""
    comp = comps.get(comp_name)
    if comp is None or depth > 8:
        return
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            tm = _TRIP.search(ins.attrs)
            trips = int(tm.group(1)) if tm else 1
            bm = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            if bm:
                _traffic_walk(bm.group(1), comps, traffic, mult * trips,
                              depth + 1)
            continue
        if op in ("call",):
            cm = _CALL_ATTR.search(ins.attrs)
            if cm:
                _traffic_walk(cm.group(1), comps, traffic, mult, depth + 1)
            continue
        if op in _SKIP_BYTES_OPS or op.endswith("-done"):
            continue
        _, out_b = _shape_elems_bytes(ins.shape_str)
        in_b = sum(
            _shape_elems_bytes(comp.shapes[o])[1]
            for o in ins.operands if o in comp.shapes
        )
        if out_b + in_b:
            key = f"{op} {ins.shape_str[:56]}"
            traffic[key] = traffic.get(key, 0.0) + (out_b + in_b) * mult


def _cost_of(comp_name: str, comps: dict[str, _Comp], memo: dict,
             flags: dict) -> dict:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    if comp is None:
        return dict(flops=0.0, bytes=0.0, coll={}, coll_counts={})
    flops = 0.0
    byts = 0.0
    coll: dict[str, float] = {}
    coll_counts: dict[str, int] = {}
    memo[comp_name] = dict(flops=0.0, bytes=0.0, coll={}, coll_counts={})

    for ins in comp.instrs:
        op = ins.op
        base_kind = op.removesuffix("-start").removesuffix("-done")
        # ---- bytes: post-fusion buffer traffic
        if op not in _SKIP_BYTES_OPS and not op.endswith("-done"):
            _, out_b = _shape_elems_bytes(ins.shape_str)
            in_b = 0
            for o in ins.operands:
                if o in comp.shapes:
                    in_b += _shape_elems_bytes(comp.shapes[o])[1]
            byts += out_b + in_b
        # ---- flops
        if op == "dot":
            flops += _dot_flops(comp, ins)
        elif op == "fusion":
            cm = _CALL_ATTR.search(ins.attrs)
            if cm:
                sub = _cost_of(cm.group(1), comps, memo, flags)
                flops += sub["flops"]  # dots inside the fusion
                for k, v in sub["coll"].items():
                    coll[k] = coll.get(k, 0.0) + v
        elif op == "convolution":
            out_elems, _ = _shape_elems_bytes(ins.shape_str)
            flops += 2.0 * out_elems  # lower bound; convs are stubs here
            flags["conv_approx"] = True
        elif base_kind in _COLL_FACTOR and not op.endswith("-done"):
            _, b = _shape_elems_bytes(ins.shape_str)
            wire = b * _COLL_FACTOR[base_kind]
            coll[base_kind] = coll.get(base_kind, 0.0) + wire
            coll_counts[base_kind] = coll_counts.get(base_kind, 0) + 1
        elif op == "while":
            tm = _TRIP.search(ins.attrs)
            trips = int(tm.group(1)) if tm else 1
            if not tm:
                flags["unknown_trip_count"] = True
            body = call_cond = None
            bm = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            cm2 = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
            if bm:
                sub = _cost_of(bm.group(1), comps, memo, flags)
                flops += trips * sub["flops"]
                byts += trips * sub["bytes"]
                for k, v in sub["coll"].items():
                    coll[k] = coll.get(k, 0.0) + trips * v
                for k, v in sub["coll_counts"].items():
                    coll_counts[k] = coll_counts.get(k, 0) + trips * v
            if cm2:
                sub = _cost_of(cm2.group(1), comps, memo, flags)
                flops += trips * sub["flops"]
                byts += trips * sub["bytes"]
        elif op == "conditional":
            bm = _BRANCHES.search(ins.attrs)
            if bm:
                names = re.findall(r"%?([\w.\-]+)", bm.group(1))
                subs = [_cost_of(n, comps, memo, flags) for n in names]
                if subs:  # runtime takes one branch; charge the max
                    mx = max(subs, key=lambda s: s["flops"] + s["bytes"])
                    flops += mx["flops"]
                    byts += mx["bytes"]
                    for k, v in mx["coll"].items():
                        coll[k] = coll.get(k, 0.0) + v
        elif op in ("call", "async-start"):
            cm = _CALL_ATTR.search(ins.attrs)
            if cm:
                sub = _cost_of(cm.group(1), comps, memo, flags)
                flops += sub["flops"]
                byts += sub["bytes"]
                for k, v in sub["coll"].items():
                    coll[k] = coll.get(k, 0.0) + v
                for k, v in sub["coll_counts"].items():
                    coll_counts[k] = coll_counts.get(k, 0) + v

    out = dict(flops=flops, bytes=byts, coll=coll, coll_counts=coll_counts)
    memo[comp_name] = out
    return out


def hlo_cost_model(hlo_text: str) -> dict:
    """Per-device cost of the SPMD module with while-trip multipliers."""
    comps, entry = _parse(hlo_text)
    flags: dict = {}
    memo: dict = {}
    if entry is None:
        return dict(flops=0.0, bytes=0.0, coll=dict(total=0.0, per_kind={},
                    counts={}), flags=dict(no_entry=True))
    # fusions referenced via `calls=` contribute bytes only at call sites;
    # exclude their internal instruction bytes by zeroing: handled by only
    # adding sub flops/coll (not bytes) for fusion in _cost_of.
    c = _cost_of(entry, comps, memo, flags)
    traffic: dict[str, float] = {}
    _traffic_walk(entry, comps, traffic)
    top = sorted(traffic.items(), key=lambda kv: -kv[1])[:12]
    return dict(
        flops=c["flops"],
        bytes=c["bytes"],
        coll=dict(
            total=sum(c["coll"].values()),
            per_kind=c["coll"],
            counts=c["coll_counts"],
        ),
        top_traffic=[dict(op=k, bytes=v) for k, v in top],
        flags=flags,
        num_computations=len(comps),
    )
