"""Three-term roofline from a compiled (SPMD-partitioned) executable.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` supplies per-device FLOPs/bytes (the SPMD
module IS the per-device program, so no division by chips is applied to
those).  Collective bytes are NOT in cost_analysis — they are summed
from the post-optimization HLO text (the only place the partitioner's
actual all-gather/all-reduce/… schedule is visible), with op-specific
ring multipliers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms", "model_flops",
           "parse_shape_bytes"]


@dataclass(frozen=True)
class HW:
    """TPU v5e-class target (assignment constants)."""

    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([^=]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|reduce-scatter-start|"
    r"collective-permute-start)\(",
    re.MULTILINE,
)


def parse_shape_bytes(shape_str: str) -> int:
    """Sum bytes over all shapes in an HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# wire-bytes multiplier per collective kind (ring algorithms, n→large)
_FACTORS = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum wire bytes of every collective in post-optimization HLO."""
    per_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.removesuffix("-start")
        b = parse_shape_bytes(shape_str) * _FACTORS[kind]
        per_kind[kind] = per_kind.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    return dict(
        total=sum(per_kind.values()),
        per_kind=per_kind,
        counts=count,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens.

    For decode shapes D = global_batch (one token per sequence); train
    includes the 3× backward factor, inference kinds use 2·N·D.
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def roofline_terms(cost: dict, coll: dict, *, chips: int, hw: HW = HW()) -> dict:
    """cost = compiled.cost_analysis() (per-device); coll from HLO text
    (per-device program as well)."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(coll["total"])
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = coll_bytes / hw.link_bw
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return dict(
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_coll,
        dominant=dominant,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=bytes_acc,
        collective_bytes_per_chip=coll_bytes,
        chips=chips,
    )
