"""Deterministic fault injection at the executor's seams.

Chaos testing for the streaming runtime: a :class:`FaultPlan` holds a
small set of rules, each naming an injection **site** (a seam the
executor fires explicitly), an **action**, and a **trigger**.  Sites:

======================  ================================================
``stage.assemble``      slab assembly (staging worker thread, or the
                        main thread when ``pipeline_depth=0``)
``stage.device_put``    host→device slab transfer
``wave.compute``        one wave's compiled step (per-iteration step
                        for the in-core :class:`~repro.core.engine.Plan`)
``host.task``           one host-lane unit (:class:`_HostLane` pool)
``mesh.collective``     the per-wave mesh fold
``serve.query``         one device batch in :class:`GraphServer.step`
======================  ================================================

Spec grammar (``compile_plan(faults=...)`` or ``REPRO_FAULTS``)::

    spec    := rule (';' rule)*
    rule    := site ':' action [':' trigger]
    action  := 'raise' | 'oom' | 'delay(<seconds>)' | 'corrupt'
    trigger := 'once' | 'every(<k>)' | 'at(<k>)'      # default: once

``raise`` throws :class:`InjectedFault`; ``oom`` throws
:class:`InjectedOOM` (classified like a real device RESOURCE_EXHAUSTED
by :func:`repro.core.resilience.is_oom`); ``delay(s)`` sleeps;
``corrupt`` returns a corrupted copy of the value passing through the
site (recovery must discard it — the differential harness proves it
does).  ``at(k)`` matches when the site's ``wave=`` context equals
``k`` (falling back to the per-rule occurrence ordinal for sites
without a wave index); ``every(k)`` fires on every k-th occurrence.

Determinism: no randomness anywhere — rules fire on per-rule occurrence
counters, so the same plan over the same run fires at the same places
every time.  Disabled is free: plans hold ``self._faults = None`` and
every seam is one ``is not None`` check (the ``obs`` idiom).

Example::

    >>> fp = FaultPlan.parse("wave.compute:raise:at(2)")
    >>> fp.rules[0].site, fp.rules[0].action, fp.rules[0].trigger
    ('wave.compute', 'raise', 'at')
"""
from __future__ import annotations

import re
import time
from dataclasses import dataclass, field

__all__ = [
    "SITES", "FaultPlan", "FaultRule", "InjectedFault", "InjectedOOM",
]

SITES = (
    "stage.assemble", "stage.device_put", "wave.compute",
    "host.task", "mesh.collective", "serve.query",
)

ACTIONS = ("raise", "oom", "delay", "corrupt")
TRIGGERS = ("once", "every", "at")

_ARG_RE = re.compile(r"^([a-z_]+)\((-?[0-9.]+)\)$")


class InjectedFault(RuntimeError):
    """An injected failure; carries its site and firing context."""

    def __init__(self, site: str, **ctx) -> None:
        detail = ", ".join(f"{k}={v}" for k, v in sorted(ctx.items()))
        super().__init__(f"injected fault at {site}"
                         + (f" ({detail})" if detail else ""))
        self.site = site
        self.ctx = ctx


class InjectedOOM(InjectedFault):
    """An injected device out-of-memory (classified like
    RESOURCE_EXHAUSTED by the resilience policy)."""


def _parse_head(token: str, kind: str, known: tuple) -> tuple[str, float]:
    """``'delay(0.5)'`` → ``('delay', 0.5)``; ``'raise'`` → ``('raise', 0)``."""
    m = _ARG_RE.match(token)
    name, arg = (m.group(1), float(m.group(2))) if m else (token, 0.0)
    if name not in known:
        raise ValueError(
            f"unknown fault {kind} {token!r} (known: {', '.join(known)})")
    if name in ("delay", "every", "at") and m is None:
        raise ValueError(f"fault {kind} {name!r} needs an argument, "
                         f"e.g. {name}(2)")
    if name in ("raise", "oom", "corrupt", "once") and m is not None:
        raise ValueError(f"fault {kind} {name!r} takes no argument")
    return name, arg


@dataclass
class FaultRule:
    """One parsed ``site:action[:trigger]`` rule with its hit counter."""

    site: str
    action: str            # raise | oom | delay | corrupt
    arg: float = 0.0       # delay seconds
    trigger: str = "once"  # once | every | at
    k: int = 0             # every/at argument
    seen: int = 0          # occurrences of the site (this rule's view)
    fired: int = 0

    def should_fire(self, wave: int | None) -> bool:
        self.seen += 1
        if self.trigger == "once":
            return self.fired == 0
        if self.trigger == "every":
            return self.seen % self.k == 0
        # at(k): first occurrence whose wave index (or ordinal, for
        # sites without one) equals k.  Single-shot so a recovered
        # retry of the same wave does not re-fire forever.
        ordinal = wave if wave is not None and wave >= 0 else self.seen - 1
        return ordinal == self.k and self.fired == 0


@dataclass
class FaultPlan:
    """A parsed, stateful set of injection rules.

    One instance per compiled plan run-path — counters advance as sites
    fire, so a plan reused across runs keeps injecting per its
    ``every``/``once`` semantics deterministically.
    """

    rules: list[FaultRule] = field(default_factory=list)
    injected: int = 0

    @classmethod
    def parse(cls, spec: "str | FaultPlan | None") -> "FaultPlan | None":
        """Parse a spec string (``None``/empty → ``None`` = disabled)."""
        if spec is None:
            return None
        if isinstance(spec, FaultPlan):
            return spec
        rules = []
        for part in str(spec).split(";"):
            part = part.strip()
            if not part:
                continue
            bits = [b.strip() for b in part.split(":")]
            if len(bits) not in (2, 3):
                raise ValueError(
                    f"malformed fault rule {part!r}: expected "
                    "site:action[:trigger]")
            site = bits[0]
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r} "
                    f"(known: {', '.join(SITES)})")
            action, arg = _parse_head(bits[1], "action", ACTIONS)
            trigger, karg = ("once", 0.0)
            if len(bits) == 3:
                trigger, karg = _parse_head(bits[2], "trigger", TRIGGERS)
            if trigger == "every" and int(karg) < 1:
                raise ValueError(f"every(k) needs k >= 1 in {part!r}")
            if trigger == "at" and int(karg) < 0:
                raise ValueError(f"at(k) needs k >= 0 in {part!r}")
            rules.append(FaultRule(site=site, action=action, arg=arg,
                                   trigger=trigger, k=int(karg)))
        return cls(rules=rules) if rules else None

    def fire(self, site: str, value=None, **ctx):
        """Pass ``value`` through ``site``: may raise, sleep, or return
        a corrupted copy.  The executor calls this only when the plan's
        fault handle is non-``None`` — the disabled path never gets
        here."""
        wave = ctx.get("wave")
        for r in self.rules:
            if r.site != site:
                continue
            if not r.should_fire(wave):
                continue
            r.fired += 1
            self.injected += 1
            if r.action == "raise":
                raise InjectedFault(site, **ctx)
            if r.action == "oom":
                raise InjectedOOM(site, **ctx)
            if r.action == "delay":
                time.sleep(r.arg)
            elif r.action == "corrupt":
                value = _corrupt(value)
        return value

    def reset(self) -> None:
        """Rewind every trigger counter so a reused plan re-injects
        from scratch — the chaos bench re-arms its single-shot rules
        between timed attempts of the same compiled plan."""
        self.injected = 0
        for r in self.rules:
            r.seen = 0
            r.fired = 0

    def stats(self) -> dict:
        """Per-rule firing counts for ``schedule_stats["resilience"]``."""
        return dict(
            injected=self.injected,
            rules=[dict(site=r.site, action=r.action, trigger=r.trigger,
                        k=r.k, fired=r.fired) for r in self.rules],
        )


def _corrupt(value):
    """A deterministically wrong copy of ``value`` (numpy/jax leaves
    get ``~x`` / ``x + 1``-style damage; other values pass through)."""
    import numpy as np

    def dmg(a):
        arr = np.asarray(a)
        if arr.dtype == np.bool_:
            return ~arr
        if arr.dtype.kind in "iuf":
            return arr + arr.dtype.type(1)
        return a

    if value is None:
        return None
    try:
        import jax
        return jax.tree.map(dmg, value)
    except Exception:
        return value
