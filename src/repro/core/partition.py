"""Partitioner & layout manager (paper §4.3).

PGAbB does not dictate a partitioning scheme but strongly encourages
**symmetric rectilinear (conformal) 2-D** partitioning in hybrid settings:
a single set of vertex cut points is used for both the row (source) and
column (destination) dimension, so block (i, j) holds exactly the edges
u∈V_i, v∈V_j.  Conformality means the row range of B_{ij} equals the
column range of B_{ki} — the property triangle counting relies on
(S_l = D_k, S_m = D_l in the paper's block-list (B_k, B_l, B_m)).

Two partitioners are provided, mirroring the paper:

* ``partition_1d``  — optimal contiguous 1-D edge-balanced partitioning
  (dynamic programming on the degree prefix sum; the paper ships a 1-D
  "optimal" partitioner for CPU-only runs).
* ``partition_symmetric_2d`` — symmetric rectilinear cuts balancing the
  per-stripe edge counts (greedy probe + refinement, the practical
  algorithm from Yaşar et al., arXiv:2009.07735).

The layout manager assigns integer block ids in row-major order by
default (paper §4.3.1) and supports a custom order hook (space-filling
curves etc.).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .graph import Graph

__all__ = ["Layout", "partition_1d", "partition_symmetric_2d", "make_layout",
           "choose_p"]


@dataclass(frozen=True)
class Layout:
    """A conformal 2-D block layout of a graph.

    ``cuts`` is the shared (p+1,) vertex cut vector; block (i, j) covers
    sources ``[cuts[i], cuts[i+1])`` and destinations ``[cuts[j], cuts[j+1])``.
    ``block_ids`` maps grid position → block id; ``order`` is its inverse
    (block id → (i, j)).
    """

    cuts: np.ndarray           # (p+1,) int64 shared row/col cuts — conformal
    p: int                     # grid dimension (p × p blocks)
    block_ids: np.ndarray      # (p, p) int32
    block_edge_counts: np.ndarray  # (p, p) int64
    grid_pos: np.ndarray | None = None  # (p², 2) int32 inverse: id → (i, j)

    @property
    def num_blocks(self) -> int:
        return self.p * self.p

    def block_of_vertex(self, v: int) -> int:
        return int(np.searchsorted(self.cuts, v, side="right") - 1)

    def grid_of(self, block_id: int) -> tuple[int, int]:
        if self.grid_pos is not None:  # O(1): make_layout precomputes
            i, j = self.grid_pos[block_id]
            return int(i), int(j)
        pos = np.argwhere(self.block_ids == block_id)  # legacy Layouts only
        return int(pos[0, 0]), int(pos[0, 1])

    def rows(self, i: int) -> tuple[int, int]:
        return int(self.cuts[i]), int(self.cuts[i + 1])

    def max_stripe_edges(self, g: Graph) -> int:
        """Heaviest row stripe's edge count — an upper bound on any
        single block (and therefore any single-block task footprint)."""
        return _heaviest_stripe(_edge_prefix(g), self.cuts)


def _edge_prefix(g: Graph) -> np.ndarray:
    """Prefix sum of degrees: edges with source < v."""
    return g.indptr.astype(np.int64)


def _heaviest_stripe(pre: np.ndarray, cuts: np.ndarray) -> int:
    """Max edges in any row stripe of ``cuts`` given the edge prefix."""
    return int(np.max(pre[cuts[1:]] - pre[cuts[:-1]]))


def partition_1d(g: Graph, parts: int) -> np.ndarray:
    """Optimal contiguous 1-D partitioning of vertices into ``parts`` by edges.

    Minimizes the maximum per-part edge count over contiguous vertex ranges
    using parametric search over the bottleneck value (exact for contiguous
    1-D chains-on-chains partitioning).
    """
    pre = _edge_prefix(g)
    total = pre[-1]
    lo, hi = (total + parts - 1) // max(parts, 1), total

    def feasible(bound: int) -> np.ndarray | None:
        cuts = [0]
        cur = 0
        for _ in range(parts):
            # furthest vertex such that edges in (cur, v] <= bound
            target = pre[cuts[-1]] + bound
            v = int(np.searchsorted(pre, target, side="right") - 1)
            v = max(v, cuts[-1] + 1) if cuts[-1] < g.n else cuts[-1]
            v = min(v, g.n)
            cuts.append(v)
            if v >= g.n:
                break
        if cuts[-1] < g.n:
            return None
        while len(cuts) < parts + 1:
            cuts.append(g.n)
        return np.asarray(cuts[: parts + 1], dtype=np.int64)

    best = None
    while lo < hi:
        mid = (lo + hi) // 2
        c = feasible(mid)
        if c is not None:
            best, hi = c, mid
        else:
            lo = mid + 1
    if best is None:
        best = feasible(hi)
    assert best is not None
    return best


def _stripe_loads(g: Graph, cuts: np.ndarray) -> np.ndarray:
    """Edges per row stripe for the given cuts."""
    pre = _edge_prefix(g)
    return pre[cuts[1:]] - pre[cuts[:-1]]


def partition_symmetric_2d(g: Graph, p: int, *, refine_iters: int = 8) -> np.ndarray:
    """Symmetric rectilinear cuts: one (p+1,) cut vector for rows AND columns.

    Starts from the 1-D edge-balanced cuts (rows) and refines by probing:
    because the partition is symmetric, balancing row stripes also tends to
    balance column stripes on (near-)symmetric graphs — the paper's
    undirected preprocessing guarantees a symmetric adjacency structure.
    """
    if p <= 0:
        raise ValueError("p must be positive")
    if p == 1:
        return np.array([0, g.n], dtype=np.int64)
    cuts = partition_1d(g, p)
    # refinement: move each interior cut to the local optimum given neighbors
    pre = _edge_prefix(g)
    for _ in range(refine_iters):
        moved = False
        for k in range(1, p):
            lo_v, hi_v = int(cuts[k - 1]) + 1, int(cuts[k + 1]) - 1
            if lo_v > hi_v:
                continue
            # balance edges between stripe k-1 and stripe k
            target = (pre[cuts[k - 1]] + pre[cuts[k + 1]]) / 2.0
            v = int(np.searchsorted(pre, target, side="left"))
            v = min(max(v, lo_v), hi_v)
            if v != cuts[k]:
                cuts[k] = v
                moved = True
        if not moved:
            break
    return cuts.astype(np.int64)


def choose_p(g: Graph, memory_budget, *, safety: int = 2,
             p_max: int = 256, devices: int = 1) -> int:
    """Budget-aware partitioner grain: the smallest power-of-two ``p``
    whose heaviest row stripe fits ``1/safety`` of the memory budget.

    A single-block task can never stage more edges than its row stripe
    holds, so bounding the stripe bounds every task footprint the wave
    packer will see — the partition is made budget-aware up front
    instead of relying on ``build_waves`` to reject oversized tasks
    after the fact.  ``safety`` leaves headroom for bucket padding,
    per-edge routing masks, CSR slices and kernel workspace.

    ``memory_budget`` is the *per-device* budget; ``devices`` > 1
    (mesh-cooperative streaming) additionally requires ``p² ≥ devices``
    so one wave can carry at least one single-block task per mesh
    device — a coarser grain would leave devices idle even though the
    byte bound alone is satisfied.  Tasks stay atomic per device, so
    the stripe cap itself does not relax with mesh size.
    """
    from .membudget import COO_EDGE_BYTES, CSR_INDEX_BYTES, MemoryBudget

    per_edge = COO_EDGE_BYTES + CSR_INDEX_BYTES
    cap = MemoryBudget.of(memory_budget).total_bytes // (safety * per_edge)
    pre = _edge_prefix(g)
    p = 1
    while True:
        # probe with the cuts the layout will actually use
        cuts = partition_symmetric_2d(g, p) if p > 1 else np.array([0, g.n])
        heaviest = _heaviest_stripe(pre, cuts)
        fits = heaviest <= cap and p * p >= max(int(devices), 1)
        if fits or p >= p_max:
            # p_max is returned even unverified — a hub row can make the
            # cap unreachable by any contiguous partition; build_waves
            # still rejects genuinely oversized tasks downstream
            return p
        p *= 2


def make_layout(g: Graph, p: int, *, order: str = "row_major") -> Layout:
    """Build the conformal layout + per-block edge counts (for E estimates)."""
    cuts = partition_symmetric_2d(g, p)
    src, dst = g.coo()
    bi = np.searchsorted(cuts, src, side="right") - 1
    bj = np.searchsorted(cuts, dst, side="right") - 1
    counts = np.zeros((p, p), dtype=np.int64)
    np.add.at(counts, (bi, bj), 1)
    ids = np.arange(p * p, dtype=np.int32)
    if order == "row_major":
        block_ids = ids.reshape(p, p)
    elif order == "snake":
        block_ids = ids.reshape(p, p).copy()
        block_ids[1::2] = block_ids[1::2, ::-1]
    else:
        raise ValueError(f"unknown block order {order!r}")
    # invert block_ids once: grid_pos[id] = (i, j) — grid_of is then O(1)
    # instead of an O(p²) argwhere per call
    grid_pos = np.zeros((p * p, 2), dtype=np.int32)
    ii, jj = np.meshgrid(np.arange(p), np.arange(p), indexing="ij")
    grid_pos[block_ids.ravel()] = np.stack([ii.ravel(), jj.ravel()], axis=1)
    return Layout(cuts=cuts, p=p, block_ids=block_ids,
                  block_edge_counts=counts, grid_pos=grid_pos)
