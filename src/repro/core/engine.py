"""Compiled execution plans (paper §4.1, Fig. 2) — build/compile vs execute.

Execution flow reproduced from the paper:

  read → partition into blocks → compose block-lists (P_C/P_G) →
  estimate (E) & sort → [ I_B → run kernels on all tasks → I_A ]*

The API separates the two halves of that pipeline:

* :func:`compile_plan` does everything *before* the bracket once —
  schedule composition, dense-tile materialization, algorithm
  ``prepare``, backend resolution — and returns a :class:`Plan` that
  owns the jitted per-iteration step.
* :meth:`Plan.run` executes the bracketed loop: ``I_B`` and ``I_A`` run
  host-side between steps (they may look at global attributes, flip
  direction flags, and decide termination, exactly like the paper);
  the step itself runs the sparse (K_H analog) and dense (K_D analog)
  kernels back-to-back over their own slices of the work.

A ``Plan`` is reusable across runs and across *graphs*: the jitted step
is fetched from a process-wide cache keyed on
``(algorithm name, params, backend)``, and jit's own shape bucketing
makes a second graph with the same padded shapes hit the compiled
executable instead of retracing.  Kernels receive a typed
:class:`~repro.core.context.Context` (device arrays + static scalars);
hooks receive a :class:`~repro.core.context.HostCtx` (store, schedule).
Host objects never cross the jit boundary, so there is no ctx
split/merge machinery anymore.

The legacy :class:`Engine` remains as a thin deprecated shim over
``compile_plan``.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

if TYPE_CHECKING:  # pragma: no cover — import cycle guard, typing only
    from .stream import StreamingPlan

import jax
import jax.numpy as jnp

from .. import obs
from .blocks import BlockStore
from .compilecache import alg_cache_key, shared_entry
from .context import Context, HostCtx, build_context, build_host_ctx
from .direction import DirectionController, kernels_for, resolve_direction
from .faults import FaultPlan
from .functors import BlockAlgorithm
from .knobs import env_str as _knob_str
from .resilience import ResilienceStats, RetryPolicy, classify
from .scheduler import Schedule, build_schedule

__all__ = ["Plan", "compile_plan", "RunResult", "Engine", "run",
           "batch_states", "unbatch_state"]


# ----------------------------------------------------------------------
# Batched-state entry point.  Algorithms that declare
# ``metadata["batch"] == "query"`` accept a state pytree with a leading
# query axis (their kernels vmap per-query state over the one shared
# graph context).  These helpers build and take apart that axis; both
# Plan.run(state=...) and StreamingPlan.run(state=...) execute the
# batched state unchanged.  The batch axis is orthogonal to the mesh
# block axis: under ``mesh=`` the batched state is replicated like any
# other state and per-wave partials fold leaf-wise, so batch × mesh
# composes without new machinery.
def batch_states(states, *, pad_to: int | None = None):
    """Stack per-query state pytrees into one batched state.

    Every state must share one tree structure and per-leaf shapes
    (compatible queries).  With ``pad_to`` (a bucket from
    :func:`repro.core.membudget.bucket_size`), the batch is padded by
    replicating the last query's state so the compiled step traces once
    per bucket; padded rows compute real results that callers discard.
    """
    states = list(states)
    if not states:
        raise ValueError("batch_states needs at least one state")
    if pad_to is not None:
        if pad_to < len(states):
            raise ValueError(
                f"pad_to={pad_to} is smaller than the batch of {len(states)}")
        states = states + [states[-1]] * (pad_to - len(states))
    return jax.tree.map(
        lambda *leaves: jnp.stack([jnp.asarray(x) for x in leaves]), *states)


def unbatch_state(state, index: int):
    """Slice query ``index``'s row out of a batched state pytree."""
    return jax.tree.map(lambda leaf: leaf[index], state)


@dataclass
class RunResult:
    result: Any
    state: Any
    iterations: int
    seconds: float
    schedule_stats: dict


# ----------------------------------------------------------------------
# Shared compiled steps: one entry per (alg identity, backend).  jit's
# internal cache buckets by context/state shapes below this, so two
# same-shape graphs — or two Plans for the same algorithm — share one
# compilation.
class _CompiledStep:
    def __init__(self, alg: BlockAlgorithm, direction: str = "push") -> None:
        self.traces = 0
        kernel_sparse, kernel_dense = kernels_for(alg, direction)

        def step(ctx: Context, state, it, run_dense: bool):
            self.traces += 1  # trace-time side effect == compile counter
            obs.metrics.counter("compile.traces").inc()
            if kernel_sparse is not None:
                state = kernel_sparse(ctx, state, it)
            if kernel_dense is not None and run_dense:
                state = kernel_dense(ctx, state, it)
            if alg.post is not None:
                state = alg.post(ctx, state, it)
            return state

        self._jit = jax.jit(step, static_argnums=(3,))

    def __call__(self, ctx: Context, state, it, run_dense: bool):
        return self._jit(ctx, state, it, run_dense)


_STEP_CACHE: dict[tuple, _CompiledStep] = {}

# The keying/share-gating logic lives in repro.core.compilecache so the
# in-core and streaming executors cannot diverge; the old private names
# stay importable for downstream code.
_alg_cache_key = alg_cache_key
_shared_entry = shared_entry


def _compiled_step_for(alg: BlockAlgorithm, backend: str, *,
                       share: bool = True,
                       direction: str = "push") -> _CompiledStep:
    return shared_entry(_STEP_CACHE, alg_cache_key(alg, backend, direction),
                        lambda: _CompiledStep(alg, direction), share=share)


# ----------------------------------------------------------------------
@dataclass
class _Binding:
    """Per-store compiled inputs: the typed contexts + static routing."""

    store: BlockStore
    schedule: Schedule
    context: Context
    host: HostCtx
    run_dense: bool


class Plan:
    """A compiled, reusable execution plan for one algorithm.

    Produced by :func:`compile_plan`.  ``plan.run()`` executes on the
    store it was compiled against; ``plan.run(other_store)`` binds and
    runs another graph — reusing the jitted step outright when the
    padded shapes match (no recompilation).
    """

    def __init__(self, alg: BlockAlgorithm, store: BlockStore,
                 schedule: Schedule | None, *, backend: str,
                 num_devices: int, mode: str, tile_dim: int,
                 dense_frac: float, dense_density: float,
                 share: bool = True, direction: str | None = None,
                 faults: "str | FaultPlan | None" = None,
                 checkpoint_every: int | None = None,
                 checkpoint_dir: str | None = None,
                 retry_policy: RetryPolicy | None = None) -> None:
        from ..kernels.registry import resolve_backend

        # same fault-tolerance contract as StreamingPlan: the in-core
        # step is the "wave.compute" seam, iterations are idempotent
        # (the step maps iteration-start state to the next state), and
        # checkpoints land on iteration boundaries
        self._faults = FaultPlan.parse(
            faults if faults is not None else _knob_str("REPRO_FAULTS"))
        if retry_policy is not None and not isinstance(retry_policy,
                                                       RetryPolicy):
            raise TypeError(
                f"retry_policy must be a repro.core.resilience."
                f"RetryPolicy; got {type(retry_policy).__name__}")
        self._policy = retry_policy or RetryPolicy()
        if checkpoint_every is not None and int(checkpoint_every) < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1; got {checkpoint_every!r}")
        if checkpoint_every is not None and checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every requires checkpoint_dir (where the "
                "per-iteration snapshots persist)")
        self._ckpt_every = (int(checkpoint_every) if checkpoint_every
                            else (1 if checkpoint_dir else 0))
        self._ckpt_dir = checkpoint_dir
        self._resil = ResilienceStats()
        self._injected_pub = 0
        self.alg = alg
        self.backend = resolve_backend(backend)
        self.direction = resolve_direction(alg, direction)
        # None keeps the pre-direction contract: plain push, no
        # controller, no schedule_stats["direction"] block
        self._direction_requested = direction is not None
        self._sched_kw = dict(
            num_devices=num_devices, mode=mode, tile_dim=tile_dim,
            dense_frac=dense_frac, dense_density=dense_density,
        )
        self._steps = {
            "push": _compiled_step_for(alg, self.backend, share=share),
        }
        if self.direction in ("pull", "auto"):
            self._steps["pull"] = _compiled_step_for(
                alg, self.backend, share=share, direction="pull")
        self._step = self._steps["push"]
        self._bindings: dict[int, _Binding] = {}
        self._default = self.bind(store, schedule)

    # Non-default bindings are memoized with a small FIFO cap so a sweep
    # over many graphs doesn't pin every store's device arrays forever.
    _MAX_BINDINGS = 8

    # -- build/compile side -------------------------------------------
    def bind(self, store: BlockStore,
             schedule: Schedule | None = None) -> _Binding:
        """Build (and memoize) the typed contexts for ``store``."""
        cached = self._bindings.get(id(store))
        if (cached is not None and cached.store is store
                and (schedule is None or cached.schedule is schedule)):
            return cached
        sched = schedule or build_schedule(self.alg, store, **self._sched_kw)
        # stage_plan exists to keep per-wave prepare outputs
        # shape-stable across a streamed plan's waves; the in-core Plan
        # has exactly one context and one trace, so it passes None and
        # prepare keeps its unpadded single-shot form
        extras = self.alg.run_prepare(store, sched, None)
        # reserved declaration for the streaming executor's footprint
        # model — not a kernel input (see stream._assemble)
        extras.pop("__workspace_bytes__", None)
        binding = _Binding(
            store=store,
            schedule=sched,
            context=build_context(store, sched, backend=self.backend,
                                  extras=extras),
            host=build_host_ctx(store, sched, backend=self.backend),
            run_dense=(
                self.alg.kernel_dense is not None
                and bool(sched.dense_task_mask.any())
            ),
        )
        self._bindings.pop(id(store), None)
        self._bindings[id(store)] = binding
        if len(self._bindings) > self._MAX_BINDINGS:
            default = getattr(self, "_default", None)
            for key in list(self._bindings):
                if len(self._bindings) <= self._MAX_BINDINGS:
                    break
                if self._bindings[key] is not default:
                    del self._bindings[key]
        return binding

    @property
    def store(self) -> BlockStore:
        return self._default.store

    @property
    def schedule(self) -> Schedule:
        """The schedule is a first-class artifact — inspect it freely."""
        return self._default.schedule

    @property
    def context(self) -> Context:
        return self._default.context

    @property
    def host(self) -> HostCtx:
        return self._default.host

    @property
    def compile_count(self) -> int:
        """Number of times the step has been traced (≈ jit compilations).

        Shared across every Plan using the same cached step; the reuse
        tests assert this stays at 1 across same-shape graphs.  With a
        direction-optimizing plan this sums the push and pull steps —
        each variant traces once.
        """
        return sum(step.traces for step in self._steps.values())

    @property
    def resident_device_bytes(self) -> int:
        """Device bytes of holding this plan hot (default binding's
        context: graph arrays + prepared extras), state excluded — the
        serving admission controller's price for a resident in-core
        plan.  Query state is priced separately per batch."""
        from .membudget import tree_array_bytes

        return tree_array_bytes(self._default.context)

    # -- execute side --------------------------------------------------
    def run(self, store: BlockStore | None = None,
            state: Any | None = None, *,
            _start_it: int = 0, _start_cont: bool = True,
            _ctrl_restore: dict | None = None) -> RunResult:
        """Execute the iteration loop; see module docstring for the contract.

        With ``alg.after`` present, iterate while it returns True (up to
        ``max_iterations``); without it, run exactly ``max_iterations``
        steps.  The underscored keywords are :meth:`resume`'s
        continuation protocol, not public surface.
        """
        alg = self.alg
        b = self._default if store is None else self.bind(store)
        if state is None:
            assert alg.init_state is not None, f"{alg.name}: init_state required"
            state = alg.init_state(b.store)
        ctrl = (DirectionController(alg, self.direction, b.store.n)
                if self._direction_requested else None)
        if ctrl is not None and _ctrl_restore is not None:
            ctrl.current = str(_ctrl_restore["current"])
            ctrl.switches = int(_ctrl_restore["switches"])
            ctrl.decisions = list(_ctrl_restore["decisions"])
            ctrl.densities = list(_ctrl_restore["densities"])
        t0 = time.perf_counter()
        it = int(_start_it)
        cont = bool(_start_cont)
        while cont and it < alg.max_iterations:
            with obs.span("iteration", lane="main", it=it, alg=alg.name):
                if alg.before is not None:
                    state = alg.before(b.host, state, it)
                step = (self._steps[ctrl.decide(state, it)]
                        if ctrl is not None else self._step)
                state = self._step_resilient(step, b, state, it)
                if alg.after is not None:
                    state, cont = alg.after(b.host, state, it)
            it += 1
            if self._ckpt_every and (it % self._ckpt_every == 0
                                     or not cont):
                self._save_checkpoint(state, it, cont, ctrl)
        state = jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            state,
        )
        dt = time.perf_counter() - t0
        m = obs.metrics
        m.counter("engine.runs").inc()
        m.counter("engine.iterations").inc(it)
        m.histogram("engine.run_seconds").observe(dt)
        if self._faults is not None:
            new = self._faults.injected - self._injected_pub
            if new > 0:
                m.counter("stream.fault_injected").inc(new)
                self._injected_pub = self._faults.injected
        result = alg.finalize(b.store, state) if alg.finalize else state
        stats = b.schedule.stats
        if ctrl is not None:
            stats = dict(stats, direction=ctrl.stats())
        # only runs that opted into fault tolerance (or actually
        # recovered) grow the stats dict — existing callers see
        # unchanged keys
        if (self._faults is not None or self._ckpt_every
                or self._resil.fired):
            stats = dict(stats, resilience=self._resil.snapshot(self._faults))
        return RunResult(
            result=result,
            state=state,
            iterations=it,
            seconds=dt,
            schedule_stats=stats,
        )

    def _step_resilient(self, step, b: _Binding, state, it: int):
        """One device step with the fault seam + bounded retry.

        The compiled step maps iteration-start state to the next state
        without mutating its input, so a failed attempt is discarded
        wholesale and retried from the same ``state`` — recovery is
        idempotent by construction.  ``KeyboardInterrupt``/``SystemExit``
        always propagate.
        """
        faults, policy, res = self._faults, self._policy, self._resil
        attempts = 0
        while True:
            try:
                with obs.span("compute", lane="device", it=it):
                    out = step(b.context, state, jnp.int32(it), b.run_dense)
                    if faults is not None:
                        out = faults.fire("wave.compute", out, it=it)
                return out
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                kind = classify(e)
                res.detected += 1
                attempts += 1
                obs.instant("failure", lane="resilience", it=it,
                            kind=kind, error=type(e).__name__)
                if attempts > policy.max_retries:
                    res.record("exhausted", it=it, kind=kind,
                               attempts=attempts)
                    raise
                res.record("retry", it=it, kind=kind, attempts=attempts)
                res.retries += 1
                obs.metrics.counter("stream.fault_retries").inc()
                obs.instant("recovery", lane="resilience", it=it,
                            action="retry")

    def _save_checkpoint(self, state, it: int, cont: bool, ctrl) -> None:
        from ..checkpoint.runstate import save_runstate

        with obs.span("checkpoint", lane="resilience", it=it):
            save_runstate(self._ckpt_dir, state, it=it, cont=cont,
                          ctrl=ctrl)
        self._resil.checkpoints += 1
        obs.metrics.counter("stream.checkpoints").inc()

    def resume(self, ckpt_dir: str | None = None, *,
               step: int | None = None) -> RunResult:
        """Continue from the newest (or ``step``'s) snapshot in
        ``ckpt_dir`` (defaults to this plan's ``checkpoint_dir``).

        Bit-identical for integer/boolean attributes: the loop restarts
        at the stored iteration boundary with the stored continue flag
        and direction-controller history.
        """
        from ..checkpoint.runstate import load_runstate

        d = ckpt_dir if ckpt_dir is not None else self._ckpt_dir
        if d is None:
            raise ValueError(
                "resume() needs a checkpoint directory: pass ckpt_dir or "
                "build the plan with checkpoint_dir=...")
        assert self.alg.init_state is not None
        snap = load_runstate(d, self.alg.init_state(self.store), step=step)
        return self.run(state=snap.state, _start_it=snap.it,
                        _start_cont=snap.cont, _ctrl_restore=snap.ctrl)


def compile_plan(
    alg: BlockAlgorithm,
    store: BlockStore,
    schedule: Schedule | None = None,
    *,
    backend: str | None = None,
    num_devices: int = 1,
    mode: str = "hybrid",
    tile_dim: int = 512,
    dense_frac: float = 0.5,
    dense_density: float = 0.005,
    share: bool = True,
    use_pallas: bool = False,
    direction: str | None = None,
    memory_budget: "int | str | None" = None,
    rebalance_threshold: "float | str | None" = "auto",
    pipeline_depth: int | None = None,
    mesh=None,
    host_fraction: "float | str | None" = "auto",
    faults: "str | None" = None,
    checkpoint_every: int | None = None,
    checkpoint_dir: str | None = None,
    retry_policy=None,
) -> "Plan | StreamingPlan":
    """Build + compile: schedule, prepare, typed contexts, jitted step.

    ``backend`` selects kernel implementations per the registry
    (``"reference" | "xla" | "pallas"``, default ``"xla"``);
    ``"pallas"`` falls back to ``"xla"`` when no Pallas runtime is
    available.  ``use_pallas=True`` is the deprecated spelling of
    ``backend="pallas"`` (an explicit ``backend`` wins).  ``share=False``
    opts out of the process-wide compiled-step cache (use it for ad-hoc
    algorithms that reuse a registered name with different kernels).

    ``direction`` selects the kernel direction for algorithms that
    declare the ``metadata["direction"]`` capability
    (:mod:`repro.core.direction`): ``"push"`` / ``"pull"`` pin one
    variant, ``"auto"`` decides per iteration from the frontier density
    behind a hysteresis band — one direction per iteration across
    waves, mesh shards, and the host lane, so results stay
    bit-identical to fixed push for integer/bool attributes.  Each
    variant's step traces once (the compiled-step cache keys the
    direction) and every decision is recorded in
    ``schedule_stats["direction"]``.  ``None`` (the default) keeps the
    plain push step with no controller.

    ``memory_budget`` (bytes, or a string like ``"64MB"``) switches to
    the out-of-core streaming executor: the result is a
    :class:`~repro.core.stream.StreamingPlan` whose ``run`` drives a
    three-stage host→device pipeline over budget-sized waves of tasks —
    background slab assembly into a staging arena, double-buffered
    ``device_put``, compute — instead of shipping the whole edge set
    to the device up front.  The schedule is then built budget-aware
    (dense cut-offs sized so waves fit).  Same ``run()`` contract;
    ``schedule_stats["streaming"]`` reports waves, bytes staged per
    wave (CSR broken out), per-phase wall clock, trace counts, arena
    bytes, and the measured overlap efficiencies.
    ``rebalance_threshold`` (streaming only) controls tail-wave
    rebalancing, default **on** (``"auto"``): after the calibration
    pass, the wave queue is re-packed against observed task times when
    the estimate-vs-observed divergence trigger fires (hysteresis band
    2.0/1.5, deterministic noise floor).  A float keeps the legacy
    compute-skew trigger; ``None`` switches rebalancing off.
    ``pipeline_depth`` (streaming only) bounds how many waves the
    background staging worker assembles ahead (default 2; ``0`` runs
    staging synchronously in the wave loop — the benchmark baseline).

    ``host_fraction`` (streaming only) co-schedules the host CPU as a
    compute resource: each wave is split into a device partition and a
    host partition; the host tasks run the algorithm's sparse kernel
    eagerly on the CPU backend in a thread pool, overlapped with the
    device wave, and their partials fold through the same
    ``metadata["combine"]`` contract as mesh partials — bit-identical
    to a device-only run for integer/bool attributes.  ``"auto"`` (the
    default) starts device-only and peels the light/sparse tail of each
    wave only once calibration shows the host can hide behind the
    device; a float in ``[0, 1]`` pins the host share of per-wave work
    (``0.0`` disables, ``1.0`` runs everything on the host); ``None``
    disables the host lane entirely.  Host tasks are never staged, so
    every staged device slab stays within ``memory_budget``.
    ``schedule_stats["hetero"]`` reports the resolved split, host/device
    task counts, measured host/device throughput ratio, and per-resource
    makespans.  See ``docs/heterogeneous.md``.

    ``mesh`` (streaming only; a 1-D ``jax.sharding.Mesh``) composes the
    waves with the distributed execution model of
    :mod:`repro.core.distributed`: ``memory_budget`` becomes *per
    device*, each wave's tasks are LPT-split over the mesh into padded
    per-device COO/CSR/tile slabs, the double-buffered stager
    ``device_put``\\ s wave k+1's sharded slabs while the mesh computes
    wave k under ``shard_map``, and per-wave partials fold with the
    algorithm's ``metadata["combine"]`` collectives (psum/pmin/pmax) —
    bit-identical to in-core for integer/bool attributes.  Requires the
    algorithm to declare ``metadata["mesh"] == "shard"``; see
    ``docs/distributed.md``.

    ``faults`` / ``checkpoint_every`` / ``checkpoint_dir`` /
    ``retry_policy`` opt into the fault-tolerant runtime (both
    executors): ``faults`` is a seeded injection spec
    (``"site:action[:trigger]"``, ``;``-joined — see
    :mod:`repro.core.faults` and ``docs/resilience.md``; defaults to the
    ``REPRO_FAULTS`` env knob), ``checkpoint_dir`` persists atomic
    per-iteration run snapshots every ``checkpoint_every`` iterations
    (default every iteration) which ``plan.resume()`` continues
    bit-identically for integer/bool attributes, and ``retry_policy``
    (a :class:`repro.core.resilience.RetryPolicy`) bounds the
    retry/backoff/demotion recovery ladder.  All disabled by default
    with zero overhead; recoveries surface in
    ``schedule_stats["resilience"]``.
    """
    if backend is None:
        backend = "pallas" if use_pallas else "xla"
    if (rebalance_threshold not in (None, "auto")
            and memory_budget is None):
        raise ValueError(
            "rebalance_threshold only applies to the streaming executor; "
            "pass memory_budget=... as well (the in-core Plan has no waves "
            "to rebalance)"
        )
    if pipeline_depth is not None and memory_budget is None:
        raise ValueError(
            "pipeline_depth only applies to the streaming executor; "
            "pass memory_budget=... as well (the in-core Plan stages no "
            "waves)"
        )
    if host_fraction not in (None, "auto") and memory_budget is None:
        raise ValueError(
            "host_fraction only applies to the streaming executor; "
            "pass memory_budget=... as well (the in-core Plan has no "
            "waves to split across host and device)"
        )
    if mesh is not None and memory_budget is None:
        raise ValueError(
            "mesh= composes the *streaming* executor with a device mesh; "
            "pass memory_budget=... as well (for whole-graph resident mesh "
            "execution use repro.core.distributed.DistributedEngine)"
        )
    if memory_budget is not None:
        from .membudget import PIPELINE_DEPTH
        from .stream import StreamingPlan

        return StreamingPlan(
            alg, store, schedule,
            memory_budget=memory_budget,
            backend=backend, num_devices=num_devices, mode=mode,
            tile_dim=tile_dim, dense_frac=dense_frac,
            dense_density=dense_density, share=share,
            direction=direction,
            rebalance_threshold=rebalance_threshold,
            pipeline_depth=(PIPELINE_DEPTH if pipeline_depth is None
                            else pipeline_depth),
            mesh=mesh,
            host_fraction=host_fraction,
            faults=faults, checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir, retry_policy=retry_policy,
        )
    return Plan(
        alg, store, schedule,
        backend=backend, num_devices=num_devices, mode=mode,
        tile_dim=tile_dim, dense_frac=dense_frac,
        dense_density=dense_density, share=share, direction=direction,
        faults=faults, checkpoint_every=checkpoint_every,
        checkpoint_dir=checkpoint_dir, retry_policy=retry_policy,
    )


# ----------------------------------------------------------------------
# Legacy shim
class Engine:
    """Deprecated: use :func:`compile_plan` → :meth:`Plan.run`.

    Kwarg mapping: ``use_pallas=True`` → ``backend="pallas"`` (else
    ``"xla"``); everything else passes through unchanged.
    """

    def __init__(
        self,
        alg: BlockAlgorithm,
        store: BlockStore,
        schedule: Schedule | None = None,
        *,
        num_devices: int = 1,
        mode: str = "hybrid",
        use_pallas: bool = False,
        backend: str | None = None,
        tile_dim: int = 512,
        dense_frac: float = 0.5,
        dense_density: float = 0.005,
    ) -> None:
        warnings.warn(
            "Engine is deprecated; use compile_plan(alg, store, ...).run()",
            DeprecationWarning,
            stacklevel=2,
        )
        self.plan = compile_plan(
            alg, store, schedule,
            backend=backend, use_pallas=use_pallas,
            num_devices=num_devices, mode=mode, tile_dim=tile_dim,
            dense_frac=dense_frac, dense_density=dense_density,
        )
        self.alg = alg
        self.store = store

    @property
    def schedule(self) -> Schedule:
        return self.plan.schedule

    def run(self, state: Any | None = None) -> RunResult:
        return self.plan.run(state=state)


def run(alg: BlockAlgorithm, store: BlockStore, **kw) -> RunResult:
    """One-shot convenience: compile a plan and execute it."""
    return compile_plan(alg, store, **kw).run()
