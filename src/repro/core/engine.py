"""Iterative execution engine (paper §4.1, Fig. 2).

Execution flow reproduced from the paper:

  read → partition into blocks → compose block-lists (P_C/P_G) →
  estimate (E) & sort → [ I_B → run kernels on all tasks → I_A ]*

The per-iteration body is a single jitted function.  Inside it the two
paths run back-to-back over their own slice of the work:

* the **sparse path** (K_H analog) sees the segmented COO restricted to
  its tasks via a static edge mask,
* the **dense path** (K_D analog) sees the packed bitmap tiles.

``I_B``/``I_A`` run host-side between steps, exactly like the paper
(they are allowed to look at global attributes, flip direction flags,
reset counters, and decide termination).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import BlockStore
from .functors import BlockAlgorithm
from .scheduler import Schedule, build_schedule

__all__ = ["Engine", "run"]


def _split_ctx(ctx):
    """Recursively split a context into (dynamic jnp-array pytree, static rest).

    Dicts/lists/tuples are traversed; ``jax.Array`` leaves go to the
    dynamic side (same container shape, ``None`` holes on the static
    side), everything else (ints, callables, host objects) stays static.
    """
    if isinstance(ctx, jax.Array):
        return ctx, _DYN
    if isinstance(ctx, dict):
        dyn, static = {}, {}
        for k, v in ctx.items():
            d, s = _split_ctx(v)
            dyn[k], static[k] = d, s
        return dyn, static
    if isinstance(ctx, (list, tuple)):
        pairs = [_split_ctx(v) for v in ctx]
        dyn = [p[0] for p in pairs]
        static = [p[1] for p in pairs]
        return dyn, static
    return None, ctx


class _Dyn:
    """Sentinel marking 'value lives on the dynamic side'."""

    def __repr__(self) -> str:  # pragma: no cover
        return "<dyn>"


_DYN = _Dyn()


def _merge_ctx(dyn, static):
    if static is _DYN:
        return dyn
    if isinstance(static, dict):
        return {k: _merge_ctx(dyn[k], static[k]) for k in static}
    if isinstance(static, (list, tuple)):
        return [
            _merge_ctx(d, s) for d, s in zip(dyn, static)
        ]
    return static


@dataclass
class RunResult:
    result: Any
    state: Any
    iterations: int
    seconds: float
    schedule_stats: dict


class Engine:
    def __init__(
        self,
        alg: BlockAlgorithm,
        store: BlockStore,
        schedule: Schedule | None = None,
        *,
        num_devices: int = 1,
        mode: str = "hybrid",
        use_pallas: bool = False,
        tile_dim: int = 512,
        dense_frac: float = 0.5,
        dense_density: float = 0.005,
    ) -> None:
        self.alg = alg
        self.store = store
        self.schedule = schedule or build_schedule(
            alg,
            store,
            num_devices=num_devices,
            mode=mode,
            tile_dim=tile_dim,
            dense_frac=dense_frac,
            dense_density=dense_density,
        )
        self.use_pallas = use_pallas
        self.ctx = self._build_context()
        # Split device arrays out of the context and pass them as jit
        # ARGUMENTS: baking them in as closure constants makes XLA
        # constant-fold whole kernels at compile time (minutes for the
        # dense-tile paths) and bloats every recompile.
        self._ctx_dyn, self._ctx_static = _split_ctx(self.ctx)

        def step(dyn, state, it):
            ctx = _merge_ctx(dyn, self._ctx_static)
            return self._step_impl(ctx, state, it)

        self._step = jax.jit(step)

    # ------------------------------------------------------------------
    def _build_context(self) -> dict:
        """Static per-run context handed to kernels."""
        store, sched = self.store, self.schedule
        ctx = store.device_arrays()
        # static edge → path routing: an edge is on the dense path iff the
        # task owning its block went dense.  (Bulk mode: task == block.)
        dense_blocks = np.zeros(store.layout.num_blocks, dtype=bool)
        if sched.dense_block_ids.size:
            dense_blocks[sched.dense_block_ids] = True
        edge_dense = dense_blocks[np.asarray(store.edge_block)]
        ctx["sparse_edge_mask"] = jnp.asarray(~edge_dense)
        ctx["dense_edge_mask"] = jnp.asarray(edge_dense)
        ctx["n"] = store.n
        ctx["m"] = store.m
        ctx["p"] = store.p
        ctx["cuts"] = jnp.asarray(store.layout.cuts)
        ctx["tile_dim"] = sched.tile_dim
        ctx["use_pallas"] = self.use_pallas
        ctx["schedule"] = sched
        ctx["store"] = store  # host-side only; kernels must not trace through it
        if self.alg.prepare is not None:
            ctx = self.alg.prepare(ctx, store, sched)
        return ctx

    def _step_impl(self, ctx, state, it):
        alg = self.alg
        if alg.kernel_sparse is not None:
            state = alg.kernel_sparse(ctx, state, it)
        if alg.kernel_dense is not None and self.schedule.dense_task_mask.any():
            state = alg.kernel_dense(ctx, state, it)
        if alg.post is not None:
            state = alg.post(ctx, state, it)
        return state

    # ------------------------------------------------------------------
    def run(self, state: Any | None = None) -> RunResult:
        alg = self.alg
        if state is None:
            assert alg.init_state is not None, f"{alg.name}: init_state required"
            state = alg.init_state(self.store)
        t0 = time.perf_counter()
        it = 0
        cont = True
        while cont and it < alg.max_iterations:
            if alg.before is not None:
                state = alg.before(self.ctx, state, it)
            state = self._step(self._ctx_dyn, state, jnp.int32(it))
            if alg.after is not None:
                state, cont = alg.after(self.ctx, state, it)
            else:
                cont = False
            it += 1
        state = jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            state,
        )
        dt = time.perf_counter() - t0
        result = alg.finalize(self.store, state) if alg.finalize else state
        return RunResult(
            result=result,
            state=state,
            iterations=it,
            seconds=dt,
            schedule_stats=self.schedule.stats,
        )


def run(alg: BlockAlgorithm, store: BlockStore, **kw) -> RunResult:
    """One-shot convenience: build a schedule, run the algorithm."""
    return Engine(alg, store, **kw).run()
