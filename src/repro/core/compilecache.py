"""Share-gated compiled-step caching, common to both executors.

The in-core :class:`~repro.core.engine.Plan` and the streaming
:class:`~repro.core.stream.StreamingPlan` each own jitted step flavours
(`_CompiledStep`, `_StreamStep`, `_PostStep`, ...).  All of them are
cached process-wide under the same identity — ``(algorithm name,
trace-affecting params, backend)`` — so that two plans for the same
algorithm share one compilation, and jit's own shape bucketing makes
same-shape graphs hit the compiled executable instead of retracing.

This module is the single home of that keying/invalidation logic:
``alg_cache_key`` builds the identity tuple, ``shared_entry`` is the
share-gated lookup every cache flavour goes through.  Keeping them in
one place means a change to the cache contract (new key component,
eviction, ...) cannot silently diverge between the executors.

Execution-time configuration — fault-injection plans, checkpoint
settings, retry policies (:mod:`repro.core.faults`,
:mod:`repro.core.resilience`) — must NEVER enter a cache key: it does
not affect the traced computation, and keying on it would force
needless retraces (and let a chaos run pollute the cache for the
fault-free plans that share its steps).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, TypeVar

from .. import obs

if TYPE_CHECKING:  # pragma: no cover — typing only, avoids an import cycle
    from .functors import BlockAlgorithm

__all__ = ["alg_cache_key", "shared_entry"]

T = TypeVar("T")


def alg_cache_key(alg: "BlockAlgorithm", backend: str,
                  direction: str = "push") -> tuple:
    """Algorithms are identified by (name, trace-affecting params,
    backend, kernel direction).

    Factories record trace-affecting parameters under
    ``metadata["params"]``; two factory calls with equal params produce
    behaviourally identical kernels and may share a compiled step.  The
    ``direction`` component keys the push/pull kernel variant
    (:mod:`repro.core.direction`) so each direction traces exactly once
    and an auto plan's two steps never collide in the cache.
    """
    params = alg.metadata.get("params")
    return (alg.name, repr(sorted(params.items())) if params else None,
            backend, direction)


def shared_entry(cache: dict, key: tuple, factory: Callable[[], T], *,
                 share: bool = True) -> T:
    """The one share-gated cache lookup used for every compiled-step
    flavour (in-core step in engine.py; wave/post/mesh steps in
    stream.py).  ``share=False`` bypasses the cache for ad-hoc
    algorithms that reuse a registered name with different kernels."""
    if not share:
        obs.metrics.counter("compile.cache.bypasses").inc()
        return factory()
    entry = cache.get(key)
    if entry is None:
        obs.metrics.counter("compile.cache.misses").inc()
        entry = cache[key] = factory()
    else:
        obs.metrics.counter("compile.cache.hits").inc()
    return entry
