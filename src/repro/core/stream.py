"""Out-of-core streaming executor: memory-budgeted, double-buffered waves.

This subsystem makes any :class:`~repro.core.engine.Plan`-compatible
algorithm runnable under an explicit device-memory budget — the paper's
headline capability ("graphs that fit host DRAM but not device memory",
§4.3/§4.4, the block-list bound on device copies).  Four parts:

1. **Footprint model** (:mod:`repro.core.membudget`) prices each
   schedule task's COO slice, dense tiles, and kernel workspace in
   bytes.
2. **Wave builder** packs the LPT-ordered tasks into budget-sized
   *waves*; every wave's edge slab is padded to one of a few fixed
   bucket shapes (power-of-two ladder) so a single jitted step serves
   all waves without retracing.  Within a wave, tasks are sorted by
   leading block id so the segmented-COO gather coalesces into few
   contiguous segments — staging approaches a single slice copy.
3. **Double-buffered staging loop**: wave ``k``'s compute is dispatched
   asynchronously (JAX async dispatch — the analog of the paper's four
   CUDA streams), then wave ``k+1``'s host slab is ``jax.device_put``
   while the device works; the previous slab's buffers are released as
   their references drop.  The first executed iteration runs
   synchronously to calibrate stage/compute times; every later
   iteration overlaps, and ``schedule_stats`` reports the measured
   overlap efficiency.
4. **Partial-result combination**: each wave's kernels run against the
   *iteration-start* state and its per-leaf updates are folded with the
   algorithm's declared ``metadata["combine"]`` op (``add``/``min``/
   ``max`` — the same semantics as
   :func:`repro.core.distributed.combine_fn`), so streamed results
   match the in-core bulk-synchronous step: exactly for integer/bool
   attributes, and up to float summation order for real ones.  Leaves a
   kernel passes through untouched are detected at trace time and
   carried over unchanged, so no combine kind is needed for them.
   ``post`` (and the host hooks) run once per iteration on the combined
   state, against a *resident* context that holds only vertex-level
   arrays.

The device working set is: resident vertex-level arrays (state pytree,
``indptr``/``degrees``/``row_block_ptr``/``cuts``, and — not yet
streamed — the CSR ``indices``; see ROADMAP) plus at most two staged
wave slabs (current + prefetch), each ≤ the budget.

Entry point: ``compile_plan(alg, store, memory_budget=...)`` returns a
:class:`StreamingPlan` instead of a :class:`~repro.core.engine.Plan`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace as dc_replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import BlockStore
from .context import Context, build_host_ctx, with_arrays
from .functors import BlockAlgorithm
from .membudget import (
    MemoryBudget, Wave, bucket_size, build_waves, resident_bytes,
    split_wave, task_footprints, tree_array_bytes,
)
from .scheduler import Schedule, build_schedule
from .engine import RunResult, _alg_cache_key, _shared_entry

__all__ = ["StreamingPlan", "compile_streaming_plan"]

_COMBINE_KINDS = ("add", "min", "max")


def _combine_spec(alg: BlockAlgorithm):
    """metadata['combine'] → leaf-name → kind (or None when undeclared)."""
    c = alg.metadata.get("combine")
    if isinstance(c, str):
        return lambda key: c
    if isinstance(c, dict):
        return lambda key: c.get(key)
    return lambda key: None


def _combine_leaf(kind: str | None, key: str, acc, s0, new):
    if kind == "add":
        return acc + (new - s0)
    if kind == "min":
        return jnp.minimum(acc, new)
    if kind == "max":
        return jnp.maximum(acc, new)
    raise ValueError(
        f"state leaf {key!r} is modified by the kernels but declares no "
        f"combine kind in metadata['combine'] (one of {_COMBINE_KINDS}); "
        f"streaming cannot fold its per-wave partial results"
    )


class _StreamStep:
    """The jitted per-wave step: kernels from iteration-start state,
    partials folded into the running accumulator via the combine spec.

    Pass-through detection happens at trace time: a kernel that returns
    ``dict(state, acc=...)`` leaves the other values as the *same*
    tracer objects, which is exactly the contract "this wave did not
    touch that attribute"."""

    def __init__(self, alg: BlockAlgorithm) -> None:
        self.traces = 0
        spec = _combine_spec(alg)

        def step(ctx: Context, state0, acc, it, run_dense: bool):
            self.traces += 1
            if not isinstance(state0, dict):
                raise TypeError(
                    f"{alg.name}: streaming requires a dict state pytree"
                )
            new = state0
            if alg.kernel_sparse is not None:
                new = alg.kernel_sparse(ctx, new, it)
            if alg.kernel_dense is not None and run_dense:
                new = alg.kernel_dense(ctx, new, it)
            added = set(new) - set(state0)
            if added:  # the in-core step would forward these to post;
                # per-wave there is no baseline to combine them against
                raise ValueError(
                    f"{alg.name}: kernels added state leaves "
                    f"{sorted(added)}; streaming requires kernels to "
                    f"write only leaves present in init_state (declare "
                    f"scratch attributes there)"
                )
            out = {}
            for key in state0:
                s0, nw = state0[key], new[key]
                out[key] = (
                    acc[key] if nw is s0
                    else _combine_leaf(spec(key), key, acc[key], s0, nw)
                )
            return out

        self._jit = jax.jit(step, static_argnums=(4,))

    def __call__(self, ctx, state0, acc, it, run_dense: bool):
        return self._jit(ctx, state0, acc, it, run_dense)


class _PostStep:
    """``post`` + trace counter, jitted once per algorithm identity."""

    def __init__(self, alg: BlockAlgorithm) -> None:
        self.traces = 0

        def step(ctx: Context, state, it):
            self.traces += 1
            return alg.post(ctx, state, it)

        self._jit = jax.jit(step)

    def __call__(self, ctx, state, it):
        return self._jit(ctx, state, it)


_STREAM_STEP_CACHE: dict[tuple, _StreamStep] = {}
_POST_STEP_CACHE: dict[tuple, _PostStep] = {}


def _stream_step_for(alg: BlockAlgorithm, backend: str, *,
                     share: bool = True) -> _StreamStep:
    return _shared_entry(_STREAM_STEP_CACHE, _alg_cache_key(alg, backend),
                         lambda: _StreamStep(alg), share=share)


def _post_step_for(alg: BlockAlgorithm, backend: str, *,
                   share: bool = True) -> _PostStep | None:
    if alg.post is None:
        return None
    return _shared_entry(_POST_STEP_CACHE, _alg_cache_key(alg, backend),
                         lambda: _PostStep(alg), share=share)


# ----------------------------------------------------------------------
@dataclass
class _WaveSlab:
    """Host-side staged form of one wave: padded numpy arrays ready for
    a single ``jax.device_put`` per iteration."""

    wave: Wave
    src: np.ndarray
    dst: np.ndarray
    edge_block: np.ndarray
    sparse_mask: np.ndarray
    dense_mask: np.ndarray
    tiles: np.ndarray | None
    tile_row_start: np.ndarray | None
    tile_col_start: np.ndarray | None
    extras: Any                    # host pytree, or None once hoisted resident
    run_dense: bool
    staged_bytes: int
    workspace_bytes: int           # kernel scratch estimate (not staged)
    edges: int
    segments: int                  # coalesced COO slices gathered


def _is_array_leaf(leaf: Any) -> bool:
    return isinstance(leaf, (np.ndarray, jax.Array))


def _to_host(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: np.asarray(l) if _is_array_leaf(l) else l, tree
    )


def _put_arrays(tree: Any) -> Any:
    """device_put only the array leaves; static leaves stay untouched."""
    return jax.tree_util.tree_map(
        lambda l: jax.device_put(l) if _is_array_leaf(l) else l, tree
    )


def _trees_equal(a: Any, b: Any) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if _is_array_leaf(x) != _is_array_leaf(y):
            return False
        if _is_array_leaf(x):
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                return False
        elif x != y:
            return False
    return True


def _block_tree(tree: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


# ----------------------------------------------------------------------
class StreamingPlan:
    """A compiled plan whose execution streams budget-sized waves.

    Produced by ``compile_plan(alg, store, memory_budget=...)``.  Same
    ``run()`` contract as :class:`~repro.core.engine.Plan` (hooks, post,
    iteration control, RunResult), but the per-iteration step is the
    double-buffered wave loop described in the module docstring, and
    ``schedule_stats`` additionally carries a ``"streaming"`` dict:
    wave count, bytes staged per wave (each ≤ budget), resident bytes,
    and overlap efficiency.
    """

    def __init__(self, alg: BlockAlgorithm, store: BlockStore,
                 schedule: Schedule | None = None, *,
                 memory_budget: int | str | MemoryBudget,
                 backend: str = "xla", num_devices: int = 1,
                 mode: str = "hybrid", tile_dim: int = 512,
                 dense_frac: float = 0.5, dense_density: float = 0.005,
                 share: bool = True) -> None:
        from ..kernels.registry import resolve_backend

        self.alg = alg
        self.store = store
        self.backend = resolve_backend(backend)
        self.budget = MemoryBudget.of(memory_budget)
        self.schedule = schedule or build_schedule(
            alg, store, num_devices=num_devices, mode=mode,
            tile_dim=tile_dim, dense_frac=dense_frac,
            dense_density=dense_density,
        )
        self.host = build_host_ctx(store, self.schedule, backend=self.backend)

        self._footprints = task_footprints(
            store, self.schedule,
            workspace_kernel=alg.metadata.get("workspace_kernel"),
        )
        self._slabs = self._build_slabs(
            build_waves(store, self.schedule, self.budget, self._footprints)
        )
        self._resident = self._build_resident_context()
        self._step = _stream_step_for(alg, self.backend, share=share)
        self._post = _post_step_for(alg, self.backend, share=share)
        self._calibration: dict | None = None
        self._bytes_staged = 0          # actual H2D traffic, all passes
        self._edge_free = int(alg.metadata.get("edge_free_iterations", 0))
        self._edge_free_bufs: dict | None = None
        self.schedule.stats["waves"] = len(self._slabs)

    # -- build side ----------------------------------------------------
    def _build_slabs(self, waves: list[Wave]) -> list[_WaveSlab]:
        """Assemble host slabs; split any wave whose *actual* staged
        bytes overflow the budget (model under-priced prepare extras).

        Wave-invariant extras are hoisted resident *before* the budget
        check — they are staged once, not per wave, so counting them
        per wave would spuriously reject (or over-split) workable
        budgets."""
        slabs = [self._assemble(w) for w in waves]
        self._decide_hoist(slabs)
        out: list[_WaveSlab] = []
        pending = slabs
        while pending:
            slab = pending.pop(0)
            if (slab.staged_bytes + slab.workspace_bytes
                    > self.budget.total_bytes):
                # staged arrays + kernel scratch are the wave's real
                # device footprint; split_wave raises for size-1 waves —
                # the ≤ budget invariant is never silently violated
                a, b = split_wave(slab.wave, self.schedule, self._footprints)
                halves = [self._assemble(a), self._assemble(b)]
                for h in halves:
                    self._strip_hoisted(h)
                pending[:0] = halves
                continue
            out.append(slab)
        return out

    def _assemble(self, wave: Wave) -> _WaveSlab:
        store, sched = self.store, self.schedule
        wsched = sched.restrict(wave.task_ids)
        blocks = np.unique(wsched.blocklists)
        segments = store.edge_segments(blocks)
        idx = (
            np.concatenate([np.arange(s, e, dtype=np.int64)
                            for s, e in segments])
            if segments else np.zeros(0, np.int64)
        )
        ne = int(idx.size)
        eb = bucket_size(ne)
        src = np.zeros(eb, np.int32)
        dst = np.zeros(eb, np.int32)
        edge_block = np.zeros(eb, np.int32)
        sparse_mask = np.zeros(eb, bool)
        dense_mask = np.zeros(eb, bool)
        if ne:
            src[:ne] = store.src[idx]
            dst[:ne] = store.dst[idx]
            edge_block[:ne] = store.edge_block[idx]
            dense_blocks = np.zeros(store.layout.num_blocks, bool)
            if wsched.dense_block_ids.size:
                dense_blocks[wsched.dense_block_ids] = True
            edense = dense_blocks[edge_block[:ne]]
            sparse_mask[:ne] = ~edense
            dense_mask[:ne] = edense

        # -- dense tiles (already materialized by build_schedule) ------
        tiles = trs = tcs = None
        run_dense = (
            self.alg.kernel_dense is not None
            and bool(wsched.dense_task_mask.any())
        )
        wstore = store
        if run_dense:
            sub, sub_rs, sub_cs = store.tile_subset(wsched.dense_block_ids)
            nd = sub.shape[0]
            tb = bucket_size(nd, minimum=1)
            t = sched.tile_dim
            tiles = np.zeros((tb, t, t), np.float32)
            tiles[:nd] = sub
            trs = np.zeros(tb, np.int64)
            trs[:nd] = sub_rs
            tcs = np.zeros(tb, np.int64)
            tcs[:nd] = sub_cs
            wstore = dc_replace(
                store, tile_dim=t,
                tile_block_ids=wsched.dense_block_ids.astype(np.int32),
                tiles=sub, tile_row_start=sub_rs, tile_col_start=sub_cs,
            )
        elif self.alg.prepare is not None:
            # prepare must not see tiles the wave does not stage
            wstore = dc_replace(
                store, tile_dim=0,
                tile_block_ids=np.zeros(0, np.int32),
                tiles=np.zeros((0, 0, 0), np.float32),
                tile_row_start=np.zeros(0, np.int64),
                tile_col_start=np.zeros(0, np.int64),
            )

        extras = (
            _to_host(self.alg.prepare(wstore, wsched))
            if self.alg.prepare is not None else {}
        )

        staged = (
            src.nbytes + dst.nbytes + edge_block.nbytes
            + sparse_mask.nbytes + dense_mask.nbytes
            + tree_array_bytes(extras)
        )
        ws = 0
        if tiles is not None:
            staged += tiles.nbytes + trs.nbytes + tcs.nbytes
            from ..kernels.registry import max_workspace_bytes, workspace_bytes

            wk = self.alg.metadata.get("workspace_kernel")
            hints = dict(nd=int(tiles.shape[0]), tile_dim=sched.tile_dim)
            ws = (workspace_bytes(wk, **hints) if wk is not None
                  else max_workspace_bytes(**hints))
        return _WaveSlab(
            wave=wave, src=src, dst=dst, edge_block=edge_block,
            sparse_mask=sparse_mask, dense_mask=dense_mask,
            tiles=tiles, tile_row_start=trs, tile_col_start=tcs,
            extras=extras, run_dense=run_dense,
            staged_bytes=int(staged), workspace_bytes=int(ws),
            edges=ne, segments=len(segments),
        )

    def _decide_hoist(self, slabs: list[_WaveSlab]) -> None:
        """Wave-invariant ``prepare`` outputs (vertex-level attribute
        arrays like PageRank's ``inv_deg``) are staged once as resident
        instead of once per wave per iteration."""
        self._resident_extras: dict = {}
        self._hoisted = False
        if not slabs:
            return
        first = slabs[0].extras
        if all(_trees_equal(s.extras, first) for s in slabs[1:]):
            self._resident_extras = first
            self._hoisted = True
            for s in slabs:
                self._strip_hoisted(s)

    def _strip_hoisted(self, slab: _WaveSlab) -> None:
        """Drop a slab's extras (and their byte cost) when they match
        the hoisted resident tree — also applied to slabs rebuilt by a
        budget split after the hoist decision."""
        if (self._hoisted and slab.extras is not None
                and _trees_equal(slab.extras, self._resident_extras)):
            slab.staged_bytes -= tree_array_bytes(slab.extras)
            slab.extras = None

    def _build_resident_context(self) -> Context:
        """Vertex-level arrays only — the per-wave slab fields start
        empty and are swapped in by :func:`with_arrays` each wave."""
        store = self.store
        return Context(
            src=jnp.zeros(0, jnp.int32),
            dst=jnp.zeros(0, jnp.int32),
            edge_block=jnp.zeros(0, jnp.int32),
            indptr=jnp.asarray(store.indptr),
            indices=jnp.asarray(store.indices),
            degrees=jnp.asarray(store.degrees),
            row_block_ptr=jnp.asarray(store.row_block_ptr),
            cuts=jnp.asarray(store.layout.cuts),
            sparse_edge_mask=jnp.zeros(0, bool),
            dense_edge_mask=jnp.zeros(0, bool),
            extras=_put_arrays(dict(self._resident_extras)),
            n=store.n,
            m=store.m,
            p=store.p,
            tile_dim=self.schedule.tile_dim,
            backend=self.backend,
        )

    # -- execute side --------------------------------------------------
    @property
    def num_waves(self) -> int:
        return len(self._slabs)

    @property
    def compile_count(self) -> int:
        return self._step.traces

    def _stage(self, w: int) -> dict:
        """One host→device copy of wave ``w``'s preassembled slab."""
        slab = self._slabs[w]
        self._bytes_staged += slab.staged_bytes
        arrays = dict(
            src=slab.src, dst=slab.dst, edge_block=slab.edge_block,
            sparse_edge_mask=slab.sparse_mask, dense_edge_mask=slab.dense_mask,
        )
        if slab.tiles is not None:
            arrays.update(tiles=slab.tiles, tile_row_start=slab.tile_row_start,
                          tile_col_start=slab.tile_col_start)
        bufs = jax.device_put(arrays)
        if slab.extras is not None:
            bufs["extras"] = _put_arrays(slab.extras)
        return bufs

    def _wave_context(self, bufs: dict) -> Context:
        arrays = {k: v for k, v in bufs.items() if k != "extras"}
        extras = bufs.get("extras")
        if extras is not None:
            return with_arrays(self._resident, extras=extras, **arrays)
        return with_arrays(self._resident, **arrays)

    def _run_waves(self, state0, it: int):
        """One iteration's kernel work: stage + step every wave, folding
        partials; calibration (synchronous, timed) on the first executed
        iteration, double-buffered overlap afterwards."""
        acc = state0
        nw = len(self._slabs)
        if nw == 0:
            return acc, 0.0
        iarr = jnp.int32(it)
        if it < self._edge_free:
            # the algorithm declared these iterations edge-free
            # (kernels never read slab fields — e.g. Afforest's
            # neighbor-sampling rounds): one representative wave,
            # staged once and cached across the edge-free phase, gives
            # the identical combined result — W-1 redundant full-vertex
            # passes and all repeat stagings saved
            if self._edge_free_bufs is None:
                self._edge_free_bufs = self._stage(0)
            acc = self._step(self._wave_context(self._edge_free_bufs),
                             state0, acc, iarr, self._slabs[0].run_dense)
            return acc, 0.0
        self._edge_free_bufs = None     # release once edge work begins
        if self._calibration is None:
            # warm-up pass: trace/compile every distinct wave shape with
            # the result discarded, so the timed pass below measures
            # steady-state compute — not compilation (which would
            # otherwise saturate overlap_efficiency at 1.0)
            warm = state0
            for w in range(nw):
                warm = self._step(self._wave_context(self._stage(w)),
                                  state0, warm, iarr, self._slabs[w].run_dense)
            _block_tree(warm)
            stage_s = compute_s = 0.0
            for w in range(nw):
                t0 = time.perf_counter()
                bufs = self._stage(w)
                _block_tree(bufs)
                stage_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                acc = self._step(self._wave_context(bufs), state0, acc, iarr,
                                 self._slabs[w].run_dense)
                _block_tree(acc)
                compute_s += time.perf_counter() - t0
            self._calibration = dict(stage_s=stage_s, compute_s=compute_s)
            return acc, 0.0
        t0 = time.perf_counter()
        bufs = self._stage(0)
        for w in range(nw):
            ctx = self._wave_context(bufs)
            # async dispatch: the step for wave w starts on device...
            acc = self._step(ctx, state0, acc, iarr, self._slabs[w].run_dense)
            # ...while wave w+1's slab crosses host→device.  Dropping
            # `bufs` here releases the previous slab's buffers as soon
            # as the step consumes them (two slabs max in flight).
            bufs = self._stage(w + 1) if w + 1 < nw else None
        _block_tree(acc)
        return acc, time.perf_counter() - t0

    def run(self, store: BlockStore | None = None,
            state: Any | None = None) -> RunResult:
        """Execute the streamed iteration loop (same contract as
        :meth:`repro.core.engine.Plan.run`)."""
        if store is not None and store is not self.store:
            raise TypeError(
                "StreamingPlan is bound to the store it was compiled "
                "against; compile a new plan for a different graph"
            )
        alg = self.alg
        if state is None:
            assert alg.init_state is not None, f"{alg.name}: init_state required"
            state = alg.init_state(self.store)
        t0 = time.perf_counter()
        it = 0
        cont = True
        overlapped_wall = 0.0
        overlapped_iters = 0
        staged_before = self._bytes_staged
        while cont and it < alg.max_iterations:
            if alg.before is not None:
                state = alg.before(self.host, state, it)
            state, wall = self._run_waves(state, it)
            if wall > 0.0:
                overlapped_wall += wall
                overlapped_iters += 1
            if self._post is not None:
                state = self._post(self._resident, state, jnp.int32(it))
            if alg.after is not None:
                state, cont = alg.after(self.host, state, it)
            it += 1
        state = jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            state,
        )
        dt = time.perf_counter() - t0
        result = alg.finalize(self.store, state) if alg.finalize else state
        return RunResult(
            result=result,
            state=state,
            iterations=it,
            seconds=dt,
            schedule_stats=dict(
                self.schedule.stats,
                streaming=self._streaming_stats(
                    state, overlapped_wall, overlapped_iters,
                    staged_delta=self._bytes_staged - staged_before,
                ),
            ),
        )

    def _streaming_stats(self, state, overlapped_wall: float,
                         overlapped_iters: int, *,
                         staged_delta: int) -> dict:
        bytes_per_wave = [s.staged_bytes for s in self._slabs]
        calib = self._calibration or dict(stage_s=0.0, compute_s=0.0)
        eff = 0.0
        denom = min(calib["stage_s"], calib["compute_s"])
        if overlapped_iters and denom > 0:
            serial = calib["stage_s"] + calib["compute_s"]
            mean_wall = overlapped_wall / overlapped_iters
            eff = max(0.0, min(1.0, (serial - mean_wall) / denom))
        return dict(
            num_waves=len(self._slabs),
            budget_bytes=self.budget.total_bytes,
            bytes_per_wave=bytes_per_wave,
            # actual H2D traffic this run, counting the calibration
            # warm-up pass and edge-free single-wave iterations honestly
            bytes_staged_total=int(staged_delta),
            resident_bytes=(
                resident_bytes(self.store, state)
                + tree_array_bytes(self._resident_extras)
                + tree_array_bytes(state)     # the accumulator copy
            ),
            edge_buckets=sorted({s.src.shape[0] for s in self._slabs}),
            coalesced_segments=[s.segments for s in self._slabs],
            overlap_efficiency=eff,
            calibration=dict(calib),
            overlapped_iterations=overlapped_iters,
        )


def compile_streaming_plan(alg: BlockAlgorithm, store: BlockStore,
                           schedule: Schedule | None = None,
                           **kw) -> StreamingPlan:
    """Explicit spelling of ``compile_plan(..., memory_budget=...)``."""
    return StreamingPlan(alg, store, schedule, **kw)
