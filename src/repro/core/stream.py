"""Out-of-core streaming executor: memory-budgeted, pipelined waves.

This subsystem makes any :class:`~repro.core.engine.Plan`-compatible
algorithm runnable under an explicit device-memory budget — the paper's
headline capability ("graphs that fit host DRAM but not device memory",
§4.3/§4.4, the block-list bound on device copies).  Five parts:

1. **Footprint model** (:mod:`repro.core.membudget`) prices each
   schedule task's COO slice, dense tiles, conformal CSR row slices
   (for ``metadata["csr"] == "slice"`` algorithms), and kernel
   workspace in bytes.  The schedule itself is built budget-aware
   (:func:`repro.core.scheduler.build_schedule` receives the budget):
   ``tile_dim`` shrinks until a staged tile fits and tasks whose dense
   working set cannot fit are routed to the sparse path up front.
2. **Wave builder** packs the LPT-ordered tasks into budget-sized
   *waves*; every wave's edge slab is padded to one of a few fixed
   bucket shapes (power-of-two ladder) so a single jitted step serves
   all waves without retracing.  Within a wave, tasks are sorted by
   leading block id so the segmented-COO gather coalesces into few
   contiguous segments — staging approaches a single slice copy.
3. **Three-stage host→device pipeline**: after a one-time *planning
   pass* (assemble every wave once: verify bytes against the budget,
   split overflows, hoist wave-invariant extras, cache each wave's
   ``prepare`` outputs), the per-iteration wave loop runs as

   * **stage 1 — background assembly** (:class:`_StagePipeline`, a
     worker thread behind a bounded queue of depth
     ``pipeline_depth``): wave ``k+2``'s numpy slab is gathered into
     pooled arena buffers while wave ``k`` computes.  ``prepare``
     outputs ride with the staged payload (cached from the planning
     pass — never a synchronous loop step);
   * **stage 2 — double-buffered ``device_put``**: wave ``k+1``'s slab
     crosses host→device while the device works on wave ``k`` (JAX
     async dispatch — the analog of the paper's CUDA copy streams);
   * **stage 3 — compute**: the jitted wave step, folding partials.

   The first executed iteration runs synchronously to calibrate
   per-phase times (assemble / device_put / compute); every later
   iteration overlaps, and ``schedule_stats`` reports the measured
   ``overlap_efficiency`` plus ``host_stage_overlap`` — the fraction
   of background host assembly hidden behind compute.
4. **Staging arena** (:class:`_HostArena`): because all slabs are
   padded to the power-of-two bucket ladder, the pipeline draws its
   host buffers from one pool per (shape, dtype) and recycles wave
   ``k``'s buffers into a later wave's assembly.  Recycling is
   *completion-gated* — ``jax.device_put`` may alias host memory on
   CPU, so a buffer re-enters the pool only once the step that read it
   reports ready (non-blocking ``is_ready`` probe; iteration end is
   the force-drain barrier).  When the device keeps up, steady-state
   staging memory approaches the model's ``(depth + 1)``-slab bound
   (:func:`repro.core.membudget.arena_model_bytes` through the
   registry's ``stage_arena`` estimator; the measured high water is
   reported as ``arena_bytes``) instead of one fresh allocation per
   wave per iteration.  On device the bucket ladder plays the same
   role: at most two staged slabs (current + prefetch) are in flight,
   each ≤ the budget, and freed buffers match the next wave's shapes
   exactly, so the device allocator reuses them instead of churning.
5. **Partial-result combination**: each wave's kernels run against the
   *iteration-start* state and its per-leaf updates are folded with the
   algorithm's declared ``metadata["combine"]`` op (``add``/``min``/
   ``max`` — the same semantics as
   :func:`repro.core.distributed.combine_fn`), so streamed results
   match the in-core bulk-synchronous step: exactly for integer/bool
   attributes, and up to float summation order for real ones.  Leaves a
   kernel passes through untouched are detected at trace time and
   carried over unchanged, so no combine kind is needed for them.
   ``post`` (and the host hooks) run once per iteration on the combined
   state, against a *resident* context that holds only vertex-level
   arrays.

Cross-wave trace stability
--------------------------
The jitted wave step retraces once per distinct (slab shapes, extras
structure) combination.  Slab shapes are already bucketed (point 2);
``prepare`` outputs are kept shape-stable by the algorithm's optional
``stage_plan`` hook (:class:`~repro.core.functors.BlockAlgorithm`):
it runs once per plan against the *full* store/schedule and its result
is passed to every per-wave (and per-device) ``prepare``, so
shape-driving decisions — TC's dp/steps bucket ladder — are made once
for the whole plan.  ``schedule_stats["streaming"]["trace_count"]``
reports the step's trace counter: with the hook it is one per distinct
bucket shape, independent of the number of waves (the TC retrace that
used to dominate high-wave-count runs).  All compiled-step flavours
share the process-wide cache in :mod:`repro.core.compilecache`.

Tail-wave rebalancing — ``rebalance_threshold``
-----------------------------------------------
Default **on** (``"auto"``): after the calibration pass, the observed
per-wave compute shares are compared against the schedule's estimate
shares (task weights); when the worst wave's observed/estimated share
diverges beyond a hysteresis band (fire ≥ 2.0×, re-arm < 1.5×) *and*
the measured times are above the noise floor (mean wave ≥ 10 ms — tiny
runs are deterministically left alone, keeping staged-byte accounting
reproducible), the remaining iterations' waves are re-packed LPT
against the observed per-task times
(:func:`repro.core.membudget.repack_waves`) — the paper's dynamic work
queue at wave granularity.  A float keeps the legacy behavior (fire
when the max/mean compute skew exceeds it); ``None`` is the explicit
off switch.  A fire disarms the trigger and the post-re-pack
recalibration only re-arms it below the low watermark — so the
automatic path re-packs at most once per plan and a still-diverged but
freshly packed queue never thrashes.  Results are unchanged by
construction (per-wave folding is partition-invariant) and every
re-packed wave is re-verified against the byte budget.

CSR streaming — ``metadata["csr"]``
-----------------------------------
What happens to the CSR adjacency (``ctx.indices``) is declared by the
algorithm:

``"slice"``
    Each wave stages only the conformal CSR row ranges its tasks touch
    (:meth:`repro.core.blocks.BlockStore.csr_slices`): ``ctx.indices``
    holds the sliced adjacency, and the *wave store* handed to
    ``prepare`` carries the rebased ``row_block_ptr``/``indptr`` so
    host-computed positions (e.g. TC's bucket items) index the slice.
    Slice lengths are rebase-invariant; global vertex attributes remain
    on ``wstore.graph``.  Kernels must size by ``ctx.indices.shape[0]``,
    never ``ctx.m``.
``"none"``
    The kernels never read the adjacency (pure COO scatter/gather
    algorithms); ``ctx.indices`` is a minimal placeholder and nothing
    edge-proportional is staged or resident.
``"resident"`` (default for custom algorithms)
    The full ``indices`` stays device-resident, as before this
    distinction existed — safe for kernels that index it with global
    positions, but the device footprint is then *not* bounded by the
    budget (``resident_bytes`` reports it honestly).

Algorithms declaring ``edge_free_iterations`` (Afforest's neighbor
sampling) additionally get a *prefix CSR* (:func:`repro.core.graph.csr_prefix`)
— the first ``k`` neighbors of every row, ``n·k`` entries — swapped in
as ``ctx.indptr``/``ctx.indices`` during those iterations, so even
adjacency-sampling rounds stay vertex-proportional on device.

The device working set is: resident vertex-level arrays (state pytree,
``indptr``/``degrees``/``row_block_ptr``/``cuts``) plus at most two
staged wave slabs (current + prefetch), each ≤ the budget — with
``"slice"``/``"none"`` algorithms, *every* edge-proportional device
allocation is bounded by ``memory_budget``.

Mesh-cooperative streaming — ``mesh=``
--------------------------------------
``compile_plan(alg, store, memory_budget=..., mesh=mesh)`` composes the
waves with :mod:`repro.core.distributed`'s execution model: the budget
becomes *per device*, waves are packed to the mesh capacity
``D × budget`` (:func:`repro.core.membudget.build_waves`), and each
wave's tasks are LPT-split over the mesh so every device stages only
its own padded COO/CSR/tile slab
(:func:`repro.core.distributed.make_device_edge_partition`, bucket
ladder — and staging arena — shared with the single-device path).  The
same three-stage pipeline stages the *sharded* slabs: the background
worker assembles wave ``k+2``'s per-device slabs into arena buffers,
wave ``k+1``'s slabs ``device_put`` with the block-axis sharding while
the mesh computes wave ``k`` under ``shard_map``; inside the shard each
device runs the kernels on its slice from iteration-start state,
per-leaf updates are combined across the mesh with the algorithm's
declared ``metadata["combine"]`` collective (``psum``/``pmin``/``pmax``
— :func:`repro.core.distributed.combine_fn`) and folded into the
running accumulator, so results stay bit-identical to in-core for
integer/bool attributes and equal up to float summation order
otherwise.  Vertex attributes, the resident context, and the state are
replicated; only edge work is sharded — the paper's "reads are free,
writes are reduced" model at wave granularity.  Algorithms opt in with
``metadata["mesh"] == "shard"``; ``prepare`` runs per device against a
device-local store view (device-rebased CSR, device tile subset), and
structurally device-varying outputs are unified by the algorithm's
``mesh_pack`` hook (see :class:`~repro.core.functors.BlockAlgorithm`).
``schedule_stats["streaming"]`` grows ``mesh_devices``,
``per_device_bytes`` (each entry ≤ the per-device budget),
``collective_bytes``, and the mesh-wide ``overlap_efficiency``.  The
full model is documented in ``docs/distributed.md``.

Heterogeneous co-scheduling — ``host_fraction``
-----------------------------------------------
The host CPU is a compute resource, not just a staging engine: each
wave splits into a *device partition* (the streamed pipeline above)
and a *host partition* — the smallest/sparsest tasks peeled off by
:func:`repro.core.membudget.peel_host_tasks` into host execution
units that run the algorithm's sparse kernel eagerly on the CPU jax
backend (:class:`_HostLane`, a ``concurrent.futures`` thread pool)
against host-side store views.  Host tasks are never ``device_put``,
so they do not touch the memory budget; their partials fold into the
per-iteration state through the same ``metadata["combine"]`` contract
as device waves and mesh shards, keeping results bit-identical to a
device-only run for integer/boolean attributes.  ``host_fraction``
is ``"auto"`` by default — zero split until the calibration pass
measures per-wave times above a noise floor, then a hide-criterion
split with probe-based host-rate measurement and hysteresis
(:func:`repro.core.membudget.hetero_split_diverged`) — or a fixed
float in [0, 1]; ``None`` disables the lane.  ``schedule_stats``
gains a ``"hetero"`` block (split ratio, host/device task counts,
per-resource makespans) and the ``host-compute`` tracer lane carries
the per-unit spans.  Full model in ``docs/heterogeneous.md``.

Entry point: ``compile_plan(alg, store, memory_budget=...)`` returns a
:class:`StreamingPlan` instead of a :class:`~repro.core.engine.Plan`.
"""
from __future__ import annotations

import concurrent.futures
import os
import queue
import threading
import time
from dataclasses import dataclass, replace as dc_replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .. import obs
from .blocks import BlockStore
from .compilecache import alg_cache_key, shared_entry
from .context import _TRACED, Context, build_host_ctx, with_arrays
from .direction import (
    DirectionController, kernels_for, resolve_direction, workspace_kernels,
)
from .distributed import combine_fn, make_device_edge_partition
from .faults import FaultPlan, InjectedFault
from .functors import BlockAlgorithm
from .graph import csr_prefix
from .knobs import env_float as _knob_float, env_str as _knob_str
from .membudget import (
    HOST_RATIO_DEFAULT, MemoryBudget, PIPELINE_DEPTH, Wave,
    arena_model_bytes, bucket_size, build_waves, hetero_split_diverged,
    peel_host_tasks, repack_waves, resident_bytes, split_wave,
    task_footprints, tree_array_bytes,
)
from .resilience import (
    HostTaskError, ResilienceStats, RetryPolicy, WorkerDeath, classify,
)
from .scheduler import Schedule, build_schedule
from .engine import RunResult

__all__ = ["StreamingPlan", "compile_streaming_plan", "PHASES"]

#: Per-wave pipeline phases, in execution order — also the
#: ``stream.phase_seconds.<phase>`` metric-name suffixes.
PHASES = ("assemble", "prepare", "device_put", "compute", "collective",
          "host_compute")

_COMBINE_KINDS = ("add", "min", "max")
_CSR_MODES = ("resident", "slice", "none")

# Auto-rebalancing (default): fire when the *observed* wave-compute
# skew (max/mean) exceeds the skew the schedule's estimates predicted
# by _REBALANCE_HI; re-arm below _REBALANCE_LO (the hysteresis band
# keeps a borderline queue from flapping).  Comparing skews — not raw
# shares — makes the trigger insensitive to the constant per-wave
# dispatch overhead, and means "the estimate already predicted this
# imbalance" correctly stands down (LPT packed it as well as the bytes
# allow).  Below the noise floor the timings are dominated by dispatch
# jitter, so the trigger deterministically stands down — small runs
# keep reproducible staged-byte accounting.
_REBALANCE_HI = 2.0
_REBALANCE_LO = 1.5
_REBALANCE_NOISE_FLOOR_S = 10e-3


def _hetero_noise_floor_s() -> float:
    """Below this mean device-wave time the ``"auto"`` host split stays
    at zero: dispatch jitter dominates, so peeling would be decided by
    noise.  ``REPRO_HETERO_NOISE_FLOOR_S`` overrides (the hetero smoke
    lowers it to exercise the split on small CI graphs)."""
    return _knob_float("REPRO_HETERO_NOISE_FLOOR_S",
                       _REBALANCE_NOISE_FLOOR_S)


def _hetero_host_ratio_default() -> float:
    """Assumed host-vs-device slowdown before the host lane has been
    measured; ``REPRO_HETERO_HOST_RATIO`` overrides."""
    return _knob_float("REPRO_HETERO_HOST_RATIO", HOST_RATIO_DEFAULT)


def _combine_spec(alg: BlockAlgorithm):
    """metadata['combine'] → leaf-name → kind (or None when undeclared)."""
    c = alg.metadata.get("combine")
    if isinstance(c, str):
        return lambda key: c
    if isinstance(c, dict):
        return lambda key: c.get(key)
    return lambda key: None


def _combine_leaf(kind: str | None, key: str, acc, s0, new):
    if kind == "add":
        return acc + (new - s0)
    if kind == "min":
        return jnp.minimum(acc, new)
    if kind == "max":
        return jnp.maximum(acc, new)
    raise ValueError(
        f"state leaf {key!r} is modified by the kernels but declares no "
        f"combine kind in metadata['combine'] (one of {_COMBINE_KINDS}); "
        f"streaming cannot fold its per-wave partial results"
    )


class _StreamStep:
    """The jitted per-wave step: kernels from iteration-start state,
    partials folded into the running accumulator via the combine spec.

    Pass-through detection happens at trace time: a kernel that returns
    ``dict(state, acc=...)`` leaves the other values as the *same*
    tracer objects, which is exactly the contract "this wave did not
    touch that attribute"."""

    def __init__(self, alg: BlockAlgorithm, direction: str = "push") -> None:
        self.traces = 0
        spec = _combine_spec(alg)
        kernel_sparse, kernel_dense = kernels_for(alg, direction)

        def step(ctx: Context, state0, acc, it, run_dense: bool):
            self.traces += 1
            if not isinstance(state0, dict):
                raise TypeError(
                    f"{alg.name}: streaming requires a dict state pytree"
                )
            new = state0
            if kernel_sparse is not None:
                new = kernel_sparse(ctx, new, it)
            if kernel_dense is not None and run_dense:
                new = kernel_dense(ctx, new, it)
            added = set(new) - set(state0)
            if added:  # the in-core step would forward these to post;
                # per-wave there is no baseline to combine them against
                raise ValueError(
                    f"{alg.name}: kernels added state leaves "
                    f"{sorted(added)}; streaming requires kernels to "
                    f"write only leaves present in init_state (declare "
                    f"scratch attributes there)"
                )
            out = {}
            for key in state0:
                s0, nw = state0[key], new[key]
                out[key] = (
                    acc[key] if nw is s0
                    else _combine_leaf(spec(key), key, acc[key], s0, nw)
                )
            return out

        self._jit = jax.jit(step, static_argnums=(4,))

    def __call__(self, ctx, state0, acc, it, run_dense: bool):
        return self._jit(ctx, state0, acc, it, run_dense)


class _PostStep:
    """``post`` + trace counter, jitted once per algorithm identity."""

    def __init__(self, alg: BlockAlgorithm) -> None:
        self.traces = 0

        def step(ctx: Context, state, it):
            self.traces += 1
            return alg.post(ctx, state, it)

        self._jit = jax.jit(step)

    def __call__(self, ctx, state, it):
        return self._jit(ctx, state, it)


def _split_static(tree):
    """Flatten ``tree`` into (array leaves, hashable aux): the same
    traced/static split :class:`~repro.core.context.Context` applies to
    ``extras``, reused here so a wave's stacked extras can cross the
    jitted mesh step as a plain tuple of sharded arrays while ints such
    as TC's ``dp``/``steps`` stay static (they drive shapes)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = tuple(leaf for leaf in leaves if _is_array_leaf(leaf))
    markers = tuple(
        _TRACED if _is_array_leaf(leaf) else leaf for leaf in leaves
    )
    return arrays, (treedef, markers)


def _rejoin_static(aux, arrays):
    treedef, markers = aux
    arr = iter(arrays)
    leaves = [next(arr) if m is _TRACED else m for m in markers]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class _MeshStreamStep:
    """The jitted mesh per-wave step: ``shard_map`` over the wave.

    Each device of the 1-D mesh receives its own shard of the wave's
    padded slab (COO, routing masks, CSR slice, tiles) plus its slice of
    the device-stacked extras, runs the kernels from the *replicated*
    iteration-start state, and the per-leaf updates are combined across
    the mesh with the algorithm's declared collective — ``psum`` for
    additive leaves (on the delta from iteration start, so replicated
    baselines are not multiplied by D), ``pmin``/``pmax`` elementwise —
    then folded into the running accumulator exactly like
    :class:`_StreamStep` does per wave.  Pass-through detection is the
    same trace-time identity test; the mesh program is SPMD, so a leaf
    is uniformly touched or untouched on every device.

    ``combined_keys`` records (at trace time) which state leaves
    actually crossed a collective — the honest basis for the
    ``collective_bytes`` accounting in ``schedule_stats``.
    """

    def __init__(self, alg: BlockAlgorithm, mesh: Mesh,
                 direction: str = "push") -> None:
        self.traces = 0
        self.combined_keys: tuple[str, ...] = ()
        spec = _combine_spec(alg)
        kernel_sparse, kernel_dense = kernels_for(alg, direction)
        axis = mesh.axis_names[0]

        def step(res_ctx, slab, ex_leaves, state0, acc, it,
                 run_dense: bool, ex_aux):
            self.traces += 1
            if not isinstance(state0, dict):
                raise TypeError(
                    f"{alg.name}: streaming requires a dict state pytree"
                )

            def body(res_ctx, slab, ex_leaves, state0, acc, it):
                # each shard sees [1, ...] slices — drop the device axis
                arrays = {k: v[0] for k, v in slab.items()}
                extras = dict(res_ctx.extras)
                if ex_aux is not None:
                    extras.update(_rejoin_static(
                        ex_aux, tuple(leaf[0] for leaf in ex_leaves)
                    ))
                ctx = with_arrays(res_ctx, extras=extras, **arrays)
                new = state0
                if kernel_sparse is not None:
                    new = kernel_sparse(ctx, new, it)
                if kernel_dense is not None and run_dense:
                    new = kernel_dense(ctx, new, it)
                added = set(new) - set(state0)
                if added:
                    raise ValueError(
                        f"{alg.name}: kernels added state leaves "
                        f"{sorted(added)}; streaming requires kernels to "
                        f"write only leaves present in init_state (declare "
                        f"scratch attributes there)"
                    )
                out = {}
                combined = []
                for key in state0:
                    s0, nw = state0[key], new[key]
                    if nw is s0:
                        out[key] = acc[key]
                        continue
                    kind = spec(key)
                    if kind not in _COMBINE_KINDS:
                        raise ValueError(
                            f"state leaf {key!r} is modified by the kernels "
                            f"but declares no combine kind in "
                            f"metadata['combine'] (one of {_COMBINE_KINDS}); "
                            f"the mesh cannot fold its per-device partials"
                        )
                    red = combine_fn(kind, axis)(
                        nw - s0 if kind == "add" else nw
                    )
                    if kind == "add":
                        out[key] = acc[key] + red
                    elif kind == "min":
                        out[key] = jnp.minimum(acc[key], red)
                    else:
                        out[key] = jnp.maximum(acc[key], red)
                    combined.append(key)
                self.combined_keys = tuple(combined)
                return out

            P = PartitionSpec
            return shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(axis), P(axis), P(), P(), P()),
                out_specs=P(),
                check_rep=False,
            )(res_ctx, slab, ex_leaves, state0, acc, it)

        self._jit = jax.jit(step, static_argnums=(6, 7))

    def __call__(self, res_ctx, slab, ex_leaves, state0, acc, it,
                 run_dense: bool, ex_aux):
        return self._jit(res_ctx, slab, ex_leaves, state0, acc, it,
                         run_dense, ex_aux)


_STREAM_STEP_CACHE: dict[tuple, _StreamStep] = {}
_POST_STEP_CACHE: dict[tuple, _PostStep] = {}


def _stream_step_for(alg: BlockAlgorithm, backend: str, *,
                     share: bool = True,
                     direction: str = "push") -> _StreamStep:
    return shared_entry(_STREAM_STEP_CACHE,
                        alg_cache_key(alg, backend, direction),
                        lambda: _StreamStep(alg, direction), share=share)


def _post_step_for(alg: BlockAlgorithm, backend: str, *,
                   share: bool = True) -> _PostStep | None:
    if alg.post is None:
        return None
    return shared_entry(_POST_STEP_CACHE, alg_cache_key(alg, backend),
                        lambda: _PostStep(alg), share=share)


# ----------------------------------------------------------------------
class _HostArena:
    """Pooled host staging buffers, one free-list per (shape, dtype).

    Every wave slab is padded to the power-of-two bucket ladder, so a
    handful of buffer shapes serves the whole plan: the pipeline
    *takes* zeroed buffers for assembly and *gives* them back once the
    step that read them completed (completion-gated — see the plan's
    ``_park_for_recycle``), keeping steady-state staging memory near
    ``(depth + 1)`` slabs instead of a fresh allocation per wave per
    iteration.  Thread-safe (the background worker takes while the
    main loop gives)."""

    def __init__(self) -> None:
        self._free: dict[tuple, list[np.ndarray]] = {}
        self._lock = threading.Lock()
        self.bytes = 0          # high-water: total bytes ever pooled
        self.reuses = 0

    def take(self, shape, dtype=np.float64) -> np.ndarray:
        key = (tuple(np.atleast_1d(shape).tolist())
               if not np.isscalar(shape) else (int(shape),),
               np.dtype(dtype).str)
        with self._lock:
            pool = self._free.get(key)
            buf = pool.pop() if pool else None
        if buf is None:
            buf = np.zeros(shape, dtype)
            self.bytes += buf.nbytes
            return buf
        self.reuses += 1
        buf.fill(0)             # padding semantics: zeroed like np.zeros
        return buf

    def give(self, *arrays: np.ndarray) -> None:
        with self._lock:
            for a in arrays:
                if a is None:
                    continue
                key = (tuple(a.shape), a.dtype.str)
                self._free.setdefault(key, []).append(a)


class _StagePipeline:
    """Stage 1 of the pipeline: a persistent background worker that
    assembles wave slabs ahead of the compute loop, behind a bounded
    queue.

    With depth ``d`` the worker runs at most ``d`` waves ahead — wave
    ``k+2``'s gathers (and nothing else: ``prepare`` outputs were
    cached by the planning pass) happen while wave ``k`` computes and
    wave ``k+1``'s ``device_put`` crosses the bus.  The worker lives
    across iterations: the main loop *requests* each iteration's wave
    epoch, and requests the next one as soon as the current epoch's
    last slab is drained, so the next iteration's first waves assemble
    while ``post``/host hooks run — no per-iteration cold start.
    ``assemble_s`` is the worker's busy time, ``stall_s`` the main
    loop's time blocked on the queue — their ratio is the
    ``host_stage_overlap`` statistic."""

    def __init__(self, plan: "StreamingPlan", depth: int) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=max(int(depth), 1))
        self._cmd: queue.Queue = queue.Queue()
        self.assemble_s = 0.0
        self.stall_s = 0.0
        self.dead = False
        self._err: BaseException | None = None
        self._t = threading.Thread(target=self._work, args=(plan,),
                                   name="repro-staging", daemon=True)
        self._t.start()

    def _work(self, plan: "StreamingPlan") -> None:
        try:
            while True:
                indices = self._cmd.get()
                if indices is None:
                    return
                for w in indices:
                    t0 = time.perf_counter()
                    slab = plan._assemble_runtime(plan._slabs[w], wave=w)
                    self.assemble_s += time.perf_counter() - t0
                    self._q.put(slab)
        except BaseException as e:  # surfaced on the consumer side
            self._err = e
            self._q.put(None)

    def request(self, indices) -> None:
        """Enqueue one epoch (an iteration's wave order) for assembly."""
        self._cmd.put(list(indices))

    def get(self) -> "_WaveSlab":
        t0 = time.perf_counter()
        slab = self._q.get()
        self.stall_s += time.perf_counter() - t0
        if slab is None:
            # the worker died; mark it so the watchdog fails over to
            # synchronous assembly instead of waiting on a dead queue
            self.dead = True
            raise WorkerDeath(self._err)
        return slab

    def close(self, arena: _HostArena) -> None:
        """Stop the worker; speculatively assembled slabs hand their
        buffers straight back to the arena (they were never staged).
        Keeps draining while the worker finishes its in-flight epoch
        (it may be blocked on the bounded queue), then joins the thread
        so teardown is deterministic — no daemon-thread leak survives
        ``StreamingPlan.close()``."""
        self._cmd.put(None)
        while self._t.is_alive() or not self._q.empty():
            try:
                slab = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if slab is not None:
                arena.give(*slab.arena_arrays)
        self._t.join(timeout=5.0)


# ----------------------------------------------------------------------
class _HostLane:
    """The host-CPU compute lane of heterogeneous co-scheduling.

    Each execution *unit* is one wave's peeled ``host_task_ids``
    (:func:`repro.core.membudget.peel_host_tasks`).  A unit's context is
    built once — the unit's COO slice gathered from the host store, the
    global CSR views shared across every unit, and the algorithm's
    ``prepare`` outputs for the unit's restricted sub-schedule — with
    every array leaf committed to the host CPU jax backend, and the
    sparse kernel runs *eagerly* under ``jax.default_device(cpu)`` in a
    ``concurrent.futures`` thread pool while the device pipeline
    streams its own waves.  Nothing here is ever ``device_put`` to the
    accelerator: host units never touch the memory budget.

    Peeled dense tasks run the sparse formulation on the host — each
    unit's sub-schedule clears its dense routing masks, and the two
    paths agree per block-list (the same property the dense/sparse
    split relies on), so results stay bit-identical for integer/bool
    attributes.  Per-unit updates fold through the identical
    ``metadata["combine"]`` contract as device waves: ``add`` folds the
    delta from iteration-start state, ``min``/``max`` fold elementwise,
    and pass-through leaves are detected by the same identity test
    :class:`_StreamStep` applies at trace time — here evaluated
    eagerly, where it holds for exactly the same ``dict(state, k=v)``
    kernel idiom.

    ``prepare`` runs against the *global* store view (``plan=None`` —
    the unpadded branch of staged-prepare algorithms), so
    host-computed positions index the global CSR the host already
    holds; nothing is sliced or rebased for the host lane.
    """

    def __init__(self, plan: "StreamingPlan",
                 units: list[np.ndarray]) -> None:
        self.plan = plan
        self.units = [np.asarray(u, np.int64) for u in units]
        self._spec = _combine_spec(plan.alg)
        self._cpu = jax.devices("cpu")[0]
        store = plan.store
        t0 = time.perf_counter()
        with jax.default_device(self._cpu):
            # global CSR views: converted to CPU-committed jax arrays
            # ONCE and shared by every unit context (eager lax.cond
            # traces both kernel branches, so even csr="none"
            # algorithms need indexable adjacency leaves — and numpy
            # arrays indexed by tracers would fail inside the trace)
            self._globals = {
                k: self._put(v) for k, v in dict(
                    indptr=store.indptr, indices=store.indices,
                    degrees=store.degrees,
                    row_block_ptr=store.row_block_ptr,
                    cuts=store.layout.cuts,
                ).items()
            }
            self._ctxs = [self._unit_context(ids) for ids in self.units]
        plan._phase["prepare"] += time.perf_counter() - t0
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=min(len(self.units),
                            max(1, (os.cpu_count() or 2) - 1)),
            thread_name_prefix="repro-host",
        )

    def _put(self, a):
        """CPU-committed jax array from any array-like."""
        return jax.device_put(np.asarray(a), self._cpu)

    def _unit_context(self, ids: np.ndarray) -> Context:
        plan = self.plan
        store, sched = plan.store, plan.schedule
        hsched = sched.restrict(ids)
        # peeled dense tasks run the sparse formulation on the host:
        # clearing the routing masks sends every edge down the sparse
        # path and keeps prepare from bucketing dense-path work
        hsched.dense_task_mask = np.zeros(hsched.num_tasks, bool)
        hsched.dense_block_ids = np.zeros(0, np.int32)
        blocks = np.unique(hsched.blocklists)
        segments = store.edge_segments(blocks)
        idx = (
            np.concatenate([np.arange(s, e, dtype=np.int64)
                            for s, e in segments])
            if segments else np.zeros(0, np.int64)
        )
        extras = {}
        if plan.alg.prepare is not None:
            extras = _to_host(plan.alg.run_prepare(store, hsched, None))
            extras.pop("__workspace_bytes__", None)
        extras = jax.tree_util.tree_map(
            lambda l: self._put(l) if _is_array_leaf(l) else l, extras
        )
        ne = int(idx.size)
        return Context(
            extras=extras,
            n=store.n, m=store.m, p=store.p,
            tile_dim=sched.tile_dim,
            backend="reference",
            src=self._put(store.src[idx]),
            dst=self._put(store.dst[idx]),
            edge_block=self._put(store.edge_block[idx]),
            sparse_edge_mask=self._put(np.ones(ne, bool)),
            dense_edge_mask=self._put(np.zeros(ne, bool)),
            **self._globals,
        )

    def submit(self, state0, it: int, direction: str = "push") -> list:
        """Snapshot iteration-start state to the host CPU and dispatch
        every unit into the pool; returns futures for ``fold``.

        ``direction`` selects the sparse kernel variant — the host lane
        must run the *same* direction as the device waves within one
        iteration, or the push/pull bit-identity contract (which holds
        per direction, not across a mix) breaks."""
        hstate = {k: self._put(v) for k, v in state0.items()}
        iarr = self._put(np.int32(it))
        kernel, _ = kernels_for(self.plan.alg, direction)
        return [self._pool.submit(self._run_unit, u, hstate, iarr, kernel)
                for u in range(len(self.units))]

    def _run_unit(self, u: int, hstate: dict, iarr, kernel):
        it = int(np.asarray(jax.device_get(iarr)))
        try:
            return self._run_unit_inner(u, hstate, iarr, kernel)
        except HostTaskError:
            raise
        except Exception as e:
            # attach unit/task/iteration blame here, where it is known —
            # not at fold time, where the bare future exception used to
            # surface with no context at all
            raise HostTaskError(u, self.units[u].tolist(), it, e) from e

    def _run_unit_inner(self, u: int, hstate: dict, iarr, kernel):
        alg = self.plan.alg
        faults = self.plan._faults
        t0 = time.perf_counter()
        with obs.span("host_compute", lane="host-compute", unit=u,
                      tasks=int(self.units[u].size)):
            if faults is not None:
                faults.fire("host.task", unit=u)
            with jax.default_device(self._cpu):
                new = kernel(self._ctxs[u], hstate, iarr)
        added = set(new) - set(hstate)
        if added:
            raise ValueError(
                f"{alg.name}: kernels added state leaves "
                f"{sorted(added)}; streaming requires kernels to "
                f"write only leaves present in init_state (declare "
                f"scratch attributes there)"
            )
        payload = {}
        for key, s0 in hstate.items():
            nw = new[key]
            if nw is s0:
                continue
            kind = self._spec(key)
            if kind not in _COMBINE_KINDS:
                raise ValueError(
                    f"state leaf {key!r} is modified by the kernels but "
                    f"declares no combine kind in metadata['combine'] "
                    f"(one of {_COMBINE_KINDS}); the host lane cannot "
                    f"fold its per-unit partial results"
                )
            payload[key] = (
                kind,
                np.asarray(nw - s0) if kind == "add" else np.asarray(nw),
            )
        return payload, time.perf_counter() - t0

    def fold(self, results: list, acc: dict) -> tuple[dict, float]:
        """Merge every unit's payload (in unit order — deterministic)
        and fold ONCE into the device accumulator with the same
        semantics as :func:`_combine_leaf`: exact for integer/boolean
        attributes, up to summation order for floats."""
        merged: dict[str, tuple[str, np.ndarray]] = {}
        busy_s = 0.0
        for payload, dt in results:
            busy_s += dt
            for key, (kind, val) in payload.items():
                if key not in merged:
                    merged[key] = (kind, val)
                elif kind == "add":
                    merged[key] = (kind, merged[key][1] + val)
                elif kind == "min":
                    merged[key] = (kind, np.minimum(merged[key][1], val))
                else:
                    merged[key] = (kind, np.maximum(merged[key][1], val))
        out = dict(acc)
        for key, (kind, val) in merged.items():
            v = jnp.asarray(val)
            if kind == "add":
                out[key] = acc[key] + v
            elif kind == "min":
                out[key] = jnp.minimum(acc[key], v)
            else:
                out[key] = jnp.maximum(acc[key], v)
        return out, busy_s

    def close(self, wait: bool = False) -> None:
        """Shut the pool down; ``wait=True`` joins the worker threads —
        the deterministic-teardown path of ``StreamingPlan.close()``."""
        self._pool.shutdown(wait=wait, cancel_futures=True)


# ----------------------------------------------------------------------
@dataclass
class _WaveSlab:
    """Host-side staged form of one wave: padded numpy arrays ready for
    a single ``jax.device_put`` per iteration.

    Under a mesh the same fields carry a leading device axis (``[D, …]``
    per-device slabs, uniformly padded), ``staged_bytes`` totals the
    whole wave's H2D traffic, and ``per_device_bytes`` is the share one
    mesh device holds — the quantity the per-device budget bounds.
    ``arena_arrays`` names the buffers drawn from the staging arena
    (runtime assembly only) so ``_put_slab`` can recycle exactly those."""

    wave: Wave
    src: np.ndarray
    dst: np.ndarray
    edge_block: np.ndarray
    sparse_mask: np.ndarray
    dense_mask: np.ndarray
    tiles: np.ndarray | None
    tile_row_start: np.ndarray | None
    tile_col_start: np.ndarray | None
    csr: np.ndarray | None         # bucket-padded conformal CSR slice
    extras: Any                    # host pytree, or None once hoisted resident
    run_dense: bool
    staged_bytes: int
    workspace_bytes: int           # kernel scratch estimate (not staged)
    edges: int
    segments: int                  # coalesced COO slices gathered
    csr_entries: int               # unpadded CSR slice length
    csr_segments: int              # coalesced CSR row-range gathers
    per_device_bytes: int = 0      # one device's staged share (mesh)
    arena_arrays: tuple = ()       # arena-owned buffers to recycle
    prep_ws: int = 0               # prepare-declared share of workspace


@dataclass
class _WaveRecipe:
    """The retained, array-free description of one planned wave.

    The planning pass assembles every wave once (budget verification,
    splits, hoisting, byte accounting) and keeps only this recipe plus
    the cached ``prepare`` outputs — the big gather arrays are
    reproduced per iteration by the staging pipeline into arena
    buffers, so host memory holds ``O(pipeline depth)`` slabs instead
    of every wave at once."""

    wave: Wave
    run_dense: bool
    staged_bytes: int
    workspace_bytes: int
    per_device_bytes: int
    edges: int
    segments: int
    csr_entries: int
    csr_segments: int
    csr_bytes: int                 # padded CSR slab bytes (0 when none)
    src_bucket: int                # padded edge-slab width
    extras: Any = None             # cached post-hoist prepare outputs


@dataclass
class _PlanUnit:
    """One wave mid-planning: the assembled slab plus its *raw* prepare
    outputs, so :meth:`StreamingPlan._fit_unified` can re-derive the
    shared extras shapes after any split without re-running prepare."""

    slab: _WaveSlab
    dev_extras: list | None = None   # mesh: per-device raw prepare outputs
    raw_extras: Any = None           # single device: the wave's raw outputs
    base_staged: int = 0             # staged bytes excluding extras
    base_ws: int = 0
    prep_ws: int = 0                 # prepare-declared share of base_ws

    @classmethod
    def of_single(cls, slab: _WaveSlab) -> "_PlanUnit":
        return cls(
            slab=slab, raw_extras=slab.extras,
            base_staged=slab.staged_bytes - tree_array_bytes(slab.extras),
            base_ws=slab.workspace_bytes, prep_ws=slab.prep_ws,
        )


def _is_array_leaf(leaf: Any) -> bool:
    return isinstance(leaf, (np.ndarray, jax.Array))


def _to_host(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: np.asarray(l) if _is_array_leaf(l) else l, tree
    )


def _put_arrays(tree: Any) -> Any:
    """device_put only the array leaves; static leaves stay untouched."""
    return jax.tree_util.tree_map(
        lambda l: jax.device_put(l) if _is_array_leaf(l) else l, tree
    )


def _trees_equal(a: Any, b: Any) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if _is_array_leaf(x) != _is_array_leaf(y):
            return False
        if _is_array_leaf(x):
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                return False
        elif x != y:
            return False
    return True


def _block_tree(tree: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


_ABSENT = object()


# ----------------------------------------------------------------------
class StreamingPlan:
    """A compiled plan whose execution streams budget-sized waves.

    Produced by ``compile_plan(alg, store, memory_budget=...)``.  Same
    ``run()`` contract as :class:`~repro.core.engine.Plan` (hooks, post,
    iteration control, RunResult), but the per-iteration step is the
    three-stage pipelined wave loop described in the module docstring,
    and ``schedule_stats`` additionally carries a ``"streaming"`` dict:
    wave count, bytes staged per wave (each ≤ budget), resident bytes,
    per-phase wall clock, trace count, arena bytes, and the measured
    overlap efficiencies.
    """

    def __init__(self, alg: BlockAlgorithm, store: BlockStore,
                 schedule: Schedule | None = None, *,
                 memory_budget: int | str | MemoryBudget,
                 backend: str = "xla", num_devices: int = 1,
                 mode: str = "hybrid", tile_dim: int = 512,
                 dense_frac: float = 0.5, dense_density: float = 0.005,
                 rebalance_threshold: float | str | None = "auto",
                 pipeline_depth: int = PIPELINE_DEPTH,
                 share: bool = True, mesh: Mesh | None = None,
                 host_fraction: float | str | None = "auto",
                 direction: str | None = None,
                 faults: "str | FaultPlan | None" = None,
                 checkpoint_every: int | None = None,
                 checkpoint_dir: str | None = None,
                 retry_policy: RetryPolicy | None = None) -> None:
        from ..kernels.registry import host_executable, resolve_backend

        self.alg = alg
        self.store = store
        self.backend = resolve_backend(backend)
        self.direction = resolve_direction(alg, direction)
        # None keeps the pre-direction contract (plain push, no
        # controller, no schedule_stats["direction"] block)
        self._direction_requested = direction is not None
        self._direction_now = "push"    # the current iteration's choice
        self.budget = MemoryBudget.of(memory_budget)
        self._csr_mode = str(alg.metadata.get("csr", "resident"))
        if self._csr_mode not in _CSR_MODES:
            raise ValueError(
                f"{alg.name}: metadata['csr'] must be one of {_CSR_MODES}, "
                f"got {self._csr_mode!r}"
            )
        self.mesh = mesh
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    "mesh-cooperative streaming requires a 1-D mesh (one "
                    f"block-parallel axis); got axes {mesh.axis_names}"
                )
            if alg.metadata.get("mesh") != "shard":
                raise ValueError(
                    f"{alg.name}: metadata['mesh'] must declare 'shard' to "
                    "run under a mesh — the kernels must decompose over any "
                    "partition of a wave's tasks judged from iteration-start "
                    "state, and prepare must restrict to a device-local view "
                    "(see docs/distributed.md)"
                )
            self.mesh_axis = mesh.axis_names[0]
            self._mesh_devices = int(mesh.size)
        else:
            self.mesh_axis = None
            self._mesh_devices = 1
        if not (rebalance_threshold is None
                or rebalance_threshold == "auto"
                or isinstance(rebalance_threshold, (int, float))):
            raise ValueError(
                "rebalance_threshold must be 'auto' (default: deterministic "
                "estimate-vs-observed divergence trigger), a float (legacy "
                "compute-skew threshold), or None (off); got "
                f"{rebalance_threshold!r}"
            )
        self.rebalance_threshold = rebalance_threshold
        # -- heterogeneous co-scheduling: the host CPU as a resource ---
        if not (host_fraction is None or host_fraction == "auto"
                or isinstance(host_fraction, (int, float))):
            raise ValueError(
                "host_fraction must be 'auto' (default: calibrated "
                "host/device split), a float in [0, 1] (fixed share of "
                "each wave's work peeled to the host CPU), or None "
                f"(off); got {host_fraction!r}"
            )
        if (isinstance(host_fraction, (int, float))
                and not 0.0 <= float(host_fraction) <= 1.0):
            raise ValueError(
                f"host_fraction must lie in [0, 1]; got {host_fraction!r}"
            )
        host_flag = str(alg.metadata.get("host", "auto"))
        if host_flag not in ("auto", "never"):
            raise ValueError(
                f"{alg.name}: metadata['host'] must be 'auto' or "
                f"'never', got {host_flag!r}"
            )
        blockers = []
        if alg.kernel_sparse is None:
            blockers.append("the algorithm has no kernel_sparse (host "
                            "units run the sparse formulation)")
        if host_flag == "never":
            blockers.append("metadata['host'] declares 'never'")
        uncertified = [k for k in alg.metadata.get("host_kernels", ())
                       if not host_executable(k)]
        if uncertified:
            blockers.append(
                f"metadata['host_kernels'] names kernels not certified "
                f"host-executable: {uncertified}"
            )
        if mesh is not None:
            blockers.append("mesh-cooperative streaming (the mesh "
                            "already owns the wave partition)")
        self._host_capable = not blockers
        if (isinstance(host_fraction, (int, float))
                and float(host_fraction) > 0.0 and blockers):
            raise ValueError(
                f"{alg.name}: host_fraction={host_fraction!r} requires "
                f"host-lane capability — " + "; ".join(blockers)
            )
        self._host_frac_req = host_fraction
        # "auto" resolves to a zero split until calibration activates
        # it; an incapable algorithm silently stays device-only there
        self._host_frac = (
            host_fraction
            if self._host_capable and host_fraction is not None else 0.0
        )
        # -- fault tolerance: injection, retry ladder, checkpoints -----
        # REPRO_FAULTS is the env spelling of compile_plan(faults=...);
        # an explicit argument wins.  Disabled is self._faults = None —
        # every seam guards with one `is not None` check (the obs idiom)
        self._faults = FaultPlan.parse(
            faults if faults is not None else _knob_str("REPRO_FAULTS"))
        if retry_policy is not None and not isinstance(retry_policy,
                                                       RetryPolicy):
            raise TypeError(
                f"retry_policy must be a repro.core.resilience."
                f"RetryPolicy; got {type(retry_policy).__name__}")
        self._policy = retry_policy or RetryPolicy()
        if checkpoint_every is not None and int(checkpoint_every) < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1; got {checkpoint_every!r}")
        if checkpoint_every is not None and checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every requires checkpoint_dir (where the "
                "per-iteration snapshots persist)")
        # a directory alone means "checkpoint every iteration"
        self._ckpt_every = (int(checkpoint_every) if checkpoint_every
                            else (1 if checkpoint_dir else 0))
        self._ckpt_dir = checkpoint_dir
        self._resil = ResilienceStats()
        self._injected_pub = 0          # injections already published
        self._sync_iters_left = 0       # transient sync-assembly window
        self._worker_deaths = 0
        self._host_failures = 0
        self._host_futs: list | None = None   # in-flight host futures
        self.pipeline_depth = max(int(pipeline_depth), 0)
        self.schedule = schedule or build_schedule(
            alg, store, num_devices=max(num_devices, self._mesh_devices),
            mode=mode, tile_dim=tile_dim, dense_frac=dense_frac,
            dense_density=dense_density, memory_budget=self.budget,
            direction=self.direction,
        )
        self.host = build_host_ctx(store, self.schedule, backend=self.backend)
        # the cross-wave staging plan: shape-driving prepare decisions
        # (TC's bucket ladder) made once against the FULL schedule
        self._plan_state = (
            alg.stage_plan(store, self.schedule)
            if alg.stage_plan is not None else None
        )
        self._phase = {p: 0.0 for p in PHASES}
        self._arena = _HostArena()
        self._arena_deferred: list[tuple] = []
        self._pipe: _StagePipeline | None = None

        # "auto" prices the max over the push/pull dense variants, so
        # whichever direction an iteration picks fits the planned budget
        self._workspace_decl = workspace_kernels(alg, self.direction)
        self._footprints = task_footprints(
            store, self.schedule,
            workspace_kernel=self._workspace_decl,
            stage_csr=self._csr_mode == "slice",
        )
        self._host_ratio = _hetero_host_ratio_default()
        self._host_units: list[np.ndarray] = []
        self._host_lane: _HostLane | None = None
        self._host_seconds = 0.0
        self._host_tasks_executed = 0
        self._host_measured = False
        self._hetero_refreshes = 0
        waves = build_waves(store, self.schedule, self.budget,
                            self._footprints, devices=self._mesh_devices,
                            host_fraction=self._host_frac,
                            host_ratio=self._host_ratio)
        self._apply_waves(waves, initial=True)
        # the one-time planning pass's host cost (per-wave prepare),
        # reported separately from the per-run phase deltas
        self._planning_phase = dict(self._phase)
        self._resident = self._build_resident_context()
        self._step = _stream_step_for(alg, self.backend, share=share)
        self._mesh_step = (
            _MeshStreamStep(alg, mesh) if mesh is not None else None
        )
        # pull twins, built only when the plan may take a pull
        # iteration; each direction traces once (cache keys the variant)
        want_pull = self.direction in ("pull", "auto")
        self._step_pull = (
            _stream_step_for(alg, self.backend, share=share,
                             direction="pull") if want_pull else None
        )
        self._mesh_step_pull = (
            _MeshStreamStep(alg, mesh, "pull")
            if want_pull and mesh is not None else None
        )
        self._post = _post_step_for(alg, self.backend, share=share)
        self._calibration: dict | None = None
        self._collective_bytes = 0      # payload across mesh combines
        self._collective_unit_s = 0.0   # isolated all-reduce estimate
        self._bytes_staged = 0          # actual H2D traffic, all passes
        self._stall_s = 0.0             # main loop blocked on the queue
        self._assemble_overlapped_s = 0.0
        self._edge_free = int(alg.metadata.get("edge_free_iterations", 0))
        self._edge_free_bufs: dict | None = None
        # first-k-neighbors CSR for the edge-free sampling phase: the
        # only adjacency those iterations see (vertex-proportional)
        self._prefix_host = (
            csr_prefix(store.indptr, store.indices, self._edge_free)
            if self._edge_free > 0 else None
        )
        self._prefix_dev: dict | None = None
        self._rebalanced = False
        self._reb_armed = True
        self._last_skew: float | None = None
        self._last_divergence: float | None = None
        self.schedule.stats["waves"] = len(self._slabs)

    # -- build side (planning pass) ------------------------------------
    def _plan_recipes(self, waves: list[Wave], *,
                      initial: bool = False) -> list[_WaveRecipe]:
        """Assemble each wave once, decide hoisting (first build only),
        unify extras shapes across waves, verify/split against the
        budget, and retain only the recipes.

        Any wave whose *actual* staged bytes overflow the budget (model
        under-priced prepare extras, bucket padding) is split.  Wave-
        invariant extras are hoisted resident *before* the budget check
        — they are staged once, not per wave, so counting them per wave
        would spuriously reject (or over-split) workable budgets."""
        if self.mesh is not None:
            units = [self._make_unit(w) for w in waves]
            if initial:
                self._resident_extras: dict = {}
                self._hoisted = False
                trees = [e for u in units for e in u.dev_extras]
                if trees and all(_trees_equal(e, trees[0])
                                 for e in trees[1:]):
                    # device- and wave-invariant prepare outputs
                    # (PageRank's inv_deg, ...) are staged once,
                    # replicated over the mesh
                    self._resident_extras = trees[0]
                    self._hoisted = True
            if self._hoisted or self.alg.mesh_pack is None:
                slabs = [self._finalize_mesh_extras(u.slab, u.dev_extras)
                         for u in units]
                slabs = self._fit_slabs(slabs)
            else:
                slabs = self._fit_unified(units)
        else:
            slabs = [self._assemble(w) for w in waves]
            if initial:
                self._decide_hoist(slabs)
            else:
                # re-pack rebuild: the hoist decision stands (the
                # resident context already carries the hoisted extras)
                for s in slabs:
                    self._strip_hoisted(s)
            if self._hoisted or self.alg.mesh_pack is None:
                slabs = self._fit_slabs(slabs)
            else:
                slabs = self._fit_unified(
                    [_PlanUnit.of_single(s) for s in slabs]
                )
        return [self._recipe(s) for s in slabs]

    def _apply_waves(self, waves: list[Wave], *,
                     initial: bool = False) -> None:
        """Install a packed wave list: device tasks stay in the
        streaming pipeline (empty waves vanish), peeled
        ``host_task_ids`` become host-lane execution units, and the
        lane (thread pool + per-unit CPU contexts) is rebuilt."""
        if self._host_lane is not None:
            self._host_lane.close()
            self._host_lane = None
        self._host_units = [w.host_task_ids for w in waves
                            if w.host_task_ids.size]
        dev_waves = [w for w in waves if w.task_ids.size]
        self._slabs = self._plan_recipes(dev_waves, initial=initial)
        edge_free = int(self.alg.metadata.get("edge_free_iterations", 0))
        if (self._host_units and not self._slabs and not self._hoisted
                and self.alg.prepare is not None
                and (self.alg.post is not None or edge_free > 0)):
            # fully host-peeled plan (host_fraction=1.0): post / the
            # edge-free phase still run against the resident context,
            # whose extras are normally hoisted from the device waves'
            # prepare outputs — no device wave exists here, so prepare
            # runs once against the full store instead
            extras = _to_host(self.alg.run_prepare(
                self.store, self.schedule, self._plan_state))
            extras.pop("__workspace_bytes__", None)
            self._resident_extras = extras
            self._hoisted = True
        if self._host_units:
            self._host_lane = _HostLane(self, self._host_units)
        self.schedule.stats["waves"] = len(self._slabs)

    def _make_unit(self, wave: Wave) -> "_PlanUnit":
        """Assemble one wave into a planning unit (raw extras kept)."""
        if self.mesh is not None:
            slab, lst = self._assemble_mesh(wave)
            return _PlanUnit(slab=slab, dev_extras=lst,
                             base_staged=slab.staged_bytes,
                             base_ws=slab.workspace_bytes,
                             prep_ws=slab.prep_ws)
        slab = self._assemble(wave)
        self._strip_hoisted(slab)
        return _PlanUnit.of_single(slab)

    def _fit_unified(self, units: list["_PlanUnit"]) -> list[_WaveSlab]:
        """Cross-wave shape cache + budget fit, to fixpoint.

        Every wave's ``prepare`` outputs are padded to one shared shape
        set via the algorithm's ``mesh_pack`` — it already solves
        exactly this problem for per-device outputs (unify
        data-dependent structures like TC's bucket ladder with
        kernel-neutral padding, array leaves gaining a leading axis);
        treating the *waves* (× devices, under a mesh) as that axis
        makes every wave's extras structurally identical, so the jitted
        step traces once per distinct slab shape instead of once per
        wave.  Because padding can push a unified slab over the budget,
        the loop verifies the *unified* bytes, splits any offender, and
        re-unifies the new wave set (smaller waves shrink the shared
        caps) until every wave fits.  When even a single-task wave
        cannot afford the shared caps (very tight budgets), unification
        is abandoned for the whole plan — per-wave shapes cost extra
        jit traces but keep the ≤ budget invariant without refusing a
        runnable workload."""
        if not units:       # fully host-peeled plan: no device waves
            return []
        d = self._mesh_devices
        while True:
            slabs = [u.slab for u in units]
            if self.mesh is not None:
                flat = [e for u in units for e in u.dev_extras]
                packed = _to_host(self.alg.mesh_pack(flat))

                def sliced(w):
                    return jax.tree_util.tree_map(
                        lambda leaf: (leaf[w * d: (w + 1) * d]
                                      if _is_array_leaf(leaf) else leaf),
                        packed,
                    )
            else:
                packed = _to_host(
                    self.alg.mesh_pack([u.raw_extras for u in units])
                )

                def sliced(w):
                    return jax.tree_util.tree_map(
                        lambda leaf: (leaf[w] if _is_array_leaf(leaf)
                                      else leaf),
                        packed,
                    )
            # uniform shapes → uniform device scratch.  mesh_pack may
            # re-declare the prepare scratch for the *unified* shapes
            # (every wave now runs every bucket at the padded cap — the
            # per-wave pre-unification declarations can under-count
            # when different waves define different buckets' caps);
            # the dense-path share stays the per-wave max.
            ws_decl = None
            if isinstance(packed, dict):
                ws_decl = packed.pop("__workspace_bytes__", None)
            if ws_decl is not None:
                ws = (max(u.base_ws - u.prep_ws for u in units)
                      + int(ws_decl))
            else:
                ws = max(u.base_ws for u in units)
            for w, u in enumerate(units):
                u.slab.extras = sliced(w)
                u.slab.staged_bytes = (
                    u.base_staged + tree_array_bytes(u.slab.extras)
                )
                u.slab.workspace_bytes = ws
                if self.mesh is not None:
                    u.slab.per_device_bytes = -(-u.slab.staged_bytes // d)
            over = {
                w for w, u in enumerate(units)
                if self._budget_load(u.slab) > self.budget.total_bytes
            }
            if not over:
                return slabs
            try:
                rebuilt: list[_PlanUnit] = []
                for w, u in enumerate(units):
                    if w in over:
                        a, b = split_wave(u.slab.wave, self.schedule,
                                          self._footprints)
                        rebuilt += [self._make_unit(a), self._make_unit(b)]
                    else:
                        rebuilt.append(u)
                units = rebuilt
            except ValueError:
                # a single-task wave cannot afford the shared caps:
                # fall back to raw per-wave shapes for the whole plan
                return self._fit_slabs(
                    [self._restore_raw(u) for u in units]
                )

    def _restore_raw(self, u: "_PlanUnit") -> _WaveSlab:
        """Undo shape unification on one planning unit."""
        slab = u.slab
        slab.workspace_bytes = u.base_ws
        if self.mesh is not None:
            slab.staged_bytes = u.base_staged
            slab.extras = None
            return self._finalize_mesh_extras(slab, u.dev_extras)
        slab.extras = u.raw_extras
        slab.staged_bytes = u.base_staged + tree_array_bytes(u.raw_extras)
        return slab

    def _recipe(self, slab: _WaveSlab) -> _WaveRecipe:
        return _WaveRecipe(
            wave=slab.wave, run_dense=slab.run_dense,
            staged_bytes=slab.staged_bytes,
            workspace_bytes=slab.workspace_bytes,
            per_device_bytes=slab.per_device_bytes,
            edges=slab.edges, segments=slab.segments,
            csr_entries=slab.csr_entries, csr_segments=slab.csr_segments,
            csr_bytes=slab.csr.nbytes if slab.csr is not None else 0,
            src_bucket=int(slab.src.shape[-1]),
            extras=slab.extras,
        )

    def _reassemble(self, wave: Wave) -> _WaveSlab:
        """One wave → finished slab, honoring the standing hoist
        decision — shared by budget splits and rebalance rebuilds."""
        if self.mesh is not None:
            slab, extras_list = self._assemble_mesh(wave)
            return self._finalize_mesh_extras(slab, extras_list)
        slab = self._assemble(wave)
        self._strip_hoisted(slab)
        return slab

    def _budget_load(self, slab: _WaveSlab) -> int:
        """The bytes the budget must bound: one device's staged share
        plus its kernel scratch (per-device under a mesh; the whole
        slab on a single device)."""
        staged = (slab.per_device_bytes if self.mesh is not None
                  else slab.staged_bytes)
        return staged + slab.workspace_bytes

    def _fit_slabs(self, slabs: list[_WaveSlab]) -> list[_WaveSlab]:
        out: list[_WaveSlab] = []
        pending = list(slabs)
        while pending:
            slab = pending.pop(0)
            if self._budget_load(slab) > self.budget.total_bytes:
                # staged arrays + kernel scratch are the wave's real
                # device footprint; split_wave raises for size-1 waves —
                # the ≤ budget invariant is never silently violated
                a, b = split_wave(slab.wave, self.schedule, self._footprints)
                pending[:0] = [self._reassemble(a), self._reassemble(b)]
                continue
            out.append(slab)
        return out

    def _assemble_runtime(self, recipe: _WaveRecipe, *,
                          wave: int = -1) -> _WaveSlab:
        """Stage-1 body: reproduce one wave's slab into arena buffers.

        Pure gathers — ``prepare`` ran in the planning pass and its
        (post-hoist) outputs are cached on the recipe, so the worker
        thread never touches jax or the algorithm.  Byte accounting is
        pinned to the recipe's planned numbers (they are equal by
        construction; pinning keeps the stats deterministic).  The span
        lands on the ``staging`` lane whichever thread runs it — the
        background worker in steady state, the main loop during
        calibration and at ``pipeline_depth=0``."""
        with obs.span("assemble", lane="staging", wave=wave,
                      bytes=recipe.staged_bytes):
            if self._faults is not None:
                # fires on whichever thread assembles: a raise in the
                # background worker surfaces as WorkerDeath at get()
                self._faults.fire("stage.assemble", wave=wave)
            if self.mesh is not None:
                slab, _ = self._assemble_mesh(
                    recipe.wave, extras=recipe.extras,
                    alloc=self._arena.take,
                )
            else:
                slab = self._assemble(recipe.wave, extras=recipe.extras,
                                      alloc=self._arena.take)
        slab.staged_bytes = recipe.staged_bytes
        slab.workspace_bytes = recipe.workspace_bytes
        slab.per_device_bytes = recipe.per_device_bytes
        return slab

    def _assemble(self, wave: Wave, *, extras: Any = _ABSENT,
                  alloc=None) -> _WaveSlab:
        """Assemble one wave's padded host slab.

        Planning mode (``extras`` absent): build the wave-local store
        view, run the algorithm's ``prepare`` against it (timed into
        the ``prepare`` phase), and measure the staged bytes.  Runtime
        mode (``extras`` given — the recipe's cached outputs, possibly
        ``None`` after hoisting): gathers only, drawn from ``alloc``
        (the staging arena)."""
        store, sched = self.store, self.schedule
        zeros = alloc if alloc is not None else np.zeros
        planning = extras is _ABSENT
        wsched = sched.restrict(wave.task_ids)
        blocks = np.unique(wsched.blocklists)
        segments = store.edge_segments(blocks)
        idx = (
            np.concatenate([np.arange(s, e, dtype=np.int64)
                            for s, e in segments])
            if segments else np.zeros(0, np.int64)
        )
        ne = int(idx.size)
        eb = bucket_size(ne)
        src = zeros(eb, np.int32)
        dst = zeros(eb, np.int32)
        edge_block = zeros(eb, np.int32)
        sparse_mask = zeros(eb, bool)
        dense_mask = zeros(eb, bool)
        arena_arrays = [src, dst, edge_block, sparse_mask, dense_mask]
        if ne:
            src[:ne] = store.src[idx]
            dst[:ne] = store.dst[idx]
            edge_block[:ne] = store.edge_block[idx]
            dense_blocks = np.zeros(store.layout.num_blocks, bool)
            if wsched.dense_block_ids.size:
                dense_blocks[wsched.dense_block_ids] = True
            edense = dense_blocks[edge_block[:ne]]
            sparse_mask[:ne] = ~edense
            dense_mask[:ne] = edense

        # -- dense tiles (already materialized by build_schedule) ------
        tiles = trs = tcs = None
        run_dense = (
            self.alg.kernel_dense is not None
            and bool(wsched.dense_task_mask.any())
        )
        wstore = store
        if run_dense:
            sub, sub_rs, sub_cs = store.tile_subset(wsched.dense_block_ids)
            nd = sub.shape[0]
            tb = bucket_size(nd, minimum=1)
            t = sched.tile_dim
            tiles = zeros((tb, t, t), np.float32)
            tiles[:nd] = sub
            trs = zeros(tb, np.int64)
            trs[:nd] = sub_rs
            tcs = zeros(tb, np.int64)
            tcs[:nd] = sub_cs
            arena_arrays += [tiles, trs, tcs]
            if planning and self.alg.prepare is not None:
                wstore = dc_replace(
                    store, tile_dim=t,
                    tile_block_ids=wsched.dense_block_ids.astype(np.int32),
                    tiles=sub, tile_row_start=sub_rs, tile_col_start=sub_cs,
                )
        elif planning and self.alg.prepare is not None:
            # prepare must not see tiles the wave does not stage
            wstore = dc_replace(
                store, tile_dim=0,
                tile_block_ids=np.zeros(0, np.int32),
                tiles=np.zeros((0, 0, 0), np.float32),
                tile_row_start=np.zeros(0, np.int64),
                tile_col_start=np.zeros(0, np.int64),
            )

        # -- conformal CSR row slices (metadata["csr"] == "slice") -----
        csr = None
        csr_entries = csr_segments = 0
        if self._csr_mode == "slice":
            sl_idx, rbp_r, indptr_r, csr_segs = store.csr_slices(blocks)
            csr_entries = int(sl_idx.size)
            csr_segments = len(csr_segs)
            cb = bucket_size(csr_entries)
            csr = zeros(cb, np.int32)
            csr[:csr_entries] = sl_idx
            arena_arrays.append(csr)
            if planning and self.alg.prepare is not None:
                # prepare sees the wave-local CSR view: positions it
                # computes from row_block_ptr index the staged slice
                wstore = dc_replace(
                    wstore, indices=sl_idx, row_block_ptr=rbp_r,
                    indptr=indptr_r,
                )

        ws = prep_ws = 0
        if planning:
            t0 = time.perf_counter()
            extras = _to_host(
                self.alg.run_prepare(wstore, wsched, self._plan_state)
            )
            self._phase["prepare"] += time.perf_counter() - t0
            # prepare may declare additional device scratch (e.g. TC's
            # bucketed membership-test gather) under the reserved key;
            # it is a budget input, not a kernel input
            ws = prep_ws = int(extras.pop("__workspace_bytes__", 0))

        staged = (
            src.nbytes + dst.nbytes + edge_block.nbytes
            + sparse_mask.nbytes + dense_mask.nbytes
            + tree_array_bytes(extras)
        )
        if csr is not None:
            staged += csr.nbytes
        if tiles is not None:
            staged += tiles.nbytes + trs.nbytes + tcs.nbytes
            if planning:
                from ..kernels.registry import (
                    max_workspace_bytes, workspace_bytes,
                )

                wk = self._workspace_decl
                hints = dict(nd=int(tiles.shape[0]), tile_dim=sched.tile_dim)
                ws += (workspace_bytes(wk, **hints) if wk is not None
                       else max_workspace_bytes(**hints))
        return _WaveSlab(
            wave=wave, src=src, dst=dst, edge_block=edge_block,
            sparse_mask=sparse_mask, dense_mask=dense_mask,
            tiles=tiles, tile_row_start=trs, tile_col_start=tcs,
            csr=csr, extras=extras, run_dense=run_dense,
            staged_bytes=int(staged), workspace_bytes=int(ws),
            edges=ne, segments=len(segments),
            csr_entries=csr_entries, csr_segments=csr_segments,
            arena_arrays=tuple(arena_arrays) if alloc is not None else (),
            prep_ws=int(prep_ws),
        )

    def _assemble_mesh(self, wave: Wave, *, extras: Any = _ABSENT,
                       alloc=None) -> tuple[_WaveSlab, list]:
        """Assemble one wave as padded per-device slabs ``[D, …]``.

        The wave's tasks are LPT-split over the mesh
        (:meth:`~repro.core.scheduler.Schedule.partition_tasks` on the
        wave's restricted sub-schedule), each device's COO/CSR slices
        come from :func:`~repro.core.distributed.make_device_edge_partition`
        (every block of every assigned task, bucket-ladder padded so all
        waves share a few slab shapes), dense tiles are per-device
        subsets zero-padded to the wave's tile bucket (zero tiles are
        neutral for every shipped kernel: no set bits → no contribution),
        and — in the planning pass — ``prepare`` runs once per device
        against a device-local store view (device-rebased CSR maps,
        device tile subset) so host-computed positions index that
        device's staged slice.  Runtime re-assembly (``extras`` given)
        skips prepare and attaches the recipe's cached stacked extras.

        Returns the slab plus the per-device prepare outputs (planning
        only); :meth:`_finalize_mesh_extras` hoists or stacks them.
        """
        store, sched = self.store, self.schedule
        zeros = alloc if alloc is not None else np.zeros
        planning = extras is _ABSENT
        d = self._mesh_devices
        t = sched.tile_dim
        wsched = sched.restrict(wave.task_ids)
        assign = wsched.partition_tasks(d)
        part = make_device_edge_partition(
            store, wsched, assignment=assign, num_devices=d, bucket=True,
            stage_csr=self._csr_mode == "slice", alloc=alloc,
        )
        src, dst = part["src"], part["dst"]
        edge_block, valid = part["edge_block"], part["valid"]
        dense_blocks = np.zeros(store.layout.num_blocks, bool)
        if wsched.dense_block_ids.size:
            dense_blocks[wsched.dense_block_ids] = True
        edense = dense_blocks[edge_block] & valid
        sparse_mask = valid & ~edense
        dense_mask = edense
        arena_arrays = [src, dst, edge_block, valid]
        run_dense = (
            self.alg.kernel_dense is not None
            and bool(wsched.dense_task_mask.any())
        )
        dev_scheds = [
            wsched.restrict(np.nonzero(assign == i)[0]) for i in range(d)
        ]

        # -- per-device dense tiles, padded to the wave tile bucket ----
        tiles = trs = tcs = None
        tb = 0
        empty_sub = (np.zeros((0, t, t), np.float32),
                     np.zeros(0, np.int64), np.zeros(0, np.int64))
        dev_subs = [empty_sub] * d      # reused below for prepare views
        if run_dense:
            nds = [int(ds.dense_block_ids.size) for ds in dev_scheds]
            tb = bucket_size(max(nds), minimum=1)
            tiles = zeros((d, tb, t, t), np.float32)
            trs = zeros((d, tb), np.int64)
            tcs = zeros((d, tb), np.int64)
            arena_arrays += [tiles, trs, tcs]
            for i, ds in enumerate(dev_scheds):
                if ds.dense_block_ids.size:
                    dev_subs[i] = store.tile_subset(ds.dense_block_ids)
                    sub, sub_rs, sub_cs = dev_subs[i]
                    tiles[i, : sub.shape[0]] = sub
                    trs[i, : sub.shape[0]] = sub_rs
                    tcs[i, : sub.shape[0]] = sub_cs

        # -- per-device prepare against device-local store views -------
        ws = prep_ws = 0
        extras_list: list = []
        if planning and self.alg.prepare is not None:
            t_prep = time.perf_counter()
            for i, ds in enumerate(dev_scheds):
                if run_dense:
                    sub, sub_rs, sub_cs = dev_subs[i]
                    wstore = dc_replace(
                        store, tile_dim=t,
                        tile_block_ids=ds.dense_block_ids.astype(np.int32),
                        tiles=sub, tile_row_start=sub_rs,
                        tile_col_start=sub_cs,
                    )
                else:
                    wstore = dc_replace(
                        store, tile_dim=0,
                        tile_block_ids=np.zeros(0, np.int32),
                        tiles=np.zeros((0, 0, 0), np.float32),
                        tile_row_start=np.zeros(0, np.int64),
                        tile_col_start=np.zeros(0, np.int64),
                    )
                if self._csr_mode == "slice":
                    rbp_i, indptr_i = part["csr_maps"][i]
                    sl = part["indices"][i, : part["csr_entries"][i]]
                    wstore = dc_replace(
                        wstore, indices=sl, row_block_ptr=rbp_i,
                        indptr=indptr_i,
                    )
                dev_extras = _to_host(
                    self.alg.run_prepare(wstore, ds, self._plan_state)
                )
                ws = max(ws, int(dev_extras.pop("__workspace_bytes__", 0)))
                extras_list.append(dev_extras)
            prep_ws = ws
            self._phase["prepare"] += time.perf_counter() - t_prep
        elif planning:
            extras_list = [{} for _ in range(d)]

        if planning and run_dense:
            from ..kernels.registry import max_workspace_bytes, workspace_bytes

            wk = self._workspace_decl
            hints = dict(nd=tb, tile_dim=t)   # per-device padded count
            ws += (workspace_bytes(wk, **hints) if wk is not None
                   else max_workspace_bytes(**hints))

        csr = part.get("indices")
        if csr is not None and alloc is not None:
            arena_arrays.append(csr)
        staged = (
            src.nbytes + dst.nbytes + edge_block.nbytes
            + sparse_mask.nbytes + dense_mask.nbytes
        )
        if csr is not None:
            staged += csr.nbytes
        if tiles is not None:
            staged += tiles.nbytes + trs.nbytes + tcs.nbytes
        slab = _WaveSlab(
            wave=wave, src=src, dst=dst, edge_block=edge_block,
            sparse_mask=sparse_mask, dense_mask=dense_mask,
            tiles=tiles, tile_row_start=trs, tile_col_start=tcs,
            csr=csr, extras=None if planning else extras,
            run_dense=run_dense,
            staged_bytes=int(staged), workspace_bytes=int(ws),
            edges=int(sum(part["edges"])),
            segments=int(sum(part["segments"])),
            csr_entries=int(sum(part.get("csr_entries", []))),
            csr_segments=int(sum(part.get("csr_segments", []))),
            arena_arrays=tuple(arena_arrays) if alloc is not None else (),
            prep_ws=int(prep_ws),
        )
        return slab, extras_list

    def _finalize_mesh_extras(self, slab: _WaveSlab,
                              extras_list: list) -> _WaveSlab:
        """Attach a mesh slab's extras (hoisted → none; else stacked
        with a leading device axis) and fix the byte accounting."""
        if (self._hoisted
                and all(_trees_equal(e, self._resident_extras)
                        for e in extras_list)):
            slab.extras = None
        else:
            slab.extras = self._stack_extras(extras_list)
            if (isinstance(slab.extras, dict)
                    and "__workspace_bytes__" in slab.extras):
                # mesh_pack re-declared the prepare scratch for the
                # stacked (per-device padded) shapes — swap it in for
                # the per-device pre-pack declaration
                decl = int(slab.extras.pop("__workspace_bytes__"))
                slab.workspace_bytes += decl - slab.prep_ws
                slab.prep_ws = decl
            slab.staged_bytes += tree_array_bytes(slab.extras)
        slab.per_device_bytes = -(-slab.staged_bytes // self._mesh_devices)
        return slab

    def _stack_extras(self, extras_list: list):
        """Per-device prepare outputs → one tree with a leading device
        axis: the algorithm's ``mesh_pack`` when provided (required for
        structurally device-varying outputs like TC's bucket ladder),
        else a plain stack of structurally identical trees.  Padding is
        never invented here — a neutral pad value is algorithm
        knowledge, so shape mismatches without ``mesh_pack`` raise."""
        alg = self.alg
        if alg.mesh_pack is not None:
            return _to_host(alg.mesh_pack(extras_list))
        flat = [jax.tree_util.tree_flatten(e) for e in extras_list]
        leaves0, treedef0 = flat[0]
        err = (
            f"{alg.name}: per-device prepare outputs differ in "
            f"structure or shape across mesh devices; provide "
            f"BlockAlgorithm.mesh_pack to unify them (padding must be "
            f"neutral for the kernels)"
        )
        if any(td != treedef0 for _, td in flat[1:]):
            raise ValueError(err)
        stacked = []
        for i, leaf0 in enumerate(leaves0):
            col = [leaves for leaves, _ in flat]
            vals = [c[i] for c in col]
            if _is_array_leaf(leaf0):
                if len({np.asarray(v).shape for v in vals}) != 1:
                    raise ValueError(err)
                stacked.append(np.stack([np.asarray(v) for v in vals]))
            else:
                if any(v != leaf0 for v in vals[1:]):
                    raise ValueError(err)
                stacked.append(leaf0)
        return jax.tree_util.tree_unflatten(treedef0, stacked)

    def _decide_hoist(self, slabs: list[_WaveSlab]) -> None:
        """Wave-invariant ``prepare`` outputs (vertex-level attribute
        arrays like PageRank's ``inv_deg``) are staged once as resident
        instead of once per wave per iteration."""
        self._resident_extras: dict = {}
        self._hoisted = False
        if not slabs:
            return
        first = slabs[0].extras
        if all(_trees_equal(s.extras, first) for s in slabs[1:]):
            self._resident_extras = first
            self._hoisted = True
            for s in slabs:
                self._strip_hoisted(s)

    def _strip_hoisted(self, slab: _WaveSlab) -> None:
        """Drop a slab's extras (and their byte cost) when they match
        the hoisted resident tree — also applied to slabs rebuilt by a
        budget split after the hoist decision."""
        if (self._hoisted and slab.extras is not None
                and _trees_equal(slab.extras, self._resident_extras)):
            slab.staged_bytes -= tree_array_bytes(slab.extras)
            slab.extras = None

    def _replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())

    def _put_replicated(self, tree: Any) -> Any:
        """device_put array leaves — replicated over the mesh when one
        is set (reads are free: every device holds the vertex-level
        arrays and the state), plain single-device placement otherwise."""
        if self.mesh is None:
            return _put_arrays(tree)
        sh = self._replicated_sharding()
        return jax.tree_util.tree_map(
            lambda leaf: jax.device_put(leaf, sh)
            if _is_array_leaf(leaf) else leaf,
            tree,
        )

    def _build_resident_context(self) -> Context:
        """Vertex-level arrays only — the per-wave slab fields start
        empty and are swapped in by :func:`with_arrays` each wave.

        ``indices`` is the full CSR only in ``"resident"`` csr mode; in
        ``"slice"`` mode each wave swaps in its staged slice, and in
        ``"none"`` mode kernels never read it, so a minimal placeholder
        keeps both traced branches of conditional kernels indexable
        without holding ``m``-proportional memory.  Under a mesh every
        resident array is replicated on all devices (the model's
        "reads are free" half — writes are reduced by the collectives)."""
        store = self.store
        indices = (
            np.asarray(store.indices) if self._csr_mode == "resident"
            else np.zeros(bucket_size(0), np.int32)
        )
        arrays = self._put_replicated(dict(
            src=np.zeros(0, np.int32),
            dst=np.zeros(0, np.int32),
            edge_block=np.zeros(0, np.int32),
            indptr=np.asarray(store.indptr),
            indices=indices,
            degrees=np.asarray(store.degrees),
            row_block_ptr=np.asarray(store.row_block_ptr),
            cuts=np.asarray(store.layout.cuts),
            sparse_edge_mask=np.zeros(0, bool),
            dense_edge_mask=np.zeros(0, bool),
        ))
        return Context(
            extras=self._put_replicated(dict(self._resident_extras)),
            n=store.n,
            m=store.m,
            p=store.p,
            tile_dim=self.schedule.tile_dim,
            backend=self.backend,
            **arrays,
        )

    # -- execute side --------------------------------------------------
    @property
    def num_waves(self) -> int:
        return len(self._slabs)

    @property
    def resident_device_bytes(self) -> int:
        """Device bytes of holding this streamed plan hot, state
        excluded: the cross-wave resident arrays (vertex-level store
        arrays, hoisted extras, the global CSR only in ``"resident"``
        mode) plus the double-buffered worst-case wave — two staged
        slabs (current + prefetch) and the kernel workspace.  The
        serving admission controller prices a resident streamed plan
        with this bound; query state is priced separately per batch."""
        worst = max(
            (s.staged_bytes + s.workspace_bytes for s in self._slabs),
            default=0,
        )
        return int(
            resident_bytes(self.store,
                           include_csr=self._csr_mode == "resident")
            + tree_array_bytes(self._resident_extras)
            + 2 * worst
        )

    def _estimate_shares(self) -> np.ndarray:
        """Each wave's share of the schedule's total weight — the
        estimate the auto-rebalance trigger diverges against."""
        w = np.asarray([
            float(self.schedule.weights[s.wave.task_ids].sum())
            for s in self._slabs
        ])
        tot = w.sum()
        return w / tot if tot > 0 else np.full(w.shape, 1.0 / max(w.size, 1))

    def rebalance(self, wave_compute_s) -> bool:
        """Re-pack the wave queue against observed per-wave compute times.

        The paper's dynamic work queue at wave granularity, evaluated
        automatically after the calibration pass.  Trigger modes (see
        ``rebalance_threshold``):

        * ``"auto"`` (default) — deterministic estimate-vs-observed
          divergence with hysteresis: each wave's observed compute
          share is compared against its estimated share (schedule
          weights); the re-pack fires when the worst ratio reaches
          ``2.0`` and re-arms below ``1.5``.  Measurements below the
          noise floor (mean wave < 10 ms) never fire — dispatch jitter
          at that scale would make the staged-byte accounting
          nondeterministic.
        * float — legacy skew trigger: fire when max/mean of
          ``wave_compute_s`` exceeds the threshold.
        * ``None`` — off.

        On fire, each wave's time is attributed to its tasks
        proportionally to their schedule weights and the whole queue is
        re-packed LPT against those observed times
        (:func:`repro.core.membudget.repack_waves`) — still under the
        byte budget (re-verified per assembled wave).  Later iterations
        run the re-packed waves; per-wave partial folding makes any
        task partition produce the identical combined state, so results
        are unchanged.  Returns True when a re-pack happened.  The
        automatic path fires at most once per plan (a fire disarms the
        trigger, and the post-re-pack recalibration only re-arms it —
        never re-fires); callers feeding fresh timings through this
        method directly can fire again once an evaluation re-armed the
        latch.  The legacy float trigger stays strictly one-shot.
        """
        times = np.asarray(wave_compute_s, dtype=np.float64)
        if times.size != len(self._slabs) or len(self._slabs) < 2:
            return False
        mean = float(times.mean())
        if mean <= 0.0:
            return False
        self._last_skew = float(times.max() / mean)
        thr = self.rebalance_threshold
        if thr is None:
            return False
        if thr == "auto":
            est = self._estimate_shares()
            est_skew = float(est.max() * est.size) if est.size else 1.0
            self._last_divergence = self._last_skew / max(est_skew, 1.0)
            if mean < _REBALANCE_NOISE_FLOOR_S:
                return False            # noise-dominated: stand down
            # the hysteresis latch: a fire disarms the trigger, and the
            # post-re-pack recalibration re-evaluates here — a queue
            # that is still diverged (≥ LO) stays disarmed rather than
            # thrashing through another re-pack; only once an
            # evaluation sees divergence back under LO does the trigger
            # re-arm (relevant to callers feeding rebalance() fresh
            # timings per run — the automatic path fires at most once)
            if self._last_divergence < _REBALANCE_LO:
                self._reb_armed = True
                return False
            if not self._reb_armed or self._last_divergence < _REBALANCE_HI:
                return False            # inside the band, or disarmed
            self._reb_armed = False
        else:
            if self._rebalanced:
                return False            # legacy float trigger: one-shot
            if self._last_skew <= float(thr):
                return False
        task_t = np.zeros(self.schedule.num_tasks, dtype=np.float64)
        for t_w, slab in zip(times, self._slabs):
            ids = slab.wave.task_ids
            wts = self.schedule.weights[ids].astype(np.float64)
            tot = float(wts.sum())
            task_t[ids] = (t_w * wts / tot) if tot > 0 else t_w / ids.size
        if self._host_units:
            # host tasks never ran on the device: give them device-
            # equivalent times at the measured device rate so the
            # re-pack sees the whole schedule, then re-peel to preserve
            # the standing host/device split across the new packing
            dev_w = float(sum(self.schedule.weights[s.wave.task_ids].sum()
                              for s in self._slabs))
            dev_rate = float(times.sum()) / dev_w if dev_w > 0 else 0.0
            for ids in self._host_units:
                task_t[ids] = self.schedule.weights[ids] * dev_rate
        new_waves = repack_waves(self.schedule, self.budget,
                                 self._footprints, task_t,
                                 devices=self._mesh_devices)
        if self._host_units:
            new_waves = peel_host_tasks(
                self.schedule, new_waves, self._host_frac,
                task_times=task_t, host_ratio=self._host_ratio,
                footprints=self._footprints,
            )
        self._apply_waves(new_waves)
        self._edge_free_bufs = None     # stale slab-0 reference
        self._rebalanced = True
        obs.metrics.counter("stream.rebalances").inc()
        obs.instant("rebalance", lane="main", skew=self._last_skew,
                    waves=len(self._slabs))
        return True

    @property
    def compile_count(self) -> int:
        steps = ((self._mesh_step, self._mesh_step_pull)
                 if self._mesh_step is not None
                 else (self._step, self._step_pull))
        return sum(s.traces for s in steps if s is not None)

    def _active_steps(self):
        """The (single-device, mesh) step pair for the direction the
        controller picked for the current iteration."""
        if self._direction_now == "pull":
            return self._step_pull, self._mesh_step_pull
        return self._step, self._mesh_step

    # -- arena recycling ------------------------------------------------
    # ``jax.device_put`` of a numpy array may alias the host memory
    # instead of copying (CPU zero-copy), so a slab's arena buffers are
    # only safe to reuse once the step that read them has COMPLETED —
    # not merely been dispatched.  Each staged slab is parked with a
    # probe leaf of its step's output; ``is_ready()`` (non-blocking)
    # gates the hand-back, and a barrier point (iteration end, where
    # ``_block_tree`` already waits) force-drains the queue.
    def _park_for_recycle(self, slab: _WaveSlab, acc) -> None:
        if not slab.arena_arrays:
            return
        probe = next(
            (leaf for leaf in jax.tree_util.tree_leaves(acc)
             if hasattr(leaf, "is_ready")), None,
        )
        self._arena_deferred.append((probe, slab.arena_arrays))

    def _drain_recycle(self, *, force: bool = False) -> None:
        while self._arena_deferred:
            probe, arrays = self._arena_deferred[0]
            if not (force or probe is None or probe.is_ready()):
                return
            self._arena.give(*arrays)
            self._arena_deferred.pop(0)

    def _put_slab(self, slab: _WaveSlab, *, wave: int = -1):
        """Stage 2: one host→device copy of an assembled wave slab.

        Single device: a dict of device buffers.  Mesh: the ``[D, …]``
        slabs are ``device_put`` with the block-axis sharding (one row
        per device) and the stacked extras travel as a tuple of sharded
        leaves plus their hashable static aux — the pipeline overlaps
        exactly this transfer with the previous wave's compute."""
        if self._faults is not None:
            self._faults.fire("stage.device_put", wave=wave)
        self._bytes_staged += slab.staged_bytes
        t0 = time.perf_counter()
        with obs.span("device_put", lane="device", wave=wave,
                      devices=self._mesh_devices, bytes=slab.staged_bytes):
            arrays = dict(
                src=slab.src, dst=slab.dst, edge_block=slab.edge_block,
                sparse_edge_mask=slab.sparse_mask,
                dense_edge_mask=slab.dense_mask,
            )
            if slab.tiles is not None:
                arrays.update(tiles=slab.tiles,
                              tile_row_start=slab.tile_row_start,
                              tile_col_start=slab.tile_col_start)
            if slab.csr is not None:
                arrays["indices"] = slab.csr
            if self.mesh is None:
                bufs = jax.device_put(arrays)
                if slab.extras is not None:
                    bufs["extras"] = _put_arrays(slab.extras)
            else:
                shard = NamedSharding(self.mesh, PartitionSpec(self.mesh_axis))
                slab_bufs = jax.device_put(arrays, {k: shard for k in arrays})
                if slab.extras is not None:
                    ex_leaves, ex_aux = _split_static(slab.extras)
                    ex_leaves = tuple(
                        jax.device_put(leaf, shard) for leaf in ex_leaves
                    )
                else:
                    ex_leaves, ex_aux = (), None
                bufs = (slab_bufs, ex_leaves, ex_aux)
        self._phase["device_put"] += time.perf_counter() - t0
        return bufs

    def _wave_context(self, bufs: dict) -> Context:
        arrays = {k: v for k, v in bufs.items() if k != "extras"}
        extras = bufs.get("extras")
        if extras is not None:
            return with_arrays(self._resident, extras=extras, **arrays)
        return with_arrays(self._resident, **arrays)

    def _step_wave(self, w: int, bufs, state0, acc, iarr):
        """Stage 3: dispatch one staged wave into the right jitted step."""
        run_dense = self._slabs[w].run_dense
        step, mesh_step = self._active_steps()
        faults = self._faults
        if self.mesh is None:
            with obs.span("compute", lane="device", wave=w,
                          devices=self._mesh_devices):
                out = step(self._wave_context(bufs), state0, acc,
                           iarr, run_dense)
            if faults is not None:
                # firing on the accumulator lets `corrupt` damage the
                # wave's folded partial — recovery must discard it
                out = faults.fire("wave.compute", out, wave=w)
            return out
        with obs.span("compute", lane="device", wave=w,
                      devices=self._mesh_devices):
            slab_bufs, ex_leaves, ex_aux = bufs
            out = mesh_step(self._resident, slab_bufs, ex_leaves,
                            state0, acc, iarr, run_dense, ex_aux)
        if faults is not None:
            out = faults.fire("wave.compute", out, wave=w)
            out = faults.fire("mesh.collective", out, wave=w)
        # per-device collective payload: each combined leaf crosses one
        # all-reduce per wave step (trace-time combined_keys is exact)
        cbytes = sum(
            int(state0[k].nbytes) for k in mesh_step.combined_keys
            if hasattr(state0[k], "nbytes")
        )
        self._collective_bytes += cbytes
        self._phase["collective"] += self._collective_unit_s
        # the real all-reduce is fused inside the shard_map step, so the
        # timeline carries its attributable stand-in cost as a span
        obs.add_span("collective", self._collective_unit_s, lane="device",
                     wave=w, devices=self._mesh_devices, bytes=cbytes)
        return out

    def _measure_collective_unit(self, state0) -> None:
        """Estimate one wave step's collective cost: an isolated, jitted
        all-reduce of the combined state leaves across the mesh, timed
        after a warm-up call.  The real collective is fused inside the
        ``shard_map`` step, so this is the attributable stand-in the
        phase breakdown reports (× wave steps executed)."""
        keys = self._mesh_step.combined_keys if self._mesh_step else ()
        if self.mesh is None or not keys:
            return
        axis = self.mesh_axis
        tree = {k: state0[k] for k in keys if hasattr(state0[k], "nbytes")}
        if not tree:
            return

        def allreduce(t):
            return shard_map(
                lambda x: jax.tree_util.tree_map(combine_fn("add", axis), x),
                mesh=self.mesh,
                in_specs=(PartitionSpec(),), out_specs=PartitionSpec(),
                check_rep=False,
            )(t)

        fn = jax.jit(allreduce)
        _block_tree(fn(tree))           # compile
        t0 = time.perf_counter()
        _block_tree(fn(tree))
        self._collective_unit_s = time.perf_counter() - t0

    def _calibrate(self, state0, acc, iarr, it: int):
        """The synchronous first iteration: trace every distinct wave
        shape (warm-up, result discarded), then time each phase —
        assemble / device_put / compute — per wave, so the overlap and
        phase statistics measure steady state rather than compilation."""
        nw = len(self._slabs)
        warm = state0
        for w in range(nw):
            t0 = time.perf_counter()
            slab = self._assemble_runtime(self._slabs[w], wave=w)
            self._phase["assemble"] += time.perf_counter() - t0
            warm = self._step_wave(w, self._put_slab(slab, wave=w), state0,
                                   warm, iarr)
            self._park_for_recycle(slab, warm)
            # keep the pool at its (depth+1)-slab bound even here: on a
            # caught-up device the previous wave's buffers are already
            # reusable
            self._drain_recycle()
        _block_tree(warm)
        self._drain_recycle(force=True)
        if self.mesh is not None and self._collective_unit_s == 0.0:
            self._measure_collective_unit(state0)
        assemble_s = put_s = compute_s = 0.0
        wave_s: list[float] = []
        for w in range(nw):
            t0 = time.perf_counter()
            slab = self._assemble_runtime(self._slabs[w], wave=w)
            dt = time.perf_counter() - t0
            assemble_s += dt
            put0 = self._phase["device_put"]
            bufs = self._put_slab(slab, wave=w)
            _block_tree(bufs)
            put_s += self._phase["device_put"] - put0
            t0 = time.perf_counter()
            acc = self._step_wave(w, bufs, state0, acc, iarr)
            _block_tree(acc)
            dt = time.perf_counter() - t0
            compute_s += dt
            wave_s.append(dt)
            # the blocking wait above is the safe recycle point
            self._arena.give(*slab.arena_arrays)
        self._phase["assemble"] += assemble_s
        self._phase["compute"] += compute_s
        self._calibration = dict(
            stage_s=assemble_s + put_s, compute_s=compute_s,
            assemble_s=assemble_s, put_s=put_s, wave_compute_s=wave_s,
        )
        # a re-pack only pays off if another iteration will run it — on
        # the final possible iteration it would rebuild (and report)
        # slabs that never execute
        if (self.rebalance_threshold is not None
                and it + 1 < self.alg.max_iterations
                and self.rebalance(wave_s)):
            # the measured stage/compute baseline described the old
            # packing — recalibrate on the next iteration so
            # overlap_efficiency reflects the re-packed waves
            # (at most once: rebalance() is one-shot per plan)
            self._calibration = None
        return acc

    def _run_waves(self, state0, it: int):
        """One iteration's kernel work: the three-stage pipeline over
        every wave, folding partials; calibration (synchronous, timed)
        on the first executed iteration, pipelined overlap afterwards."""
        acc = state0
        nw = len(self._slabs)
        lane = self._host_lane
        if nw == 0 and lane is None:
            return acc, 0.0
        iarr = jnp.int32(it)
        if it < self._edge_free:
            # the algorithm declared these iterations edge-free
            # (kernels read no slab fields and at most the prefix CSR —
            # e.g. Afforest's neighbor-sampling rounds): one
            # representative wave, staged once and cached across the
            # edge-free phase, gives the identical combined result —
            # W-1 redundant full-vertex passes and all repeat stagings
            # saved
            if self._prefix_dev is None and self._prefix_host is not None:
                pptr, pidx = self._prefix_host
                self._prefix_dev = self._put_replicated(
                    dict(indptr=pptr, indices=pidx)
                )
                # replicated puts copy to every mesh device
                self._bytes_staged += (
                    (pptr.nbytes + pidx.nbytes) * self._mesh_devices
                )
            if self.mesh is not None or nw == 0:
                # edge-free kernels consume no per-device data, so the
                # mesh runs them replicated — every device computes the
                # identical full-vertex update from replicated inputs,
                # no collectives needed (a psum here would D-multiply
                # additive leaves); the plain per-wave fold applies.
                # A fully host-peeled plan (no device waves) takes the
                # same resident-context path: the edge-free kernel is
                # full-vertex, so running it once here is the whole
                # iteration and the host lane correctly idles (its
                # units would recompute the identical update, double-
                # applying additive folds)
                ctx = self._resident
                if self._prefix_dev is not None:
                    ctx = with_arrays(ctx, **self._prefix_dev)
                acc = self._active_steps()[0](ctx, state0, acc, iarr, False)
                return acc, 0.0
            if self._edge_free_bufs is None:
                slab = self._assemble_runtime(self._slabs[0], wave=0)
                # the cached device bufs outlive this iteration (and may
                # alias the host arrays), so these buffers never
                # re-enter the arena — they free with the cache
                self._edge_free_bufs = self._put_slab(slab, wave=0)
            ctx = self._wave_context(self._edge_free_bufs)
            if self._prefix_dev is not None:
                # adjacency sampling reads the first-k-neighbors CSR,
                # not the (unbounded) global one
                ctx = with_arrays(ctx, **self._prefix_dev)
            acc = self._active_steps()[0](ctx, state0, acc, iarr,
                                          self._slabs[0].run_dense)
            return acc, 0.0
        self._edge_free_bufs = None     # release once edge work begins
        self._prefix_dev = None
        # host units dispatch FIRST — they run concurrently with the
        # whole device wave loop and are gathered after it, so host
        # work hides behind device compute (both partitions judge the
        # same iteration-start state; per-wave folding is partition-
        # invariant, so the merge order cannot change results)
        host_futs = (lane.submit(state0, it, self._direction_now)
                     if lane is not None else None)
        # stashed so a failure anywhere in the wave loop can wait the
        # in-flight host work out before the iteration retries
        self._host_futs = host_futs
        if nw == 0:
            # fully host-peeled: the host lane IS the iteration
            acc = self._gather_host(host_futs, acc)
            return acc, 0.0
        if self._calibration is None:
            # gather host partials BEFORE the timed calibration pass:
            # the fold order is immaterial (partition-invariant), the
            # host threads stop competing for CPU with the phase
            # timings, and a rebalance fired inside _calibrate may
            # rebuild the host lane — in-flight futures must be done
            acc = self._gather_host(host_futs, acc)
            acc = self._calibrate(state0, acc, iarr, it)
            self._maybe_refresh_split(it)
            return acc, 0.0
        t0 = time.perf_counter()
        put0 = self._phase["device_put"]
        pipe = self._pipe
        if (pipe is None and self.pipeline_depth > 0
                and self._sync_iters_left == 0):
            # persistent worker, created at the first overlapped
            # iteration; later iterations find their first waves
            # already assembled (the epoch below is requested early)
            pipe = self._pipe = _StagePipeline(self, self.pipeline_depth)
            pipe.request(range(nw))
        a0 = pipe.assemble_s if pipe is not None else 0.0
        s0 = pipe.stall_s if pipe is not None else 0.0
        fetched = 0

        def next_slab(i: int) -> _WaveSlab:
            nonlocal fetched
            if pipe is None:
                # synchronous baseline (pipeline_depth=0): assembly
                # runs inline on the critical path
                ta = time.perf_counter()
                s = self._assemble_runtime(self._slabs[i], wave=i)
                self._phase["assemble"] += time.perf_counter() - ta
                return s
            s = pipe.get()
            fetched += 1
            if fetched == nw and it + 1 < self.alg.max_iterations:
                # epoch drained: speculatively queue the next
                # iteration's waves so they assemble during post/host
                # hooks (an early-terminating run reclaims them)
                pipe.request(range(nw))
            return s

        slab = next_slab(0)
        bufs = self._put_slab(slab, wave=0)
        for w in range(nw):
            # fail fast on host-lane failures: a unit that already blew
            # up should abort the iteration now, not after every device
            # wave has streamed only to die at fold time
            if host_futs is not None:
                for f in host_futs:
                    if f.done() and f.exception() is not None:
                        raise f.exception()
            # async dispatch: the step for wave w starts on the device
            # (or the whole mesh, under shard_map)...
            acc = self._step_wave(w, bufs, state0, acc, iarr)
            self._park_for_recycle(slab, acc)
            self._drain_recycle()   # non-blocking: feed the worker's pool
            # ...while wave w+1's (sharded) slab crosses host→device and
            # the background worker assembles wave w+2 into the arena.
            # Rebinding `bufs` releases the previous slab's device
            # buffers as soon as the step consumes them (two slabs max
            # in flight per device).
            if w + 1 < nw:
                slab = next_slab(w + 1)
                bufs = self._put_slab(slab, wave=w + 1)
            else:
                slab, bufs = None, None
        # the host partition ran concurrently with the loop above; any
        # overhang past the last device wave is waited out here (and
        # lands in the wall clock honestly)
        acc = self._gather_host(host_futs, acc)
        _block_tree(acc)
        self._drain_recycle(force=True)
        wall = time.perf_counter() - t0
        put_d = self._phase["device_put"] - put0
        stall = 0.0
        if pipe is not None:
            asm = pipe.assemble_s - a0
            stall = pipe.stall_s - s0
            self._assemble_overlapped_s += asm
            self._stall_s += stall
            self._phase["assemble"] += asm
        self._phase["compute"] += max(wall - put_d - stall, 0.0)
        return acc, wall

    def _gather_host(self, futs, acc):
        """Wait on the host lane's unit futures and fold their partials
        into the running accumulator; publishes the host metrics."""
        if futs is None:
            return acc
        results = [f.result() for f in futs]
        self._host_futs = None
        acc, busy_s = self._host_lane.fold(results, acc)
        self._phase["host_compute"] += busy_s
        self._host_seconds += busy_s
        self._last_host_busy_s = busy_s
        ntasks = int(sum(u.size for u in self._host_units))
        self._host_tasks_executed += ntasks
        obs.metrics.counter("stream.host_tasks").inc(ntasks)
        obs.metrics.counter("stream.host_seconds").inc(busy_s)
        return acc

    # -- graceful degradation: the recovery ladder ---------------------
    def _run_waves_resilient(self, state0, it: int):
        """One iteration's wave work under the retry ladder.

        The fast path is a bare call — no bookkeeping when nothing
        fails.  On failure, every in-flight resource is quiesced, the
        failure is classified (oom / worker / host / fault), the
        matching recovery action reshapes the plan, and the *whole
        iteration* re-runs from ``state0`` — the combine contract folds
        partials from iteration-start state, so a retry can never
        double-count, whatever had already folded.  Bounded by
        ``RetryPolicy.max_retries``; an exhausted ladder re-raises."""
        policy = self._policy
        res = self._resil
        attempts = 0
        oom_count = 0
        while True:
            try:
                out = self._run_waves(state0, it)
                if self._sync_iters_left > 0:
                    self._sync_iters_left -= 1
                return out
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                kind = classify(e)
                res.detected += 1
                attempts += 1
                obs.instant("failure", lane="resilience", it=it, kind=kind,
                            attempt=attempts,
                            error=f"{type(e).__name__}: {e}")
                self._abort_inflight()
                if attempts > policy.max_retries:
                    res.record("exhausted", it=it, kind=kind)
                    raise
                if kind == "oom":
                    oom_count += 1
                    if (oom_count >= policy.demote_after
                            and self._host_capable):
                        self._demote_wave(e)
                        res.demotions += 1
                        obs.metrics.counter("stream.fault_demotions").inc()
                        res.record("demote", it=it, oom_count=oom_count)
                    else:
                        self._shrink_repack(oom_count)
                        res.oom_repacks += 1
                        res.record("oom_repack", it=it,
                                   factor=policy.backoff ** oom_count)
                elif kind == "worker":
                    self._worker_deaths += 1
                    res.failovers += 1
                    obs.metrics.counter("stream.fault_failovers").inc()
                    if self._worker_deaths >= policy.failover_after:
                        # the worker keeps dying: synchronous assembly
                        # (pipeline_depth=0 semantics) becomes permanent
                        self.pipeline_depth = 0
                        res.record("failover_permanent", it=it,
                                   deaths=self._worker_deaths)
                    else:
                        self._sync_iters_left = 1
                        res.record("failover_sync", it=it,
                                   deaths=self._worker_deaths)
                elif kind == "host":
                    self._host_failures += 1
                    if self._host_failures >= policy.failover_after:
                        self._disable_host_lane()
                        res.host_failovers += 1
                        res.record("host_disable", it=it,
                                   unit=getattr(e, "unit", None))
                    else:
                        res.record("host_retry", it=it,
                                   unit=getattr(e, "unit", None))
                else:
                    res.record("retry", it=it, kind=kind)
                res.retries += 1
                obs.metrics.counter("stream.fault_retries").inc()
                obs.instant("recovery", lane="resilience", it=it,
                            action=res.actions[-1]["action"])

    def _abort_inflight(self) -> None:
        """Quiesce every in-flight resource so a retry starts clean:
        close the staging pipe (drains a dead or a live worker alike),
        wait out dispatched host futures (their partials are discarded
        — the retry folds from iteration-start state), and force-
        recycle parked arena buffers."""
        if self._pipe is not None:
            try:
                self._pipe.close(self._arena)
            finally:
                self._pipe = None
        futs, self._host_futs = self._host_futs, None
        for f in futs or ():
            try:
                f.result(timeout=60.0)
            except Exception:
                pass            # the retry re-dispatches from scratch
        self._drain_recycle(force=True)

    def _shrink_repack(self, oom_count: int) -> None:
        """Device OOM: re-pack the device waves under an exponentially
        shrunk *effective* capacity (``budget × backoff**oom_count``),
        so each wave stages less at once.  The per-task bound is never
        relaxed — ``_fit_slabs`` still verifies every rebuilt wave
        against the ORIGINAL budget, and ``split_wave`` raises rather
        than admit a single task that cannot fit.  The standing host
        partition is preserved exactly."""
        eff = self.budget.scaled(self._policy.backoff ** oom_count)
        task_t = self.schedule.weights.astype(np.float64)
        packed = repack_waves(self.schedule, eff, self._footprints,
                              task_t, devices=self._mesh_devices)
        host_ids = (np.concatenate(self._host_units) if self._host_units
                    else np.zeros(0, np.int64))
        waves: list[Wave] = []
        for w in packed:
            dev = w.task_ids[~np.isin(w.task_ids, host_ids)]
            if dev.size:
                waves.append(Wave(
                    task_ids=dev,
                    est_bytes=int(self._footprints[dev].sum()),
                ))
        for ids in self._host_units:
            waves.append(Wave(task_ids=np.zeros(0, np.int64), est_bytes=0,
                              host_task_ids=ids))
        self._apply_waves(waves)
        self._calibration = None        # re-time the re-packed queue
        self._edge_free_bufs = None     # stale slab-0 reference

    def _demote_wave(self, exc: BaseException) -> None:
        """Repeated OOM: move the offending wave's tasks to the host
        lane wholesale (they are never staged there, so they stop
        pressing on device memory).  The wave is identified from the
        failure's ``wave=`` context when present, else the largest
        staged slab takes the blame."""
        if not self._slabs:
            return
        w = None
        ctx = getattr(exc, "ctx", None)
        if isinstance(ctx, dict):
            cw = ctx.get("wave")
            if isinstance(cw, int) and 0 <= cw < len(self._slabs):
                w = cw
        if w is None:
            w = max(range(len(self._slabs)),
                    key=lambda i: self._slabs[i].staged_bytes)
        waves: list[Wave] = []
        for i, r in enumerate(self._slabs):
            if i == w:
                waves.append(Wave(
                    task_ids=np.zeros(0, np.int64), est_bytes=0,
                    host_task_ids=np.sort(r.wave.task_ids),
                ))
            else:
                waves.append(Wave(task_ids=r.wave.task_ids,
                                  est_bytes=r.wave.est_bytes))
        for ids in self._host_units:
            waves.append(Wave(task_ids=np.zeros(0, np.int64), est_bytes=0,
                              host_task_ids=ids))
        self._apply_waves(waves)
        self._calibration = None
        self._edge_free_bufs = None
        obs.instant("demote", lane="resilience", wave=w)

    def _disable_host_lane(self) -> None:
        """Repeated host-task failure: run device-only.  Every peeled
        task returns to the device wave queue and the auto split stays
        off for the rest of the plan's life."""
        self._host_capable = False
        self._host_frac = 0.0
        task_t = self.schedule.weights.astype(np.float64)
        waves = repack_waves(self.schedule, self.budget, self._footprints,
                             task_t, devices=self._mesh_devices)
        self._apply_waves(waves)
        self._calibration = None
        self._edge_free_bufs = None
        obs.instant("host_disable", lane="resilience")

    def _maybe_refresh_split(self, it: int) -> None:
        """Adapt the ``"auto"`` host/device split to measured times.

        Runs right after each calibration pass.  Per-task device-
        equivalent times come from the calibrated wave computes (device
        tasks: wave time attributed by weight share; host tasks: their
        weight at the device rate); the schedule is re-packed LPT
        against them and re-peeled under the hide criterion
        (:func:`repro.core.membudget.peel_host_tasks`).  The new split
        is applied only when it diverged beyond the hysteresis band
        (:func:`repro.core.membudget.hetero_split_diverged`) or flipped
        between zero and nonzero — borderline proposals never thrash
        the wave queue.  The first activation forces one *probe* task
        per multi-task wave so a host rate gets measured at all; once
        measured, the observed host/device ratio replaces the assumed
        ``REPRO_HETERO_HOST_RATIO`` default.  Below the noise floor
        (``REPRO_HETERO_NOISE_FLOOR_S``) the split deterministically
        stays at its current value.  Each application invalidates the
        calibration, so the re-packed device waves are re-timed before
        the next evaluation."""
        if self._host_frac != "auto" or not self._host_capable:
            return
        if it + 1 >= self.alg.max_iterations:
            return                      # no later iteration would run it
        cal = self._calibration
        if cal is None or not self._slabs:
            return                      # a rebalance just re-packed
        wave_s = list(cal.get("wave_compute_s", []))
        if not wave_s or float(np.mean(wave_s)) < _hetero_noise_floor_s():
            return
        dev_w = float(sum(self.schedule.weights[s.wave.task_ids].sum()
                          for s in self._slabs))
        if dev_w <= 0.0:
            return
        dev_rate = float(sum(wave_s)) / dev_w
        busy_s = getattr(self, "_last_host_busy_s", 0.0)
        if self._host_units and busy_s > 0.0 and dev_rate > 0.0:
            host_w = float(sum(self.schedule.weights[u].sum()
                               for u in self._host_units))
            if host_w > 0.0:
                self._host_ratio = max((busy_s / host_w) / dev_rate, 1e-6)
                self._host_measured = True
        task_t = np.zeros(self.schedule.num_tasks, dtype=np.float64)
        for t_w, slab in zip(wave_s, self._slabs):
            ids = slab.wave.task_ids
            wts = self.schedule.weights[ids].astype(np.float64)
            tot = float(wts.sum())
            task_t[ids] = ((t_w * wts / tot) if tot > 0
                           else t_w / max(ids.size, 1))
        for ids in self._host_units:
            task_t[ids] = self.schedule.weights[ids] * dev_rate
        waves = repack_waves(self.schedule, self.budget,
                             self._footprints, task_t,
                             devices=self._mesh_devices)
        waves = peel_host_tasks(
            self.schedule, waves, "auto", task_times=task_t,
            host_ratio=self._host_ratio, footprints=self._footprints,
            min_tasks=0 if self._host_measured else 1,
        )
        host_ids = [w.host_task_ids for w in waves if w.host_task_ids.size]
        new_split = (self.schedule.weight_share(np.concatenate(host_ids))
                     if host_ids else 0.0)
        cur_split = (self.schedule.weight_share(
            np.concatenate(self._host_units)) if self._host_units else 0.0)
        if not (hetero_split_diverged(cur_split, new_split)
                or (new_split == 0.0) != (cur_split == 0.0)):
            return
        self._apply_waves(waves)
        self._edge_free_bufs = None     # stale slab-0 reference
        self._hetero_refreshes += 1
        self._calibration = None
        obs.instant("hetero_refresh", lane="main", split=float(new_split),
                    host_tasks=int(sum(u.size for u in self._host_units)),
                    waves=len(self._slabs))

    def _hetero_stats(self, phase_delta: dict) -> dict:
        """The ``schedule_stats["hetero"]`` block: the resolved
        host/device split, executed host work, and the per-resource
        makespans of this run."""
        host_ids = (np.concatenate(self._host_units) if self._host_units
                    else np.zeros(0, np.int64))
        return dict(
            enabled=bool(self._host_capable
                         and self._host_frac_req is not None),
            host_fraction=self._host_frac_req,
            resolved_split=(float(self.schedule.weight_share(host_ids))
                            if host_ids.size else 0.0),
            host_tasks=int(host_ids.size),
            device_tasks=int(self.schedule.num_tasks - host_ids.size),
            host_units=len(self._host_units),
            host_ratio=float(self._host_ratio),
            host_ratio_measured=bool(self._host_measured),
            refreshes=int(self._hetero_refreshes),
            host_tasks_executed=int(self._host_tasks_executed),
            host_seconds=float(self._host_seconds),
            makespan=dict(
                device_s=float(phase_delta.get("compute", 0.0)),
                host_s=float(phase_delta.get("host_compute", 0.0)),
            ),
        )

    def run(self, store: BlockStore | None = None,
            state: Any | None = None, *,
            _start_it: int = 0, _start_cont: bool = True,
            _ctrl_restore: dict | None = None) -> RunResult:
        """Execute the streamed iteration loop (same contract as
        :meth:`repro.core.engine.Plan.run`).

        The underscored keywords are :meth:`resume`'s continuation
        protocol — iteration counter, loop-continue flag, and the
        direction controller's restored decision history — not public
        surface."""
        if store is not None and store is not self.store:
            raise TypeError(
                "StreamingPlan is bound to the store it was compiled "
                "against; compile a new plan for a different graph"
            )
        alg = self.alg
        if state is None:
            assert alg.init_state is not None, f"{alg.name}: init_state required"
            state = alg.init_state(self.store)
        if self._host_units and self._host_lane is None:
            # close() tore the lane down; rebuild it for this run
            self._host_lane = _HostLane(self, self._host_units)
        ctrl = (DirectionController(alg, self.direction, self.store.n)
                if self._direction_requested else None)
        if ctrl is not None and _ctrl_restore is not None:
            # bit-identical hysteresis across a resume: the controller's
            # latch state and decision history ARE its inputs
            ctrl.current = str(_ctrl_restore["current"])
            ctrl.switches = int(_ctrl_restore["switches"])
            ctrl.decisions = list(_ctrl_restore["decisions"])
            ctrl.densities = list(_ctrl_restore["densities"])
        self._direction_now = "push"
        t0 = time.perf_counter()
        it = int(_start_it)
        cont = bool(_start_cont)
        overlapped_wall = 0.0
        overlapped_iters = 0
        staged_before = self._bytes_staged
        phase_before = dict(self._phase)
        asm_before = self._assemble_overlapped_s
        stall_before = self._stall_s
        try:
            while cont and it < alg.max_iterations:
                with obs.span("iteration", lane="main", it=it, alg=alg.name):
                    if alg.before is not None:
                        state = alg.before(self.host, state, it)
                    if ctrl is not None:
                        # one direction per iteration, across device
                        # waves, mesh shards, AND the host lane — the
                        # bit-identity contract holds per direction,
                        # never across a mix
                        self._direction_now = ctrl.decide(state, it)
                    if self.mesh is not None:
                        # the state is replicated on every mesh device
                        # (writes are reduced by the step's collectives;
                        # host hooks may have injected fresh uncommitted
                        # leaves) — a no-op for leaves already placed
                        state = self._put_replicated(state)
                    state, wall = self._run_waves_resilient(state, it)
                    if wall > 0.0:
                        overlapped_wall += wall
                        overlapped_iters += 1
                    if self._post is not None:
                        state = self._post(self._resident, state,
                                           jnp.int32(it))
                    if alg.after is not None:
                        state, cont = alg.after(self.host, state, it)
                it += 1
                if self._ckpt_every and (it % self._ckpt_every == 0
                                         or not cont):
                    self._save_checkpoint(state, it, cont, ctrl)
        finally:
            if self._pipe is not None:
                self._pipe.close(self._arena)
                self._pipe = None
        state = jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            state,
        )
        dt = time.perf_counter() - t0
        result = alg.finalize(self.store, state) if alg.finalize else state
        phase_delta = {k: self._phase[k] - phase_before[k]
                       for k in self._phase}
        self._publish_metrics(
            iterations=it, seconds=dt,
            staged_delta=self._bytes_staged - staged_before,
            phase_delta=phase_delta,
        )
        stats = dict(
            self.schedule.stats,
            streaming=self._streaming_stats(
                state, overlapped_wall, overlapped_iters,
                staged_delta=self._bytes_staged - staged_before,
                phase_delta=phase_delta,
                asm_delta=self._assemble_overlapped_s - asm_before,
                stall_delta=self._stall_s - stall_before,
            ),
            hetero=self._hetero_stats(phase_delta),
        )
        if ctrl is not None:
            stats["direction"] = ctrl.stats()
        if (self._faults is not None or self._ckpt_every
                or self._resil.fired):
            # emitted only when fault tolerance is configured or a
            # recovery actually fired — existing callers see unchanged
            # schedule_stats keys
            stats["resilience"] = self._resil.snapshot(self._faults)
        return RunResult(
            result=result,
            state=state,
            iterations=it,
            seconds=dt,
            schedule_stats=stats,
        )

    # -- checkpoint / resume -------------------------------------------
    def _save_checkpoint(self, state, it: int, cont: bool, ctrl) -> None:
        """Atomically persist ``(state, it, cont, controller state)``
        through :mod:`repro.checkpoint` after iteration ``it - 1``."""
        from ..checkpoint.runstate import save_runstate

        with obs.span("checkpoint", lane="resilience", it=it):
            save_runstate(self._ckpt_dir, state, it=it, cont=cont,
                          ctrl=ctrl)
        self._resil.checkpoints += 1
        obs.metrics.counter("stream.checkpoints").inc()

    def resume(self, ckpt_dir: str | None = None, *,
               step: int | None = None) -> RunResult:
        """Continue a checkpointed run from its latest (or ``step``'s)
        snapshot; bit-identical to the uninterrupted run for integer/
        boolean attributes (the same guarantee the per-wave combine
        contract gives within a run).  ``RunResult.iterations`` stays
        the absolute iteration count."""
        from ..checkpoint.runstate import load_runstate

        d = ckpt_dir if ckpt_dir is not None else self._ckpt_dir
        if d is None:
            raise ValueError(
                "resume() needs a checkpoint directory: pass ckpt_dir "
                "or compile the plan with checkpoint_dir=...")
        assert self.alg.init_state is not None
        snap = load_runstate(d, self.alg.init_state(self.store),
                             step=step)
        return self.run(state=snap.state, _start_it=snap.it,
                        _start_cont=snap.cont, _ctrl_restore=snap.ctrl)

    # -- deterministic teardown ----------------------------------------
    def close(self) -> None:
        """Tear down every background resource deterministically: the
        staging worker thread (joined, not leaked), the host-lane
        thread pool, and the parked arena buffers.  Idempotent, and
        safe mid-run cleanup after a ``KeyboardInterrupt`` — ``run()``
        rebuilds both lazily, so a closed plan can run again."""
        if self._pipe is not None:
            self._pipe.close(self._arena)
            self._pipe = None
        if self._host_lane is not None:
            self._host_lane.close(wait=True)
            self._host_lane = None
        self._host_futs = None
        self._drain_recycle(force=True)

    def __enter__(self) -> "StreamingPlan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _publish_metrics(self, *, iterations: int, seconds: float,
                         staged_delta: int, phase_delta: dict) -> None:
        """Publish one run's deltas into the process-wide registry.

        ``schedule_stats`` stays the per-run source of truth; the
        registry accumulates across runs (and plans) so the unified
        run-report and obs-smoke gate read one place."""
        m = obs.metrics
        m.counter("stream.runs").inc()
        m.counter("stream.iterations").inc(iterations)
        m.histogram("stream.run_seconds").observe(seconds)
        for k, v in phase_delta.items():
            m.counter(f"stream.phase_seconds.{k}").inc(max(v, 0.0))
        m.counter("stream.bytes_staged").inc(max(int(staged_delta), 0))
        m.gauge("stream.arena_bytes").set_max(self._arena.bytes)
        m.gauge("stream.waves").set(len(self._slabs))
        m.gauge("stream.mesh_devices").set(self._mesh_devices)
        m.gauge("stream.budget_bytes").set(self.budget.total_bytes)
        if self._slabs:
            m.gauge("stream.budget_high_water_bytes").set_max(
                max(self._budget_load(r) for r in self._slabs))
        if self._faults is not None:
            new = self._faults.injected - self._injected_pub
            if new > 0:
                m.counter("stream.fault_injected").inc(new)
            self._injected_pub = self._faults.injected

    def _streaming_stats(self, state, overlapped_wall: float,
                         overlapped_iters: int, *,
                         staged_delta: int, phase_delta: dict,
                         asm_delta: float, stall_delta: float) -> dict:
        bytes_per_wave = [s.staged_bytes for s in self._slabs]
        calib = self._calibration or dict(stage_s=0.0, compute_s=0.0)
        eff = 0.0
        denom = min(calib["stage_s"], calib["compute_s"])
        if overlapped_iters and denom > 0:
            serial = calib["stage_s"] + calib["compute_s"]
            mean_wall = overlapped_wall / overlapped_iters
            eff = max(0.0, min(1.0, (serial - mean_wall) / denom))
        # how much of the background assembly the pipeline actually hid
        # THIS run: the worker's busy time minus the main loop's queue
        # stalls, over the busy time (1.0 = staging fully off the
        # critical path)
        host_overlap = 0.0
        if asm_delta > 0:
            host_overlap = max(0.0, min(
                1.0, (asm_delta - stall_delta) / asm_delta,
            ))
        prefix_bytes = 0
        if self._prefix_host is not None:
            pptr, pidx = self._prefix_host
            prefix_bytes = pptr.nbytes + pidx.nbytes
        return dict(
            num_waves=len(self._slabs),
            budget_bytes=self.budget.total_bytes,
            bytes_per_wave=bytes_per_wave,
            # mesh composition: how many devices cooperate per wave, the
            # worst single device's staged share (each ≤ budget_bytes —
            # on one device this equals bytes_per_wave), and the
            # per-device payload that crossed the combine collectives
            # (psum/pmin/pmax) over the whole run
            mesh_devices=self._mesh_devices,
            per_device_bytes=[
                s.per_device_bytes if self.mesh is not None
                else s.staged_bytes
                for s in self._slabs
            ],
            collective_bytes=int(self._collective_bytes),
            csr_mode=self._csr_mode,
            # per-wave staged CSR slice bytes (bucket-padded, already
            # included in bytes_per_wave) — all zeros unless "slice"
            csr_bytes_per_wave=[s.csr_bytes for s in self._slabs],
            csr_segments=[s.csr_segments for s in self._slabs],
            # actual H2D traffic this run, counting the calibration
            # warm-up pass and edge-free single-wave iterations honestly
            bytes_staged_total=int(staged_delta),
            resident_bytes=(
                resident_bytes(self.store, state,
                               include_csr=self._csr_mode == "resident")
                + tree_array_bytes(self._resident_extras)
                + tree_array_bytes(state)     # the accumulator copy
            ),
            # first-k-neighbors CSR, device-held only during the
            # edge-free sampling phase (vertex-proportional)
            edge_free_prefix_bytes=int(prefix_bytes),
            edge_buckets=sorted({s.src_bucket for s in self._slabs}),
            coalesced_segments=[s.segments for s in self._slabs],
            overlap_efficiency=eff,
            # three-stage pipeline observability -----------------------
            pipeline_depth=self.pipeline_depth,
            host_stage_overlap=host_overlap,
            # jit traces of the wave step (process-wide when the step is
            # shared); with stage_plan algorithms this is one per
            # distinct bucket shape, independent of the wave count
            trace_count=int(self.compile_count),
            # staging arena: measured pooled-buffer high water vs the
            # footprint model's (depth+1)-slab bound
            arena_bytes=int(self._arena.bytes),
            arena_model_bytes=arena_model_bytes(
                bytes_per_wave, depth=max(self.pipeline_depth, 1),
            ),
            arena_reuses=int(self._arena.reuses),
            # this run's wall clock per phase; the one-time planning
            # pass (per-wave prepare + verification assembly) is broken
            # out so repeated runs stay attributable
            phase_seconds={k: float(v) for k, v in phase_delta.items()},
            planning_phase_seconds={
                k: float(v) for k, v in self._planning_phase.items()
            },
            calibration=dict(calib),
            overlapped_iterations=overlapped_iters,
            rebalanced=self._rebalanced,
            rebalance_mode=(
                "off" if self.rebalance_threshold is None
                else "auto" if self.rebalance_threshold == "auto"
                else "skew"
            ),
            rebalance_skew=self._last_skew,
            rebalance_divergence=self._last_divergence,
        )


def compile_streaming_plan(alg: BlockAlgorithm, store: BlockStore,
                           schedule: Schedule | None = None,
                           **kw) -> StreamingPlan:
    """Explicit spelling of ``compile_plan(..., memory_budget=...)``."""
    return StreamingPlan(alg, store, schedule, **kw)
