"""Out-of-core streaming executor: memory-budgeted, double-buffered waves.

This subsystem makes any :class:`~repro.core.engine.Plan`-compatible
algorithm runnable under an explicit device-memory budget — the paper's
headline capability ("graphs that fit host DRAM but not device memory",
§4.3/§4.4, the block-list bound on device copies).  Four parts:

1. **Footprint model** (:mod:`repro.core.membudget`) prices each
   schedule task's COO slice, dense tiles, conformal CSR row slices
   (for ``metadata["csr"] == "slice"`` algorithms), and kernel
   workspace in bytes.  The schedule itself is built budget-aware
   (:func:`repro.core.scheduler.build_schedule` receives the budget):
   ``tile_dim`` shrinks until a staged tile fits and tasks whose dense
   working set cannot fit are routed to the sparse path up front.
2. **Wave builder** packs the LPT-ordered tasks into budget-sized
   *waves*; every wave's edge slab is padded to one of a few fixed
   bucket shapes (power-of-two ladder) so a single jitted step serves
   all waves without retracing.  Within a wave, tasks are sorted by
   leading block id so the segmented-COO gather coalesces into few
   contiguous segments — staging approaches a single slice copy.
3. **Double-buffered staging loop**: wave ``k``'s compute is dispatched
   asynchronously (JAX async dispatch — the analog of the paper's four
   CUDA streams), then wave ``k+1``'s host slab is ``jax.device_put``
   while the device works; the previous slab's buffers are released as
   their references drop.  The first executed iteration runs
   synchronously to calibrate stage/compute times; every later
   iteration overlaps, and ``schedule_stats`` reports the measured
   overlap efficiency.
4. **Partial-result combination**: each wave's kernels run against the
   *iteration-start* state and its per-leaf updates are folded with the
   algorithm's declared ``metadata["combine"]`` op (``add``/``min``/
   ``max`` — the same semantics as
   :func:`repro.core.distributed.combine_fn`), so streamed results
   match the in-core bulk-synchronous step: exactly for integer/bool
   attributes, and up to float summation order for real ones.  Leaves a
   kernel passes through untouched are detected at trace time and
   carried over unchanged, so no combine kind is needed for them.
   ``post`` (and the host hooks) run once per iteration on the combined
   state, against a *resident* context that holds only vertex-level
   arrays.
5. **Tail-wave rebalancing** (opt-in via ``rebalance_threshold``): the
   calibration pass times every wave's compute; when the skew
   (max/mean) exceeds the threshold, the remaining iterations' waves
   are re-packed LPT against the *observed* per-task times
   (:func:`repro.core.membudget.repack_waves`) — the paper's dynamic
   work queue at wave granularity, for skewed graphs where one wave's
   compute dominates.

CSR streaming — ``metadata["csr"]``
-----------------------------------
What happens to the CSR adjacency (``ctx.indices``) is declared by the
algorithm:

``"slice"``
    Each wave stages only the conformal CSR row ranges its tasks touch
    (:meth:`repro.core.blocks.BlockStore.csr_slices`): ``ctx.indices``
    holds the sliced adjacency, and the *wave store* handed to
    ``prepare`` carries the rebased ``row_block_ptr``/``indptr`` so
    host-computed positions (e.g. TC's bucket items) index the slice.
    Slice lengths are rebase-invariant; global vertex attributes remain
    on ``wstore.graph``.  Kernels must size by ``ctx.indices.shape[0]``,
    never ``ctx.m``.
``"none"``
    The kernels never read the adjacency (pure COO scatter/gather
    algorithms); ``ctx.indices`` is a minimal placeholder and nothing
    edge-proportional is staged or resident.
``"resident"`` (default for custom algorithms)
    The full ``indices`` stays device-resident, as before this
    distinction existed — safe for kernels that index it with global
    positions, but the device footprint is then *not* bounded by the
    budget (``resident_bytes`` reports it honestly).

Algorithms declaring ``edge_free_iterations`` (Afforest's neighbor
sampling) additionally get a *prefix CSR* (:func:`repro.core.graph.csr_prefix`)
— the first ``k`` neighbors of every row, ``n·k`` entries — swapped in
as ``ctx.indptr``/``ctx.indices`` during those iterations, so even
adjacency-sampling rounds stay vertex-proportional on device.

The device working set is: resident vertex-level arrays (state pytree,
``indptr``/``degrees``/``row_block_ptr``/``cuts``) plus at most two
staged wave slabs (current + prefetch), each ≤ the budget — with
``"slice"``/``"none"`` algorithms, *every* edge-proportional device
allocation is bounded by ``memory_budget``.

Mesh-cooperative streaming — ``mesh=``
--------------------------------------
``compile_plan(alg, store, memory_budget=..., mesh=mesh)`` composes the
waves with :mod:`repro.core.distributed`'s execution model: the budget
becomes *per device*, waves are packed to the mesh capacity
``D × budget`` (:func:`repro.core.membudget.build_waves`), and each
wave's tasks are LPT-split over the mesh so every device stages only
its own padded COO/CSR/tile slab
(:func:`repro.core.distributed.make_device_edge_partition`, bucket
ladder shared with the single-device path).  The double-buffered stager
``device_put``\\ s wave ``k+1``'s *sharded* slabs while the mesh computes
wave ``k`` under ``shard_map``; inside the shard each device runs the
kernels on its slice from iteration-start state, per-leaf updates are
combined across the mesh with the algorithm's declared
``metadata["combine"]`` collective (``psum``/``pmin``/``pmax`` —
:func:`repro.core.distributed.combine_fn`) and folded into the running
accumulator, so results stay bit-identical to in-core for integer/bool
attributes and equal up to float summation order otherwise.  Vertex
attributes, the resident context, and the state are replicated; only
edge work is sharded — the paper's "reads are free, writes are
reduced" model at wave granularity.  Algorithms opt in with
``metadata["mesh"] == "shard"``; ``prepare`` runs per device against a
device-local store view (device-rebased CSR, device tile subset), and
structurally device-varying outputs are unified by the algorithm's
``mesh_pack`` hook (see :class:`~repro.core.functors.BlockAlgorithm`).
``schedule_stats["streaming"]`` grows ``mesh_devices``,
``per_device_bytes`` (each entry ≤ the per-device budget),
``collective_bytes``, and the mesh-wide ``overlap_efficiency``.  The
full model is documented in ``docs/distributed.md``.

Entry point: ``compile_plan(alg, store, memory_budget=...)`` returns a
:class:`StreamingPlan` instead of a :class:`~repro.core.engine.Plan`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace as dc_replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .blocks import BlockStore
from .context import _TRACED, Context, build_host_ctx, with_arrays
from .distributed import combine_fn, make_device_edge_partition
from .functors import BlockAlgorithm
from .graph import csr_prefix
from .membudget import (
    MemoryBudget, Wave, bucket_size, build_waves, repack_waves,
    resident_bytes, split_wave, task_footprints, tree_array_bytes,
)
from .scheduler import Schedule, build_schedule
from .engine import RunResult, _alg_cache_key, _shared_entry

__all__ = ["StreamingPlan", "compile_streaming_plan"]

_COMBINE_KINDS = ("add", "min", "max")
_CSR_MODES = ("resident", "slice", "none")


def _combine_spec(alg: BlockAlgorithm):
    """metadata['combine'] → leaf-name → kind (or None when undeclared)."""
    c = alg.metadata.get("combine")
    if isinstance(c, str):
        return lambda key: c
    if isinstance(c, dict):
        return lambda key: c.get(key)
    return lambda key: None


def _combine_leaf(kind: str | None, key: str, acc, s0, new):
    if kind == "add":
        return acc + (new - s0)
    if kind == "min":
        return jnp.minimum(acc, new)
    if kind == "max":
        return jnp.maximum(acc, new)
    raise ValueError(
        f"state leaf {key!r} is modified by the kernels but declares no "
        f"combine kind in metadata['combine'] (one of {_COMBINE_KINDS}); "
        f"streaming cannot fold its per-wave partial results"
    )


class _StreamStep:
    """The jitted per-wave step: kernels from iteration-start state,
    partials folded into the running accumulator via the combine spec.

    Pass-through detection happens at trace time: a kernel that returns
    ``dict(state, acc=...)`` leaves the other values as the *same*
    tracer objects, which is exactly the contract "this wave did not
    touch that attribute"."""

    def __init__(self, alg: BlockAlgorithm) -> None:
        self.traces = 0
        spec = _combine_spec(alg)

        def step(ctx: Context, state0, acc, it, run_dense: bool):
            self.traces += 1
            if not isinstance(state0, dict):
                raise TypeError(
                    f"{alg.name}: streaming requires a dict state pytree"
                )
            new = state0
            if alg.kernel_sparse is not None:
                new = alg.kernel_sparse(ctx, new, it)
            if alg.kernel_dense is not None and run_dense:
                new = alg.kernel_dense(ctx, new, it)
            added = set(new) - set(state0)
            if added:  # the in-core step would forward these to post;
                # per-wave there is no baseline to combine them against
                raise ValueError(
                    f"{alg.name}: kernels added state leaves "
                    f"{sorted(added)}; streaming requires kernels to "
                    f"write only leaves present in init_state (declare "
                    f"scratch attributes there)"
                )
            out = {}
            for key in state0:
                s0, nw = state0[key], new[key]
                out[key] = (
                    acc[key] if nw is s0
                    else _combine_leaf(spec(key), key, acc[key], s0, nw)
                )
            return out

        self._jit = jax.jit(step, static_argnums=(4,))

    def __call__(self, ctx, state0, acc, it, run_dense: bool):
        return self._jit(ctx, state0, acc, it, run_dense)


class _PostStep:
    """``post`` + trace counter, jitted once per algorithm identity."""

    def __init__(self, alg: BlockAlgorithm) -> None:
        self.traces = 0

        def step(ctx: Context, state, it):
            self.traces += 1
            return alg.post(ctx, state, it)

        self._jit = jax.jit(step)

    def __call__(self, ctx, state, it):
        return self._jit(ctx, state, it)


def _split_static(tree):
    """Flatten ``tree`` into (array leaves, hashable aux): the same
    traced/static split :class:`~repro.core.context.Context` applies to
    ``extras``, reused here so a wave's stacked extras can cross the
    jitted mesh step as a plain tuple of sharded arrays while ints such
    as TC's ``dp``/``steps`` stay static (they drive shapes)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = tuple(leaf for leaf in leaves if _is_array_leaf(leaf))
    markers = tuple(
        _TRACED if _is_array_leaf(leaf) else leaf for leaf in leaves
    )
    return arrays, (treedef, markers)


def _rejoin_static(aux, arrays):
    treedef, markers = aux
    arr = iter(arrays)
    leaves = [next(arr) if m is _TRACED else m for m in markers]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class _MeshStreamStep:
    """The jitted mesh per-wave step: ``shard_map`` over the wave.

    Each device of the 1-D mesh receives its own shard of the wave's
    padded slab (COO, routing masks, CSR slice, tiles) plus its slice of
    the device-stacked extras, runs the kernels from the *replicated*
    iteration-start state, and the per-leaf updates are combined across
    the mesh with the algorithm's declared collective — ``psum`` for
    additive leaves (on the delta from iteration start, so replicated
    baselines are not multiplied by D), ``pmin``/``pmax`` elementwise —
    then folded into the running accumulator exactly like
    :class:`_StreamStep` does per wave.  Pass-through detection is the
    same trace-time identity test; the mesh program is SPMD, so a leaf
    is uniformly touched or untouched on every device.

    ``combined_keys`` records (at trace time) which state leaves
    actually crossed a collective — the honest basis for the
    ``collective_bytes`` accounting in ``schedule_stats``.
    """

    def __init__(self, alg: BlockAlgorithm, mesh: Mesh) -> None:
        self.traces = 0
        self.combined_keys: tuple[str, ...] = ()
        spec = _combine_spec(alg)
        axis = mesh.axis_names[0]

        def step(res_ctx, slab, ex_leaves, state0, acc, it,
                 run_dense: bool, ex_aux):
            self.traces += 1
            if not isinstance(state0, dict):
                raise TypeError(
                    f"{alg.name}: streaming requires a dict state pytree"
                )

            def body(res_ctx, slab, ex_leaves, state0, acc, it):
                # each shard sees [1, ...] slices — drop the device axis
                arrays = {k: v[0] for k, v in slab.items()}
                extras = dict(res_ctx.extras)
                if ex_aux is not None:
                    extras.update(_rejoin_static(
                        ex_aux, tuple(leaf[0] for leaf in ex_leaves)
                    ))
                ctx = with_arrays(res_ctx, extras=extras, **arrays)
                new = state0
                if alg.kernel_sparse is not None:
                    new = alg.kernel_sparse(ctx, new, it)
                if alg.kernel_dense is not None and run_dense:
                    new = alg.kernel_dense(ctx, new, it)
                added = set(new) - set(state0)
                if added:
                    raise ValueError(
                        f"{alg.name}: kernels added state leaves "
                        f"{sorted(added)}; streaming requires kernels to "
                        f"write only leaves present in init_state (declare "
                        f"scratch attributes there)"
                    )
                out = {}
                combined = []
                for key in state0:
                    s0, nw = state0[key], new[key]
                    if nw is s0:
                        out[key] = acc[key]
                        continue
                    kind = spec(key)
                    if kind not in _COMBINE_KINDS:
                        raise ValueError(
                            f"state leaf {key!r} is modified by the kernels "
                            f"but declares no combine kind in "
                            f"metadata['combine'] (one of {_COMBINE_KINDS}); "
                            f"the mesh cannot fold its per-device partials"
                        )
                    red = combine_fn(kind, axis)(
                        nw - s0 if kind == "add" else nw
                    )
                    if kind == "add":
                        out[key] = acc[key] + red
                    elif kind == "min":
                        out[key] = jnp.minimum(acc[key], red)
                    else:
                        out[key] = jnp.maximum(acc[key], red)
                    combined.append(key)
                self.combined_keys = tuple(combined)
                return out

            P = PartitionSpec
            return shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(axis), P(axis), P(), P(), P()),
                out_specs=P(),
                check_rep=False,
            )(res_ctx, slab, ex_leaves, state0, acc, it)

        self._jit = jax.jit(step, static_argnums=(6, 7))

    def __call__(self, res_ctx, slab, ex_leaves, state0, acc, it,
                 run_dense: bool, ex_aux):
        return self._jit(res_ctx, slab, ex_leaves, state0, acc, it,
                         run_dense, ex_aux)


_STREAM_STEP_CACHE: dict[tuple, _StreamStep] = {}
_POST_STEP_CACHE: dict[tuple, _PostStep] = {}


def _stream_step_for(alg: BlockAlgorithm, backend: str, *,
                     share: bool = True) -> _StreamStep:
    return _shared_entry(_STREAM_STEP_CACHE, _alg_cache_key(alg, backend),
                         lambda: _StreamStep(alg), share=share)


def _post_step_for(alg: BlockAlgorithm, backend: str, *,
                   share: bool = True) -> _PostStep | None:
    if alg.post is None:
        return None
    return _shared_entry(_POST_STEP_CACHE, _alg_cache_key(alg, backend),
                         lambda: _PostStep(alg), share=share)


# ----------------------------------------------------------------------
@dataclass
class _WaveSlab:
    """Host-side staged form of one wave: padded numpy arrays ready for
    a single ``jax.device_put`` per iteration.

    Under a mesh the same fields carry a leading device axis (``[D, …]``
    per-device slabs, uniformly padded), ``staged_bytes`` totals the
    whole wave's H2D traffic, and ``per_device_bytes`` is the share one
    mesh device holds — the quantity the per-device budget bounds."""

    wave: Wave
    src: np.ndarray
    dst: np.ndarray
    edge_block: np.ndarray
    sparse_mask: np.ndarray
    dense_mask: np.ndarray
    tiles: np.ndarray | None
    tile_row_start: np.ndarray | None
    tile_col_start: np.ndarray | None
    csr: np.ndarray | None         # bucket-padded conformal CSR slice
    extras: Any                    # host pytree, or None once hoisted resident
    run_dense: bool
    staged_bytes: int
    workspace_bytes: int           # kernel scratch estimate (not staged)
    edges: int
    segments: int                  # coalesced COO slices gathered
    csr_entries: int               # unpadded CSR slice length
    csr_segments: int              # coalesced CSR row-range gathers
    per_device_bytes: int = 0      # one device's staged share (mesh)


def _is_array_leaf(leaf: Any) -> bool:
    return isinstance(leaf, (np.ndarray, jax.Array))


def _to_host(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: np.asarray(l) if _is_array_leaf(l) else l, tree
    )


def _put_arrays(tree: Any) -> Any:
    """device_put only the array leaves; static leaves stay untouched."""
    return jax.tree_util.tree_map(
        lambda l: jax.device_put(l) if _is_array_leaf(l) else l, tree
    )


def _trees_equal(a: Any, b: Any) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if _is_array_leaf(x) != _is_array_leaf(y):
            return False
        if _is_array_leaf(x):
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                return False
        elif x != y:
            return False
    return True


def _block_tree(tree: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


# ----------------------------------------------------------------------
class StreamingPlan:
    """A compiled plan whose execution streams budget-sized waves.

    Produced by ``compile_plan(alg, store, memory_budget=...)``.  Same
    ``run()`` contract as :class:`~repro.core.engine.Plan` (hooks, post,
    iteration control, RunResult), but the per-iteration step is the
    double-buffered wave loop described in the module docstring, and
    ``schedule_stats`` additionally carries a ``"streaming"`` dict:
    wave count, bytes staged per wave (each ≤ budget), resident bytes,
    and overlap efficiency.
    """

    def __init__(self, alg: BlockAlgorithm, store: BlockStore,
                 schedule: Schedule | None = None, *,
                 memory_budget: int | str | MemoryBudget,
                 backend: str = "xla", num_devices: int = 1,
                 mode: str = "hybrid", tile_dim: int = 512,
                 dense_frac: float = 0.5, dense_density: float = 0.005,
                 rebalance_threshold: float | None = None,
                 share: bool = True, mesh: Mesh | None = None) -> None:
        from ..kernels.registry import resolve_backend

        self.alg = alg
        self.store = store
        self.backend = resolve_backend(backend)
        self.budget = MemoryBudget.of(memory_budget)
        self._csr_mode = str(alg.metadata.get("csr", "resident"))
        if self._csr_mode not in _CSR_MODES:
            raise ValueError(
                f"{alg.name}: metadata['csr'] must be one of {_CSR_MODES}, "
                f"got {self._csr_mode!r}"
            )
        self.mesh = mesh
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    "mesh-cooperative streaming requires a 1-D mesh (one "
                    f"block-parallel axis); got axes {mesh.axis_names}"
                )
            if alg.metadata.get("mesh") != "shard":
                raise ValueError(
                    f"{alg.name}: metadata['mesh'] must declare 'shard' to "
                    "run under a mesh — the kernels must decompose over any "
                    "partition of a wave's tasks judged from iteration-start "
                    "state, and prepare must restrict to a device-local view "
                    "(see docs/distributed.md)"
                )
            self.mesh_axis = mesh.axis_names[0]
            self._mesh_devices = int(mesh.size)
        else:
            self.mesh_axis = None
            self._mesh_devices = 1
        self.rebalance_threshold = rebalance_threshold
        self.schedule = schedule or build_schedule(
            alg, store, num_devices=max(num_devices, self._mesh_devices),
            mode=mode, tile_dim=tile_dim, dense_frac=dense_frac,
            dense_density=dense_density, memory_budget=self.budget,
        )
        self.host = build_host_ctx(store, self.schedule, backend=self.backend)

        self._footprints = task_footprints(
            store, self.schedule,
            workspace_kernel=alg.metadata.get("workspace_kernel"),
            stage_csr=self._csr_mode == "slice",
        )
        waves = build_waves(store, self.schedule, self.budget,
                            self._footprints, devices=self._mesh_devices)
        self._slabs = (
            self._build_slabs_mesh(waves) if mesh is not None
            else self._build_slabs(waves)
        )
        self._resident = self._build_resident_context()
        self._step = _stream_step_for(alg, self.backend, share=share)
        self._mesh_step = (
            _MeshStreamStep(alg, mesh) if mesh is not None else None
        )
        self._post = _post_step_for(alg, self.backend, share=share)
        self._calibration: dict | None = None
        self._collective_bytes = 0      # payload across mesh combines
        self._bytes_staged = 0          # actual H2D traffic, all passes
        self._edge_free = int(alg.metadata.get("edge_free_iterations", 0))
        self._edge_free_bufs: dict | None = None
        # first-k-neighbors CSR for the edge-free sampling phase: the
        # only adjacency those iterations see (vertex-proportional)
        self._prefix_host = (
            csr_prefix(store.indptr, store.indices, self._edge_free)
            if self._edge_free > 0 else None
        )
        self._prefix_dev: dict | None = None
        self._rebalanced = False
        self._last_skew: float | None = None
        self.schedule.stats["waves"] = len(self._slabs)

    # -- build side ----------------------------------------------------
    def _build_slabs(self, waves: list[Wave]) -> list[_WaveSlab]:
        """Assemble host slabs; split any wave whose *actual* staged
        bytes overflow the budget (model under-priced prepare extras).

        Wave-invariant extras are hoisted resident *before* the budget
        check — they are staged once, not per wave, so counting them
        per wave would spuriously reject (or over-split) workable
        budgets."""
        slabs = [self._assemble(w) for w in waves]
        self._decide_hoist(slabs)
        return self._fit_slabs(slabs)

    def _build_slabs_mesh(self, waves: list[Wave]) -> list[_WaveSlab]:
        """Mesh counterpart of :meth:`_build_slabs`: assemble per-device
        slabs for every wave, decide extras hoisting across devices AND
        waves, then verify each wave's *per-device* bytes against the
        per-device budget."""
        pairs = [self._assemble_mesh(w) for w in waves]
        self._resident_extras = {}
        self._hoisted = False
        trees = [e for _, lst in pairs for e in lst]
        if trees and all(_trees_equal(e, trees[0]) for e in trees[1:]):
            # device- and wave-invariant prepare outputs (PageRank's
            # inv_deg, ...) are staged once, replicated over the mesh
            self._resident_extras = trees[0]
            self._hoisted = True
        slabs = [self._finalize_mesh_extras(s, lst) for s, lst in pairs]
        return self._fit_slabs(slabs)

    def _rebuild_slabs(self, waves: list[Wave]) -> list[_WaveSlab]:
        """Re-assemble after a re-pack, keeping the original hoist
        decision (the resident context already carries the hoisted
        extras)."""
        return self._fit_slabs([self._reassemble(w) for w in waves])

    def _reassemble(self, wave: Wave) -> _WaveSlab:
        """One wave → finished slab, honoring the standing hoist
        decision — shared by budget splits and rebalance rebuilds."""
        if self.mesh is not None:
            slab, extras_list = self._assemble_mesh(wave)
            return self._finalize_mesh_extras(slab, extras_list)
        slab = self._assemble(wave)
        self._strip_hoisted(slab)
        return slab

    def _budget_load(self, slab: _WaveSlab) -> int:
        """The bytes the budget must bound: one device's staged share
        plus its kernel scratch (per-device under a mesh; the whole
        slab on a single device)."""
        staged = (slab.per_device_bytes if self.mesh is not None
                  else slab.staged_bytes)
        return staged + slab.workspace_bytes

    def _fit_slabs(self, slabs: list[_WaveSlab]) -> list[_WaveSlab]:
        out: list[_WaveSlab] = []
        pending = list(slabs)
        while pending:
            slab = pending.pop(0)
            if self._budget_load(slab) > self.budget.total_bytes:
                # staged arrays + kernel scratch are the wave's real
                # device footprint; split_wave raises for size-1 waves —
                # the ≤ budget invariant is never silently violated
                a, b = split_wave(slab.wave, self.schedule, self._footprints)
                pending[:0] = [self._reassemble(a), self._reassemble(b)]
                continue
            out.append(slab)
        return out

    def _assemble(self, wave: Wave) -> _WaveSlab:
        store, sched = self.store, self.schedule
        wsched = sched.restrict(wave.task_ids)
        blocks = np.unique(wsched.blocklists)
        segments = store.edge_segments(blocks)
        idx = (
            np.concatenate([np.arange(s, e, dtype=np.int64)
                            for s, e in segments])
            if segments else np.zeros(0, np.int64)
        )
        ne = int(idx.size)
        eb = bucket_size(ne)
        src = np.zeros(eb, np.int32)
        dst = np.zeros(eb, np.int32)
        edge_block = np.zeros(eb, np.int32)
        sparse_mask = np.zeros(eb, bool)
        dense_mask = np.zeros(eb, bool)
        if ne:
            src[:ne] = store.src[idx]
            dst[:ne] = store.dst[idx]
            edge_block[:ne] = store.edge_block[idx]
            dense_blocks = np.zeros(store.layout.num_blocks, bool)
            if wsched.dense_block_ids.size:
                dense_blocks[wsched.dense_block_ids] = True
            edense = dense_blocks[edge_block[:ne]]
            sparse_mask[:ne] = ~edense
            dense_mask[:ne] = edense

        # -- dense tiles (already materialized by build_schedule) ------
        tiles = trs = tcs = None
        run_dense = (
            self.alg.kernel_dense is not None
            and bool(wsched.dense_task_mask.any())
        )
        wstore = store
        if run_dense:
            sub, sub_rs, sub_cs = store.tile_subset(wsched.dense_block_ids)
            nd = sub.shape[0]
            tb = bucket_size(nd, minimum=1)
            t = sched.tile_dim
            tiles = np.zeros((tb, t, t), np.float32)
            tiles[:nd] = sub
            trs = np.zeros(tb, np.int64)
            trs[:nd] = sub_rs
            tcs = np.zeros(tb, np.int64)
            tcs[:nd] = sub_cs
            wstore = dc_replace(
                store, tile_dim=t,
                tile_block_ids=wsched.dense_block_ids.astype(np.int32),
                tiles=sub, tile_row_start=sub_rs, tile_col_start=sub_cs,
            )
        elif self.alg.prepare is not None:
            # prepare must not see tiles the wave does not stage
            wstore = dc_replace(
                store, tile_dim=0,
                tile_block_ids=np.zeros(0, np.int32),
                tiles=np.zeros((0, 0, 0), np.float32),
                tile_row_start=np.zeros(0, np.int64),
                tile_col_start=np.zeros(0, np.int64),
            )

        # -- conformal CSR row slices (metadata["csr"] == "slice") -----
        csr = None
        csr_entries = csr_segments = 0
        if self._csr_mode == "slice":
            sl_idx, rbp_r, indptr_r, csr_segs = store.csr_slices(blocks)
            csr_entries = int(sl_idx.size)
            csr_segments = len(csr_segs)
            cb = bucket_size(csr_entries)
            csr = np.zeros(cb, np.int32)
            csr[:csr_entries] = sl_idx
            if self.alg.prepare is not None:
                # prepare sees the wave-local CSR view: positions it
                # computes from row_block_ptr index the staged slice
                wstore = dc_replace(
                    wstore, indices=sl_idx, row_block_ptr=rbp_r,
                    indptr=indptr_r,
                )

        extras = (
            _to_host(self.alg.prepare(wstore, wsched))
            if self.alg.prepare is not None else {}
        )
        # prepare may declare additional device scratch (e.g. TC's
        # bucketed membership-test gather) under the reserved key; it
        # is a budget input, not a kernel input
        ws = int(extras.pop("__workspace_bytes__", 0))

        staged = (
            src.nbytes + dst.nbytes + edge_block.nbytes
            + sparse_mask.nbytes + dense_mask.nbytes
            + tree_array_bytes(extras)
        )
        if csr is not None:
            staged += csr.nbytes
        if tiles is not None:
            staged += tiles.nbytes + trs.nbytes + tcs.nbytes
            from ..kernels.registry import max_workspace_bytes, workspace_bytes

            wk = self.alg.metadata.get("workspace_kernel")
            hints = dict(nd=int(tiles.shape[0]), tile_dim=sched.tile_dim)
            ws += (workspace_bytes(wk, **hints) if wk is not None
                   else max_workspace_bytes(**hints))
        return _WaveSlab(
            wave=wave, src=src, dst=dst, edge_block=edge_block,
            sparse_mask=sparse_mask, dense_mask=dense_mask,
            tiles=tiles, tile_row_start=trs, tile_col_start=tcs,
            csr=csr, extras=extras, run_dense=run_dense,
            staged_bytes=int(staged), workspace_bytes=int(ws),
            edges=ne, segments=len(segments),
            csr_entries=csr_entries, csr_segments=csr_segments,
        )

    def _assemble_mesh(self, wave: Wave) -> tuple[_WaveSlab, list]:
        """Assemble one wave as padded per-device slabs ``[D, …]``.

        The wave's tasks are LPT-split over the mesh
        (:meth:`~repro.core.scheduler.Schedule.partition_tasks` on the
        wave's restricted sub-schedule), each device's COO/CSR slices
        come from :func:`~repro.core.distributed.make_device_edge_partition`
        (every block of every assigned task, bucket-ladder padded so all
        waves share a few slab shapes), dense tiles are per-device
        subsets zero-padded to the wave's tile bucket (zero tiles are
        neutral for every shipped kernel: no set bits → no contribution),
        and ``prepare`` runs once per device against a device-local
        store view — device-rebased CSR maps, device tile subset — so
        host-computed positions index that device's staged slice.

        Returns the slab (extras unset) plus the per-device prepare
        outputs; :meth:`_finalize_mesh_extras` hoists or stacks them.
        """
        store, sched = self.store, self.schedule
        d = self._mesh_devices
        t = sched.tile_dim
        wsched = sched.restrict(wave.task_ids)
        assign = wsched.partition_tasks(d)
        part = make_device_edge_partition(
            store, wsched, assignment=assign, num_devices=d, bucket=True,
            stage_csr=self._csr_mode == "slice",
        )
        src, dst = part["src"], part["dst"]
        edge_block, valid = part["edge_block"], part["valid"]
        dense_blocks = np.zeros(store.layout.num_blocks, bool)
        if wsched.dense_block_ids.size:
            dense_blocks[wsched.dense_block_ids] = True
        edense = dense_blocks[edge_block] & valid
        sparse_mask = valid & ~edense
        dense_mask = edense
        run_dense = (
            self.alg.kernel_dense is not None
            and bool(wsched.dense_task_mask.any())
        )
        dev_scheds = [
            wsched.restrict(np.nonzero(assign == i)[0]) for i in range(d)
        ]

        # -- per-device dense tiles, padded to the wave tile bucket ----
        tiles = trs = tcs = None
        tb = 0
        empty_sub = (np.zeros((0, t, t), np.float32),
                     np.zeros(0, np.int64), np.zeros(0, np.int64))
        dev_subs = [empty_sub] * d      # reused below for prepare views
        if run_dense:
            nds = [int(ds.dense_block_ids.size) for ds in dev_scheds]
            tb = bucket_size(max(nds), minimum=1)
            tiles = np.zeros((d, tb, t, t), np.float32)
            trs = np.zeros((d, tb), np.int64)
            tcs = np.zeros((d, tb), np.int64)
            for i, ds in enumerate(dev_scheds):
                if ds.dense_block_ids.size:
                    dev_subs[i] = store.tile_subset(ds.dense_block_ids)
                    sub, sub_rs, sub_cs = dev_subs[i]
                    tiles[i, : sub.shape[0]] = sub
                    trs[i, : sub.shape[0]] = sub_rs
                    tcs[i, : sub.shape[0]] = sub_cs

        # -- per-device prepare against device-local store views -------
        ws = 0
        extras_list: list = []
        if self.alg.prepare is not None:
            for i, ds in enumerate(dev_scheds):
                if run_dense:
                    sub, sub_rs, sub_cs = dev_subs[i]
                    wstore = dc_replace(
                        store, tile_dim=t,
                        tile_block_ids=ds.dense_block_ids.astype(np.int32),
                        tiles=sub, tile_row_start=sub_rs,
                        tile_col_start=sub_cs,
                    )
                else:
                    wstore = dc_replace(
                        store, tile_dim=0,
                        tile_block_ids=np.zeros(0, np.int32),
                        tiles=np.zeros((0, 0, 0), np.float32),
                        tile_row_start=np.zeros(0, np.int64),
                        tile_col_start=np.zeros(0, np.int64),
                    )
                if self._csr_mode == "slice":
                    rbp_i, indptr_i = part["csr_maps"][i]
                    sl = part["indices"][i, : part["csr_entries"][i]]
                    wstore = dc_replace(
                        wstore, indices=sl, row_block_ptr=rbp_i,
                        indptr=indptr_i,
                    )
                extras = _to_host(self.alg.prepare(wstore, ds))
                ws = max(ws, int(extras.pop("__workspace_bytes__", 0)))
                extras_list.append(extras)
        else:
            extras_list = [{} for _ in range(d)]

        if run_dense:
            from ..kernels.registry import max_workspace_bytes, workspace_bytes

            wk = self.alg.metadata.get("workspace_kernel")
            hints = dict(nd=tb, tile_dim=t)   # per-device padded count
            ws += (workspace_bytes(wk, **hints) if wk is not None
                   else max_workspace_bytes(**hints))

        csr = part.get("indices")
        staged = (
            src.nbytes + dst.nbytes + edge_block.nbytes
            + sparse_mask.nbytes + dense_mask.nbytes
        )
        if csr is not None:
            staged += csr.nbytes
        if tiles is not None:
            staged += tiles.nbytes + trs.nbytes + tcs.nbytes
        slab = _WaveSlab(
            wave=wave, src=src, dst=dst, edge_block=edge_block,
            sparse_mask=sparse_mask, dense_mask=dense_mask,
            tiles=tiles, tile_row_start=trs, tile_col_start=tcs,
            csr=csr, extras=None, run_dense=run_dense,
            staged_bytes=int(staged), workspace_bytes=int(ws),
            edges=int(sum(part["edges"])),
            segments=int(sum(part["segments"])),
            csr_entries=int(sum(part.get("csr_entries", []))),
            csr_segments=int(sum(part.get("csr_segments", []))),
        )
        return slab, extras_list

    def _finalize_mesh_extras(self, slab: _WaveSlab,
                              extras_list: list) -> _WaveSlab:
        """Attach a mesh slab's extras (hoisted → none; else stacked
        with a leading device axis) and fix the byte accounting."""
        if (self._hoisted
                and all(_trees_equal(e, self._resident_extras)
                        for e in extras_list)):
            slab.extras = None
        else:
            slab.extras = self._stack_extras(extras_list)
            slab.staged_bytes += tree_array_bytes(slab.extras)
        slab.per_device_bytes = -(-slab.staged_bytes // self._mesh_devices)
        return slab

    def _stack_extras(self, extras_list: list):
        """Per-device prepare outputs → one tree with a leading device
        axis: the algorithm's ``mesh_pack`` when provided (required for
        structurally device-varying outputs like TC's bucket ladder),
        else a plain stack of structurally identical trees.  Padding is
        never invented here — a neutral pad value is algorithm
        knowledge, so shape mismatches without ``mesh_pack`` raise."""
        alg = self.alg
        if alg.mesh_pack is not None:
            return _to_host(alg.mesh_pack(extras_list))
        flat = [jax.tree_util.tree_flatten(e) for e in extras_list]
        leaves0, treedef0 = flat[0]
        err = (
            f"{alg.name}: per-device prepare outputs differ in "
            f"structure or shape across mesh devices; provide "
            f"BlockAlgorithm.mesh_pack to unify them (padding must be "
            f"neutral for the kernels)"
        )
        if any(td != treedef0 for _, td in flat[1:]):
            raise ValueError(err)
        stacked = []
        for i, leaf0 in enumerate(leaves0):
            col = [leaves for leaves, _ in flat]
            vals = [c[i] for c in col]
            if _is_array_leaf(leaf0):
                if len({np.asarray(v).shape for v in vals}) != 1:
                    raise ValueError(err)
                stacked.append(np.stack([np.asarray(v) for v in vals]))
            else:
                if any(v != leaf0 for v in vals[1:]):
                    raise ValueError(err)
                stacked.append(leaf0)
        return jax.tree_util.tree_unflatten(treedef0, stacked)

    def _decide_hoist(self, slabs: list[_WaveSlab]) -> None:
        """Wave-invariant ``prepare`` outputs (vertex-level attribute
        arrays like PageRank's ``inv_deg``) are staged once as resident
        instead of once per wave per iteration."""
        self._resident_extras: dict = {}
        self._hoisted = False
        if not slabs:
            return
        first = slabs[0].extras
        if all(_trees_equal(s.extras, first) for s in slabs[1:]):
            self._resident_extras = first
            self._hoisted = True
            for s in slabs:
                self._strip_hoisted(s)

    def _strip_hoisted(self, slab: _WaveSlab) -> None:
        """Drop a slab's extras (and their byte cost) when they match
        the hoisted resident tree — also applied to slabs rebuilt by a
        budget split after the hoist decision."""
        if (self._hoisted and slab.extras is not None
                and _trees_equal(slab.extras, self._resident_extras)):
            slab.staged_bytes -= tree_array_bytes(slab.extras)
            slab.extras = None

    def _replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())

    def _put_replicated(self, tree: Any) -> Any:
        """device_put array leaves — replicated over the mesh when one
        is set (reads are free: every device holds the vertex-level
        arrays and the state), plain single-device placement otherwise."""
        if self.mesh is None:
            return _put_arrays(tree)
        sh = self._replicated_sharding()
        return jax.tree_util.tree_map(
            lambda leaf: jax.device_put(leaf, sh)
            if _is_array_leaf(leaf) else leaf,
            tree,
        )

    def _build_resident_context(self) -> Context:
        """Vertex-level arrays only — the per-wave slab fields start
        empty and are swapped in by :func:`with_arrays` each wave.

        ``indices`` is the full CSR only in ``"resident"`` csr mode; in
        ``"slice"`` mode each wave swaps in its staged slice, and in
        ``"none"`` mode kernels never read it, so a minimal placeholder
        keeps both traced branches of conditional kernels indexable
        without holding ``m``-proportional memory.  Under a mesh every
        resident array is replicated on all devices (the model's
        "reads are free" half — writes are reduced by the collectives)."""
        store = self.store
        indices = (
            np.asarray(store.indices) if self._csr_mode == "resident"
            else np.zeros(bucket_size(0), np.int32)
        )
        arrays = self._put_replicated(dict(
            src=np.zeros(0, np.int32),
            dst=np.zeros(0, np.int32),
            edge_block=np.zeros(0, np.int32),
            indptr=np.asarray(store.indptr),
            indices=indices,
            degrees=np.asarray(store.degrees),
            row_block_ptr=np.asarray(store.row_block_ptr),
            cuts=np.asarray(store.layout.cuts),
            sparse_edge_mask=np.zeros(0, bool),
            dense_edge_mask=np.zeros(0, bool),
        ))
        return Context(
            extras=self._put_replicated(dict(self._resident_extras)),
            n=store.n,
            m=store.m,
            p=store.p,
            tile_dim=self.schedule.tile_dim,
            backend=self.backend,
            **arrays,
        )

    # -- execute side --------------------------------------------------
    @property
    def num_waves(self) -> int:
        return len(self._slabs)

    def rebalance(self, wave_compute_s) -> bool:
        """Re-pack the wave queue against observed per-wave compute times.

        The paper's dynamic work queue at wave granularity: when the
        measured compute skew (max/mean over ``wave_compute_s``, one
        entry per current wave) exceeds ``rebalance_threshold``, each
        wave's time is attributed to its tasks proportionally to their
        schedule weights and the whole queue is re-packed LPT against
        those observed times (:func:`repro.core.membudget.repack_waves`)
        — still under the byte budget.  Later iterations run the
        re-packed waves; per-wave partial folding makes any task
        partition produce the identical combined state, so results are
        unchanged.  Called automatically after the calibration pass
        when ``rebalance_threshold`` is set; returns True when a
        re-pack happened.  At most one re-pack per plan.
        """
        times = np.asarray(wave_compute_s, dtype=np.float64)
        if (self._rebalanced or times.size != len(self._slabs)
                or len(self._slabs) < 2):
            return False
        mean = float(times.mean())
        if mean <= 0.0:
            return False
        self._last_skew = float(times.max() / mean)
        thr = self.rebalance_threshold
        if thr is None or self._last_skew <= thr:
            return False
        task_t = np.zeros(self.schedule.num_tasks, dtype=np.float64)
        for t_w, slab in zip(times, self._slabs):
            ids = slab.wave.task_ids
            wts = self.schedule.weights[ids].astype(np.float64)
            tot = float(wts.sum())
            task_t[ids] = (t_w * wts / tot) if tot > 0 else t_w / ids.size
        new_waves = repack_waves(self.schedule, self.budget,
                                 self._footprints, task_t,
                                 devices=self._mesh_devices)
        self._slabs = self._rebuild_slabs(new_waves)
        self._edge_free_bufs = None     # stale slab-0 reference
        self._rebalanced = True
        self.schedule.stats["waves"] = len(self._slabs)
        return True

    @property
    def compile_count(self) -> int:
        return (self._mesh_step.traces if self._mesh_step is not None
                else self._step.traces)

    def _stage(self, w: int):
        """One host→device copy of wave ``w``'s preassembled slab.

        Single device: a dict of device buffers.  Mesh: the ``[D, …]``
        slabs are ``device_put`` with the block-axis sharding (one row
        per device) and the stacked extras travel as a tuple of sharded
        leaves plus their hashable static aux — the double-buffered
        loop overlaps exactly this transfer with the previous wave's
        ``shard_map`` compute."""
        slab = self._slabs[w]
        self._bytes_staged += slab.staged_bytes
        arrays = dict(
            src=slab.src, dst=slab.dst, edge_block=slab.edge_block,
            sparse_edge_mask=slab.sparse_mask, dense_edge_mask=slab.dense_mask,
        )
        if slab.tiles is not None:
            arrays.update(tiles=slab.tiles, tile_row_start=slab.tile_row_start,
                          tile_col_start=slab.tile_col_start)
        if slab.csr is not None:
            arrays["indices"] = slab.csr
        if self.mesh is None:
            bufs = jax.device_put(arrays)
            if slab.extras is not None:
                bufs["extras"] = _put_arrays(slab.extras)
            return bufs
        shard = NamedSharding(self.mesh, PartitionSpec(self.mesh_axis))
        bufs = jax.device_put(arrays, {k: shard for k in arrays})
        if slab.extras is not None:
            ex_leaves, ex_aux = _split_static(slab.extras)
            ex_leaves = tuple(
                jax.device_put(leaf, shard) for leaf in ex_leaves
            )
        else:
            ex_leaves, ex_aux = (), None
        return (bufs, ex_leaves, ex_aux)

    def _wave_context(self, bufs: dict) -> Context:
        arrays = {k: v for k, v in bufs.items() if k != "extras"}
        extras = bufs.get("extras")
        if extras is not None:
            return with_arrays(self._resident, extras=extras, **arrays)
        return with_arrays(self._resident, **arrays)

    def _step_wave(self, w: int, bufs, state0, acc, iarr):
        """Dispatch one staged wave into the right jitted step."""
        slab = self._slabs[w]
        if self.mesh is None:
            return self._step(self._wave_context(bufs), state0, acc, iarr,
                              slab.run_dense)
        slab_bufs, ex_leaves, ex_aux = bufs
        out = self._mesh_step(self._resident, slab_bufs, ex_leaves, state0,
                              acc, iarr, slab.run_dense, ex_aux)
        # per-device collective payload: each combined leaf crosses one
        # all-reduce per wave step (trace-time combined_keys is exact)
        self._collective_bytes += sum(
            int(state0[k].nbytes) for k in self._mesh_step.combined_keys
            if hasattr(state0[k], "nbytes")
        )
        return out

    def _run_waves(self, state0, it: int):
        """One iteration's kernel work: stage + step every wave, folding
        partials; calibration (synchronous, timed) on the first executed
        iteration, double-buffered overlap afterwards."""
        acc = state0
        nw = len(self._slabs)
        if nw == 0:
            return acc, 0.0
        iarr = jnp.int32(it)
        if it < self._edge_free:
            # the algorithm declared these iterations edge-free
            # (kernels read no slab fields and at most the prefix CSR —
            # e.g. Afforest's neighbor-sampling rounds): one
            # representative wave, staged once and cached across the
            # edge-free phase, gives the identical combined result —
            # W-1 redundant full-vertex passes and all repeat stagings
            # saved
            if self._prefix_dev is None and self._prefix_host is not None:
                pptr, pidx = self._prefix_host
                self._prefix_dev = self._put_replicated(
                    dict(indptr=pptr, indices=pidx)
                )
                # replicated puts copy to every mesh device
                self._bytes_staged += (
                    (pptr.nbytes + pidx.nbytes) * self._mesh_devices
                )
            if self.mesh is not None:
                # edge-free kernels consume no per-device data, so the
                # mesh runs them replicated — every device computes the
                # identical full-vertex update from replicated inputs,
                # no collectives needed (a psum here would D-multiply
                # additive leaves); the plain per-wave fold applies
                ctx = self._resident
                if self._prefix_dev is not None:
                    ctx = with_arrays(ctx, **self._prefix_dev)
                acc = self._step(ctx, state0, acc, iarr, False)
                return acc, 0.0
            if self._edge_free_bufs is None:
                self._edge_free_bufs = self._stage(0)
            ctx = self._wave_context(self._edge_free_bufs)
            if self._prefix_dev is not None:
                # adjacency sampling reads the first-k-neighbors CSR,
                # not the (unbounded) global one
                ctx = with_arrays(ctx, **self._prefix_dev)
            acc = self._step(ctx, state0, acc, iarr,
                             self._slabs[0].run_dense)
            return acc, 0.0
        self._edge_free_bufs = None     # release once edge work begins
        self._prefix_dev = None
        if self._calibration is None:
            # warm-up pass: trace/compile every distinct wave shape with
            # the result discarded, so the timed pass below measures
            # steady-state compute — not compilation (which would
            # otherwise saturate overlap_efficiency at 1.0)
            warm = state0
            for w in range(nw):
                warm = self._step_wave(w, self._stage(w), state0, warm, iarr)
            _block_tree(warm)
            stage_s = compute_s = 0.0
            wave_s: list[float] = []
            for w in range(nw):
                t0 = time.perf_counter()
                bufs = self._stage(w)
                _block_tree(bufs)
                stage_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                acc = self._step_wave(w, bufs, state0, acc, iarr)
                _block_tree(acc)
                dt = time.perf_counter() - t0
                compute_s += dt
                wave_s.append(dt)
            self._calibration = dict(stage_s=stage_s, compute_s=compute_s,
                                     wave_compute_s=wave_s)
            # a re-pack only pays off if another iteration will run it —
            # on the final possible iteration it would rebuild (and
            # report) slabs that never execute
            if (self.rebalance_threshold is not None
                    and it + 1 < self.alg.max_iterations
                    and self.rebalance(wave_s)):
                # the measured stage/compute baseline described the old
                # packing — recalibrate on the next iteration so
                # overlap_efficiency reflects the re-packed waves
                # (at most once: rebalance() is one-shot per plan)
                self._calibration = None
            return acc, 0.0
        t0 = time.perf_counter()
        bufs = self._stage(0)
        for w in range(nw):
            # async dispatch: the step for wave w starts on the device
            # (or the whole mesh, under shard_map)...
            acc = self._step_wave(w, bufs, state0, acc, iarr)
            # ...while wave w+1's (sharded) slab crosses host→device.
            # Dropping `bufs` here releases the previous slab's buffers
            # as soon as the step consumes them (two slabs max in
            # flight per device).
            bufs = self._stage(w + 1) if w + 1 < nw else None
        _block_tree(acc)
        return acc, time.perf_counter() - t0

    def run(self, store: BlockStore | None = None,
            state: Any | None = None) -> RunResult:
        """Execute the streamed iteration loop (same contract as
        :meth:`repro.core.engine.Plan.run`)."""
        if store is not None and store is not self.store:
            raise TypeError(
                "StreamingPlan is bound to the store it was compiled "
                "against; compile a new plan for a different graph"
            )
        alg = self.alg
        if state is None:
            assert alg.init_state is not None, f"{alg.name}: init_state required"
            state = alg.init_state(self.store)
        t0 = time.perf_counter()
        it = 0
        cont = True
        overlapped_wall = 0.0
        overlapped_iters = 0
        staged_before = self._bytes_staged
        while cont and it < alg.max_iterations:
            if alg.before is not None:
                state = alg.before(self.host, state, it)
            if self.mesh is not None:
                # the state is replicated on every mesh device (writes
                # are reduced by the step's collectives; host hooks may
                # have injected fresh uncommitted leaves) — a no-op for
                # leaves already placed
                state = self._put_replicated(state)
            state, wall = self._run_waves(state, it)
            if wall > 0.0:
                overlapped_wall += wall
                overlapped_iters += 1
            if self._post is not None:
                state = self._post(self._resident, state, jnp.int32(it))
            if alg.after is not None:
                state, cont = alg.after(self.host, state, it)
            it += 1
        state = jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            state,
        )
        dt = time.perf_counter() - t0
        result = alg.finalize(self.store, state) if alg.finalize else state
        return RunResult(
            result=result,
            state=state,
            iterations=it,
            seconds=dt,
            schedule_stats=dict(
                self.schedule.stats,
                streaming=self._streaming_stats(
                    state, overlapped_wall, overlapped_iters,
                    staged_delta=self._bytes_staged - staged_before,
                ),
            ),
        )

    def _streaming_stats(self, state, overlapped_wall: float,
                         overlapped_iters: int, *,
                         staged_delta: int) -> dict:
        bytes_per_wave = [s.staged_bytes for s in self._slabs]
        calib = self._calibration or dict(stage_s=0.0, compute_s=0.0)
        eff = 0.0
        denom = min(calib["stage_s"], calib["compute_s"])
        if overlapped_iters and denom > 0:
            serial = calib["stage_s"] + calib["compute_s"]
            mean_wall = overlapped_wall / overlapped_iters
            eff = max(0.0, min(1.0, (serial - mean_wall) / denom))
        prefix_bytes = 0
        if self._prefix_host is not None:
            pptr, pidx = self._prefix_host
            prefix_bytes = pptr.nbytes + pidx.nbytes
        return dict(
            num_waves=len(self._slabs),
            budget_bytes=self.budget.total_bytes,
            bytes_per_wave=bytes_per_wave,
            # mesh composition: how many devices cooperate per wave, the
            # worst single device's staged share (each ≤ budget_bytes —
            # on one device this equals bytes_per_wave), and the
            # per-device payload that crossed the combine collectives
            # (psum/pmin/pmax) over the whole run
            mesh_devices=self._mesh_devices,
            per_device_bytes=[
                s.per_device_bytes if self.mesh is not None
                else s.staged_bytes
                for s in self._slabs
            ],
            collective_bytes=int(self._collective_bytes),
            csr_mode=self._csr_mode,
            # per-wave staged CSR slice bytes (bucket-padded, already
            # included in bytes_per_wave) — all zeros unless "slice"
            csr_bytes_per_wave=[
                s.csr.nbytes if s.csr is not None else 0
                for s in self._slabs
            ],
            csr_segments=[s.csr_segments for s in self._slabs],
            # actual H2D traffic this run, counting the calibration
            # warm-up pass and edge-free single-wave iterations honestly
            bytes_staged_total=int(staged_delta),
            resident_bytes=(
                resident_bytes(self.store, state,
                               include_csr=self._csr_mode == "resident")
                + tree_array_bytes(self._resident_extras)
                + tree_array_bytes(state)     # the accumulator copy
            ),
            # first-k-neighbors CSR, device-held only during the
            # edge-free sampling phase (vertex-proportional)
            edge_free_prefix_bytes=int(prefix_bytes),
            edge_buckets=sorted({s.src.shape[0] for s in self._slabs}),
            coalesced_segments=[s.segments for s in self._slabs],
            overlap_efficiency=eff,
            calibration=dict(calib),
            overlapped_iterations=overlapped_iters,
            rebalanced=self._rebalanced,
            rebalance_skew=self._last_skew,
        )


def compile_streaming_plan(alg: BlockAlgorithm, store: BlockStore,
                           schedule: Schedule | None = None,
                           **kw) -> StreamingPlan:
    """Explicit spelling of ``compile_plan(..., memory_budget=...)``."""
    return StreamingPlan(alg, store, schedule, **kw)
