"""Block storage data structures (paper §4.3.2) adapted to JAX static shapes.

The paper stores each block as a CSR/COO/CCOO subgraph.  A TPU program
needs *static* shapes, so PGAbB-JAX packs the ground set of blocks into a
small number of flat arrays:

* **Segmented COO** — every edge appears once, sorted by (block id, src,
  dst); ``block_ptr`` delimits each block's contiguous edge segment.  A
  task is a contiguous slice — the direct analog of handing a block-list
  to a kernel.
* **Conformal row slices** — because the partition is conformal (one
  shared cut vector), the portion of vertex ``u``'s adjacency that falls
  in column stripe ``k`` is a *contiguous slice* of the global CSR row.
  ``row_block_ptr[u, k]`` gives its start; this replaces per-block CSR
  materialization and is exactly the "reasoning" benefit the paper claims
  for conformal partitioning (§4.3).
* **Dense bitmap tiles** — blocks selected by the scheduler's density
  cut-off are additionally materialized as 0/1 tiles of a fixed
  ``tile_dim`` so the MXU path (Pallas matmul kernels) can run them.
  This is the K_D representation; its VMEM footprint is bounded the way
  block-lists bound GPU copies in the paper.

All arrays are plain numpy here; ``device_arrays`` converts what an
algorithm needs to jnp once, up front (the engine hands them to jitted
kernels).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

from .graph import Graph
from .partition import Layout, make_layout

__all__ = ["BlockStore", "build_block_store"]


@dataclass
class BlockStore:
    graph: Graph
    layout: Layout

    # --- segmented COO (sorted by block, then src, then dst) ---
    src: np.ndarray          # (m,) int32 global source ids
    dst: np.ndarray          # (m,) int32 global dest ids
    edge_block: np.ndarray   # (m,) int32 block id of each edge
    block_ptr: np.ndarray    # (nb+1,) int64 edge segment offsets per block id

    # --- conformal row slicing over the (degree-ordered) global CSR ---
    indptr: np.ndarray       # (n+1,) int64
    indices: np.ndarray      # (m,) int32 sorted adjacency
    row_block_ptr: np.ndarray  # (n, p+1) int64: indptr[u] + offset of stripe k

    # --- dense bitmap tiles (filled by the scheduler's dense selection) ---
    tile_dim: int = 0
    tile_block_ids: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    tiles: np.ndarray = field(default_factory=lambda: np.zeros((0, 0, 0), np.float32))
    tile_row_start: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    tile_col_start: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def p(self) -> int:
        return self.layout.p

    @cached_property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def block_edges(self, block_id: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.block_ptr[block_id], self.block_ptr[block_id + 1]
        return self.src[s:e], self.dst[s:e]

    def block_density(self, block_id: int) -> float:
        i, j = divmod(block_id, self.p)
        r = self.layout.cuts[i + 1] - self.layout.cuts[i]
        c = self.layout.cuts[j + 1] - self.layout.cuts[j]
        e = self.block_ptr[block_id + 1] - self.block_ptr[block_id]
        return float(e) / float(max(r * c, 1))

    def block_range(self, block_id: int) -> tuple[int, int]:
        i, j = divmod(block_id, self.p)
        return (
            int(self.layout.cuts[i + 1] - self.layout.cuts[i]),
            int(self.layout.cuts[j + 1] - self.layout.cuts[j]),
        )

    # ------------------------------------------------------------------
    def materialize_tiles(self, block_ids: np.ndarray, tile_dim: int) -> None:
        """Pack the selected blocks as dense 0/1 tiles of shape (tile_dim²).

        Blocks whose vertex ranges exceed ``tile_dim`` are the caller's
        bug — the scheduler only selects blocks that fit (the analog of
        the paper's "blocks of a single block-list fit device memory").
        """
        block_ids = np.asarray(block_ids, dtype=np.int32)
        nd = block_ids.shape[0]
        tiles = np.zeros((nd, tile_dim, tile_dim), dtype=np.float32)
        row_start = np.zeros(nd, dtype=np.int64)
        col_start = np.zeros(nd, dtype=np.int64)
        for t, b in enumerate(block_ids):
            i, j = divmod(int(b), self.p)
            r0, c0 = self.layout.cuts[i], self.layout.cuts[j]
            rr, cc = self.block_range(int(b))
            if rr > tile_dim or cc > tile_dim:
                raise ValueError(
                    f"block {b} range ({rr},{cc}) exceeds tile_dim {tile_dim}"
                )
            es, ed = self.block_edges(int(b))
            tiles[t, es - r0, ed - c0] = 1.0
            row_start[t], col_start[t] = r0, c0
        self.tile_dim = tile_dim
        self.tile_block_ids = block_ids
        self.tiles = tiles
        self.tile_row_start = row_start
        self.tile_col_start = col_start

    # ------------------------------------------------------------------
    def edge_segments(self, block_ids: np.ndarray) -> list[tuple[int, int]]:
        """Coalesced ``[start, end)`` edge ranges covering ``block_ids``.

        Blocks are contiguous in the segmented COO, so a wave whose
        blocks are consecutive ids collapses to a single slice — the
        "one copy per block-list" staging property of the paper.  Input
        order is ignored; ranges come back sorted and merged.
        """
        ids = np.unique(np.asarray(block_ids, dtype=np.int64))
        out: list[tuple[int, int]] = []
        for b in ids:
            s, e = int(self.block_ptr[b]), int(self.block_ptr[b + 1])
            if s == e:
                continue
            if out and out[-1][1] == s:
                out[-1] = (out[-1][0], e)
            else:
                out.append((s, e))
        return out

    def csr_slices(
        self, block_ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, list[tuple[int, int]]]:
        """Conformal CSR row slices covering ``block_ids`` — the per-wave
        CSR staging unit of the streaming executor.

        Because the partition is conformal, the adjacency a block (i, j)
        contributes is, for every row ``u`` in stripe ``i``, the
        contiguous slice ``indices[row_block_ptr[u, j] :
        row_block_ptr[u, j+1]]``.  This method concatenates exactly
        those slices (rows ascending, stripes ascending within a row)
        and returns

        * ``indices_slice`` — the staged adjacency (int32), holding only
          the selected blocks' entries;
        * ``row_block_ptr`` — rebased ``(n, p+1)`` map: for a selected
          ``(u, k)``, ``indices_slice[rbp[u, k] : rbp[u, k+1]]`` equals
          the same slice of the global CSR.  Unselected ``(u, k)``
          entries collapse to zero-length slices;
        * ``indptr`` — rebased ``(n+1,)``: start of each row's *staged*
          adjacency (``diff`` gives staged — not global — degrees);
        * ``segments`` — the coalesced ``[start, end)`` *global* index
          ranges gathered, for staging diagnostics (few segments when
          the selected blocks are contiguous).
        """
        p = self.p
        n = self.n
        rbp = self.row_block_ptr
        ids = np.unique(np.asarray(block_ids, dtype=np.int64))
        touched = np.zeros((p, p), dtype=bool)
        if ids.size:
            gi, gj = np.divmod(ids, p)
            touched[gi, gj] = True
        stripe_of_row = np.repeat(np.arange(p), np.diff(self.layout.cuts))
        touched_row = touched[stripe_of_row]            # (n, p)
        seg_len = rbp[:, 1:] - rbp[:, :-1]              # (n, p)
        lens = np.where(touched_row, seg_len, 0).ravel()
        csum = np.concatenate([[0], np.cumsum(lens)])   # (n*p + 1,)
        new_rbp = np.empty_like(rbp)
        new_rbp[:, :p] = csum[:-1].reshape(n, p)
        new_rbp[:, p] = csum[p::p] if n else 0
        new_indptr = np.concatenate([new_rbp[:, 0], csum[-1:]])
        mask = lens > 0
        starts_g = rbp[:, :-1].ravel()[mask]
        ends_g = starts_g + lens[mask]
        if starts_g.size:
            brk = np.flatnonzero(starts_g[1:] != ends_g[:-1]) + 1
            seg_s = starts_g[np.concatenate([[0], brk])]
            seg_e = ends_g[np.concatenate([brk - 1, [starts_g.size - 1]])]
            idx = np.concatenate(
                [np.arange(s, e, dtype=np.int64) for s, e in zip(seg_s, seg_e)]
            )
            sliced = self.indices[idx]
            segments = list(zip(seg_s.tolist(), seg_e.tolist()))
        else:
            sliced = np.zeros(0, np.int32)
            segments = []
        return sliced.astype(np.int32), new_rbp, new_indptr, segments

    def tile_subset(
        self, block_ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(tiles, row_start, col_start) for ``block_ids`` out of the
        materialized tile set — the per-wave dense staging unit.  All
        requested blocks must already be materialized."""
        ids = np.asarray(block_ids, dtype=np.int32)
        pos_of = {int(b): i for i, b in enumerate(self.tile_block_ids)}
        try:
            pos = np.asarray([pos_of[int(b)] for b in ids], dtype=np.int64)
        except KeyError as e:  # pragma: no cover — scheduler bug guard
            raise ValueError(f"block {e} has no materialized tile") from e
        return self.tiles[pos], self.tile_row_start[pos], self.tile_col_start[pos]

    # ------------------------------------------------------------------
    def device_arrays(self) -> dict:
        """jnp views of the store for jitted kernels (lazy import keeps the
        host-side path numpy-only)."""
        import jax.numpy as jnp

        out = dict(
            src=jnp.asarray(self.src),
            dst=jnp.asarray(self.dst),
            edge_block=jnp.asarray(self.edge_block),
            indptr=jnp.asarray(self.indptr),
            indices=jnp.asarray(self.indices),
            degrees=jnp.asarray(self.degrees),
            row_block_ptr=jnp.asarray(self.row_block_ptr),
        )
        if self.tile_block_ids.size:
            out.update(
                tiles=jnp.asarray(self.tiles),
                tile_row_start=jnp.asarray(self.tile_row_start),
                tile_col_start=jnp.asarray(self.tile_col_start),
            )
        return out


def build_block_store(g: Graph, p: int, *, order: str = "row_major") -> BlockStore:
    """Partition ``g`` with the symmetric rectilinear partitioner and pack blocks."""
    layout = make_layout(g, p, order=order)
    src, dst = g.coo()
    src = src.astype(np.int64)
    dst64 = dst.astype(np.int64)
    bi = np.searchsorted(layout.cuts, src, side="right") - 1
    bj = np.searchsorted(layout.cuts, dst64, side="right") - 1
    bid = (bi * p + bj).astype(np.int64)
    # sort by (block, src, dst) — cheap radix via linearization
    key = (bid * g.n + src) * g.n + dst64
    order_idx = np.argsort(key, kind="stable")
    src_s = src[order_idx].astype(np.int32)
    dst_s = dst64[order_idx].astype(np.int32)
    bid_s = bid[order_idx].astype(np.int32)
    nb = p * p
    block_ptr = np.zeros(nb + 1, dtype=np.int64)
    np.add.at(block_ptr, bid_s + 1, 1)
    np.cumsum(block_ptr, out=block_ptr)

    # conformal row slicing: offsets of each column stripe inside each CSR row.
    # counts[u, k] = #neighbors of u in column stripe k; prefix over k gives
    # the slice starts.  O(m) vectorized — no per-row searchsorted loop.
    row_block_ptr = np.empty((g.n, p + 1), dtype=np.int64)
    row_block_ptr[:, 0] = g.indptr[:-1]
    if g.m:
        csr_src, _ = g.coo()
        stripe = np.searchsorted(layout.cuts, g.indices.astype(np.int64),
                                 side="right") - 1
        counts = np.zeros((g.n, p), dtype=np.int64)
        np.add.at(counts, (csr_src.astype(np.int64), stripe), 1)
        np.cumsum(counts, axis=1, out=counts)
        row_block_ptr[:, 1:] = g.indptr[:-1, None] + counts
    else:
        row_block_ptr[:, 1:] = g.indptr[:-1, None]

    return BlockStore(
        graph=g,
        layout=layout,
        src=src_s,
        dst=dst_s,
        edge_block=bid_s,
        block_ptr=block_ptr,
        indptr=g.indptr.astype(np.int64),
        indices=g.indices.astype(np.int32),
        row_block_ptr=row_block_ptr,
    )
