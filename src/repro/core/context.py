"""Typed execution contexts: the device-side ``Context`` pytree and the
host-side ``HostCtx``.

The paper's contract (§3) is that a user writes six functors and PGAbB
owns partitioning, scheduling, and dispatch.  The original engine leaked
that plumbing through a stringly-typed ``ctx`` dict that mixed device
arrays with host objects (``store``, ``schedule``) and needed a
recursive split/merge hack to cross the jit boundary.  This module
replaces the dict with two explicit objects:

* **``Context``** — everything a *kernel* may touch inside the jitted
  step.  Device arrays are pytree children; small scalars (``n``, ``m``,
  ``p``, ``tile_dim``) and the resolved ``backend`` name are static aux
  data, so they participate in jit's cache key exactly like shapes do.
  Per-algorithm ``prepare`` outputs live in ``extras``: an arbitrary
  pytree whose ``jax.Array``/ndarray leaves are traced and whose other
  leaves (ints used as shapes, flags, ...) stay static.  Container
  structure — including tuples — round-trips unchanged.
* **``HostCtx``** — everything the *host-side hooks* (``I_B``/``I_A``)
  may touch: the ``BlockStore``, the ``Schedule`` (a first-class,
  inspectable artifact), and the same static scalars.  It never crosses
  the jit boundary.

Two graphs with identical padded shapes produce ``Context`` objects with
identical treedefs, which is what lets a compiled :class:`~repro.core.engine.Plan`
be reused across graphs without retracing.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Any

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # pragma: no cover — import cycle guard, typing only
    from .blocks import BlockStore
    from .scheduler import Schedule

__all__ = ["Context", "HostCtx", "build_context", "build_host_ctx",
           "with_extras", "with_arrays"]


# Device-array fields, in flatten order.  ``tiles``/``tile_*`` are None
# when the schedule routed nothing to the dense path.
_ARRAY_FIELDS = (
    "src", "dst", "edge_block", "indptr", "indices", "degrees",
    "row_block_ptr", "cuts", "sparse_edge_mask", "dense_edge_mask",
    "tiles", "tile_row_start", "tile_col_start",
)
_STATIC_FIELDS = ("n", "m", "p", "tile_dim", "backend")


class _DynMarker:
    """Aux-data placeholder for an ``extras`` leaf that is traced."""

    def __repr__(self) -> str:  # pragma: no cover
        return "<traced>"


_TRACED = _DynMarker()


def _is_traced_leaf(leaf: Any) -> bool:
    return isinstance(leaf, (jax.Array, np.ndarray))


@dataclass(eq=False)
class Context:
    """Device-side inputs of one compiled step (a registered pytree).

    Kernels receive this as their first argument and read it by
    attribute — ``ctx.src``, ``ctx.sparse_edge_mask``, ``ctx.tiles`` —
    plus whatever their algorithm's ``prepare`` stashed in
    ``ctx.extras``.  Host objects are *not* here by construction; see
    :class:`HostCtx`.
    """

    # --- segmented COO + CSR views of the store -----------------------
    src: Any
    dst: Any
    edge_block: Any
    indptr: Any
    indices: Any
    degrees: Any
    row_block_ptr: Any
    cuts: Any
    # --- static path routing masks ------------------------------------
    sparse_edge_mask: Any
    dense_edge_mask: Any
    # --- dense bitmap tiles (None when the dense path is empty) -------
    tiles: Any = None
    tile_row_start: Any = None
    tile_col_start: Any = None
    # --- per-algorithm prepare outputs --------------------------------
    extras: dict[str, Any] = field(default_factory=dict)
    # --- static scalars (jit cache key, not traced) -------------------
    n: int = 0
    m: int = 0
    p: int = 1
    tile_dim: int = 0
    backend: str = "xla"


def _context_flatten(ctx: Context):
    fixed = tuple(getattr(ctx, f) for f in _ARRAY_FIELDS)
    leaves, treedef = jax.tree_util.tree_flatten(ctx.extras)
    traced = tuple(l for l in leaves if _is_traced_leaf(l))
    markers = tuple(
        _TRACED if _is_traced_leaf(l) else l for l in leaves
    )
    statics = tuple(getattr(ctx, f) for f in _STATIC_FIELDS)
    return fixed + (traced,), (treedef, markers, statics)


def _context_unflatten(aux, children):
    treedef, markers, statics = aux
    *fixed, traced = children
    it = iter(traced)
    leaves = [next(it) if mk is _TRACED else mk for mk in markers]
    extras = jax.tree_util.tree_unflatten(treedef, leaves)
    kw = dict(zip(_ARRAY_FIELDS, fixed))
    kw.update(zip(_STATIC_FIELDS, statics))
    return Context(extras=extras, **kw)


jax.tree_util.register_pytree_node(Context, _context_flatten, _context_unflatten)


@dataclass
class HostCtx:
    """Host-side view handed to ``before``/``after`` hooks (I_B/I_A).

    Hooks may inspect the store and the schedule (both host objects),
    read scalars, and keep private scratch in ``extras`` — but nothing
    here is ever traced.
    """

    store: "BlockStore"
    schedule: "Schedule"
    backend: str
    n: int
    m: int
    p: int
    tile_dim: int
    extras: dict[str, Any] = field(default_factory=dict)

    def __getitem__(self, key: str) -> Any:
        # Legacy convenience: old hooks indexed the ctx dict (ctx["n"]).
        if key in ("n", "m", "p", "tile_dim", "backend"):
            return getattr(self, key)
        if key in ("store", "schedule"):
            return getattr(self, key)
        return self.extras[key]


# ----------------------------------------------------------------------
def build_context(store: "BlockStore", schedule: "Schedule", *,
                  backend: str = "xla",
                  extras: dict[str, Any] | None = None) -> Context:
    """Assemble the device-side :class:`Context` for one (store, schedule).

    Mirrors what the legacy ``Engine._build_context`` produced, minus the
    host objects: segmented-COO/CSR device views, the static edge→path
    routing masks derived from the schedule's dense selection, and the
    conformal cut vector.
    """
    arrays = store.device_arrays()
    dense_blocks = np.zeros(store.layout.num_blocks, dtype=bool)
    if schedule.dense_block_ids.size:
        dense_blocks[schedule.dense_block_ids] = True
    edge_dense = dense_blocks[np.asarray(store.edge_block)]
    return Context(
        src=arrays["src"],
        dst=arrays["dst"],
        edge_block=arrays["edge_block"],
        indptr=arrays["indptr"],
        indices=arrays["indices"],
        degrees=arrays["degrees"],
        row_block_ptr=arrays["row_block_ptr"],
        cuts=jnp.asarray(store.layout.cuts),
        sparse_edge_mask=jnp.asarray(~edge_dense),
        dense_edge_mask=jnp.asarray(edge_dense),
        tiles=arrays.get("tiles"),
        tile_row_start=arrays.get("tile_row_start"),
        tile_col_start=arrays.get("tile_col_start"),
        extras=dict(extras or {}),
        n=store.n,
        m=store.m,
        p=store.p,
        tile_dim=schedule.tile_dim,
        backend=backend,
    )


def build_host_ctx(store: "BlockStore", schedule: "Schedule", *,
                   backend: str = "xla") -> HostCtx:
    return HostCtx(
        store=store,
        schedule=schedule,
        backend=backend,
        n=store.n,
        m=store.m,
        p=store.p,
        tile_dim=schedule.tile_dim,
    )


def with_arrays(ctx: Context, **arrays: Any) -> Context:
    """Return a copy of ``ctx`` with the named device-array fields (and
    optionally ``extras``) swapped out.

    This is how the streaming executor turns the *resident* context
    (vertex-level arrays, full-graph scalars) into a per-wave context:
    the segmented-COO slab, routing masks, tile set, and wave extras are
    replaced while everything resident — ``indptr``, ``degrees``,
    ``row_block_ptr``, static scalars — is shared by reference, so two
    waves with equal slab shapes produce identical treedefs and hit the
    same compiled step.
    """
    unknown = set(arrays) - set(_ARRAY_FIELDS) - {"extras"}
    if unknown:
        raise TypeError(f"unknown Context array fields: {sorted(unknown)}")
    return replace(ctx, **arrays)


def with_extras(ctx: Context, extras: dict[str, Any]) -> Context:
    """Return a copy of ``ctx`` with ``extras`` merged in (tuples and all
    other container structure preserved — this is the typed replacement
    for the old dict-merge path)."""
    merged = dict(ctx.extras)
    merged.update(extras)
    return replace(ctx, extras=merged)
