"""Multi-device execution of block algorithms via ``shard_map``.

The paper runs tasks concurrently on CPU threads + GPU streams of one
node.  On a JAX mesh the analog is a ``blocks`` mesh axis: the scheduler
LPT-packs tasks onto devices, each device processes its own contiguous
(padded) edge partition, and global vertex attributes are combined with
collectives — ``psum`` for additive attributes (PageRank ranks, triangle
counts), ``pmin``/``pmax`` for hook/label attributes (SV, CC, BFS
parents).

The combine op is declared by the algorithm (``metadata['combine']``).
Attribute arrays are replicated; edge work is sharded.  This is the
"break the decentralized model, make blocks visible to everyone" option
the paper adopts for shared memory, generalized to a mesh: reads are
free (replicated), writes are reduced.

``make_device_edge_partition`` turns an LPT schedule into the padded
per-device COO slabs; ``shard_step`` wraps one engine step in
``shard_map``.  On this CPU container the same code runs with a 1-device
mesh in-process and with an 8-device host-platform mesh in the
integration test (subprocess sets XLA_FLAGS).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .blocks import BlockStore
from .scheduler import Schedule

__all__ = ["make_device_edge_partition", "DistributedEngine", "combine_fn"]


def combine_fn(kind: str, axis: str) -> Callable:
    if kind == "add":
        return partial(jax.lax.psum, axis_name=axis)
    if kind == "min":
        return partial(jax.lax.pmin, axis_name=axis)
    if kind == "max":
        return partial(jax.lax.pmax, axis_name=axis)
    raise ValueError(f"unknown combine kind {kind!r}")


def make_device_edge_partition(
    store: BlockStore, sched: Schedule
) -> dict[str, np.ndarray]:
    """Pad each device's assigned edges into a [D, E_max] slab.

    Tasks (block-lists) were LPT-assigned; a device's edges are the union
    of the *first* block of each of its tasks (bulk/activation modes use
    single-block lists; pattern mode does its own partitioning).
    Padding uses src=dst=0 with valid=False.
    """
    d = sched.num_devices
    per_dev_edges: list[list[np.ndarray]] = [[] for _ in range(d)]
    for tid in range(sched.num_tasks):
        dev = int(sched.device_assignment[tid])
        b = int(sched.blocklists[tid][0])
        s, e = store.block_ptr[b], store.block_ptr[b + 1]
        per_dev_edges[dev].append(np.arange(s, e, dtype=np.int64))
    idx = [
        np.concatenate(lst) if lst else np.zeros(0, np.int64) for lst in per_dev_edges
    ]
    emax = max((int(x.shape[0]) for x in idx), default=1) or 1
    src = np.zeros((d, emax), dtype=np.int32)
    dst = np.zeros((d, emax), dtype=np.int32)
    valid = np.zeros((d, emax), dtype=bool)
    for i, ix in enumerate(idx):
        k = ix.shape[0]
        src[i, :k] = store.src[ix]
        dst[i, :k] = store.dst[ix]
        valid[i, :k] = True
    return dict(src=src, dst=dst, valid=valid)


class DistributedEngine:
    """Run a *bulk-synchronous* block algorithm over a device mesh.

    The algorithm provides ``edge_update(src, dst, valid, state) -> state``
    — the per-shard body (it sees only this device's edges) — and a
    ``combine`` kind for each state leaf (``metadata['combine']``:
    a single kind or a dict keyed by state field).
    """

    def __init__(
        self,
        store: BlockStore,
        sched: Schedule,
        edge_update: Callable,
        combine: str | dict[str, str] = "add",
        mesh: Mesh | None = None,
        axis: str = "blocks",
    ) -> None:
        if mesh is None:
            devs = np.array(jax.devices()[: sched.num_devices])
            mesh = Mesh(devs, (axis,))
        self.mesh = mesh
        self.axis = axis
        self.combine = combine
        self.edge_update = edge_update
        part = make_device_edge_partition(store, sched)
        shard = NamedSharding(mesh, P(axis, None))
        self.src = jax.device_put(part["src"], shard)
        self.dst = jax.device_put(part["dst"], shard)
        self.valid = jax.device_put(part["valid"], shard)

        def _step(src, dst, valid, state):
            # each shard sees (1, E_max) slabs — drop the leading axis
            new_state = self.edge_update(src[0], dst[0], valid[0], state)
            if isinstance(self.combine, str):
                new_state = jax.tree.map(
                    lambda orig, new: combine_fn(self.combine, axis)(new - orig) + orig
                    if self.combine == "add"
                    else combine_fn(self.combine, axis)(new),
                    state,
                    new_state,
                )
            else:
                out = {}
                for k, v in new_state.items():
                    kind = self.combine.get(k, "add")
                    if kind == "add":
                        out[k] = combine_fn("add", axis)(v - state[k]) + state[k]
                    else:
                        out[k] = combine_fn(kind, axis)(v)
                new_state = out
            return new_state

        self._step = jax.jit(
            shard_map(
                _step,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
                out_specs=P(),
            )
        )

    def step(self, state: Any) -> Any:
        return self._step(self.src, self.dst, self.valid, state)
