"""Multi-device execution of block algorithms via ``shard_map``.

The paper runs tasks concurrently on CPU threads + GPU streams of one
node.  On a JAX mesh the analog is a ``blocks`` mesh axis: the scheduler
LPT-packs tasks onto devices, each device processes its own contiguous
(padded) edge partition, and global vertex attributes are combined with
collectives — ``psum`` for additive attributes (PageRank ranks, triangle
counts), ``pmin``/``pmax`` for hook/label attributes (SV, CC, BFS
parents).

The combine op is declared by the algorithm (``metadata['combine']``).
Attribute arrays are replicated; edge work is sharded.  This is the
"break the decentralized model, make blocks visible to everyone" option
the paper adopts for shared memory, generalized to a mesh: reads are
free (replicated), writes are reduced.

``make_device_edge_partition`` turns an LPT schedule into the padded
per-device COO (and, on request, conformal-CSR) slabs — it is shared by
:class:`DistributedEngine` (whole-graph, resident) and by the
mesh-cooperative streaming executor (:mod:`repro.core.stream`), which
calls it once per *wave* with a wave-local assignment and bucket-ladder
padding.  On this CPU container the same code runs with a 1-device mesh
in-process and with an 8-device host-platform mesh in the integration
tests (subprocess sets XLA_FLAGS).

The full distributed execution model — what is replicated, what is
sharded, which collective folds which attribute — is documented in
``docs/distributed.md``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .blocks import BlockStore
from .scheduler import Schedule

__all__ = ["make_device_edge_partition", "DistributedEngine", "combine_fn"]


def combine_fn(kind: str, axis: str) -> Callable:
    if kind == "add":
        return partial(jax.lax.psum, axis_name=axis)
    if kind == "min":
        return partial(jax.lax.pmin, axis_name=axis)
    if kind == "max":
        return partial(jax.lax.pmax, axis_name=axis)
    raise ValueError(f"unknown combine kind {kind!r}")


def make_device_edge_partition(
    store: BlockStore, sched: Schedule, *,
    assignment: np.ndarray | None = None,
    num_devices: int | None = None,
    bucket: bool = False,
    stage_csr: bool = False,
    alloc: Callable[..., np.ndarray] | None = None,
) -> dict[str, Any]:
    """Partition a schedule's tasks into padded per-device slabs.

    A device's edge set is the union of **every** block of each of its
    assigned tasks, deduplicated within the device (an earlier revision
    took only the first block of each block-list, silently dropping the
    other blocks of multi-block pattern-mode tasks).  Across devices a
    block may be staged more than once when two tasks of different
    devices share it — harmless for pattern-mode algorithms, whose
    kernels drive work from ``prepare`` items rather than the raw slab,
    and impossible for bulk/activation composition (one block per task).
    Padding uses src=dst=0 with valid=False.

    Parameters
    ----------
    assignment
        Per-task device ids; defaults to ``sched.device_assignment``
        (the global LPT packing).  The streaming executor passes a
        wave-local LPT assignment instead.
    num_devices
        Mesh size; defaults to ``sched.num_devices``.
    bucket
        Pad the slab width up the power-of-two bucket ladder
        (:func:`repro.core.membudget.bucket_size`) so all waves of one
        plan share a few slab shapes and the jitted mesh step does not
        retrace per wave.
    alloc
        ``alloc(shape, dtype) -> zeroed np.ndarray`` used for the big
        padded per-device slabs instead of ``np.zeros`` — the streaming
        executor passes its staging arena's pooled-buffer allocator so
        per-wave assembly recycles buffers instead of churning the host
        allocator.  Must return zero-filled memory (padding semantics).
    stage_csr
        Additionally build each device's conformal CSR row slices
        (:meth:`~repro.core.blocks.BlockStore.csr_slices` over the
        device's blocks): the returned dict gains ``indices`` (a padded
        ``[D, C]`` slab), ``csr_entries``/``csr_segments`` (per-device
        true lengths / coalesced gather counts) and ``csr_maps`` — the
        per-device rebased ``(row_block_ptr, indptr)`` pair an
        algorithm's ``prepare`` needs to address its device's slice.

    Returns ``dict(src, dst, edge_block, valid, blocks, edges, ...)``:
    ``[D, E]`` int32/bool slabs plus per-device block-id arrays and true
    edge counts.
    """
    from .membudget import bucket_size

    d = int(num_devices) if num_devices is not None else sched.num_devices
    assign = (
        np.asarray(assignment, dtype=np.int64)
        if assignment is not None else sched.device_assignment
    )
    if assign.shape[0] != sched.num_tasks:
        raise ValueError(
            f"assignment covers {assign.shape[0]} tasks, schedule has "
            f"{sched.num_tasks}"
        )
    blocks = [
        np.unique(sched.blocklists[assign == i]).astype(np.int64)
        if (assign == i).any() else np.zeros(0, np.int64)
        for i in range(d)
    ]
    idx = []
    seg_counts = []
    for bl in blocks:
        segs = store.edge_segments(bl)
        seg_counts.append(len(segs))
        idx.append(
            np.concatenate([np.arange(s, e, dtype=np.int64) for s, e in segs])
            if segs else np.zeros(0, np.int64)
        )
    emax = max((int(x.shape[0]) for x in idx), default=1) or 1
    eb = bucket_size(emax) if bucket else emax
    zeros = alloc if alloc is not None else np.zeros
    src = zeros((d, eb), dtype=np.int32)
    dst = zeros((d, eb), dtype=np.int32)
    edge_block = zeros((d, eb), dtype=np.int32)
    valid = zeros((d, eb), dtype=bool)
    for i, ix in enumerate(idx):
        k = ix.shape[0]
        src[i, :k] = store.src[ix]
        dst[i, :k] = store.dst[ix]
        edge_block[i, :k] = store.edge_block[ix]
        valid[i, :k] = True
    out: dict[str, Any] = dict(
        src=src, dst=dst, edge_block=edge_block, valid=valid,
        blocks=blocks, edges=[int(x.shape[0]) for x in idx],
        segments=seg_counts,
    )
    if stage_csr:
        slices = [store.csr_slices(bl) for bl in blocks]
        cmax = max((int(s[0].shape[0]) for s in slices), default=1) or 1
        cb = bucket_size(cmax) if bucket else cmax
        indices = zeros((d, cb), dtype=np.int32)
        for i, (sl, _, _, _) in enumerate(slices):
            indices[i, : sl.shape[0]] = sl
        out.update(
            indices=indices,
            csr_entries=[int(s[0].shape[0]) for s in slices],
            csr_segments=[len(s[3]) for s in slices],
            csr_maps=[(s[1], s[2]) for s in slices],
        )
    return out


class DistributedEngine:
    """Run a *bulk-synchronous* block algorithm over a device mesh.

    The algorithm provides ``edge_update(src, dst, valid, state) -> state``
    — the per-shard body (it sees only this device's edges) — and a
    ``combine`` kind for each state leaf (``metadata['combine']``:
    a single kind or a dict keyed by state field).
    """

    def __init__(
        self,
        store: BlockStore,
        sched: Schedule,
        edge_update: Callable,
        combine: str | dict[str, str] = "add",
        mesh: Mesh | None = None,
        axis: str = "blocks",
    ) -> None:
        if mesh is None:
            devs = np.array(jax.devices()[: sched.num_devices])
            mesh = Mesh(devs, (axis,))
        self.mesh = mesh
        self.axis = axis
        self.combine = combine
        self.edge_update = edge_update
        part = make_device_edge_partition(store, sched)
        shard = NamedSharding(mesh, P(axis, None))
        self.src = jax.device_put(part["src"], shard)
        self.dst = jax.device_put(part["dst"], shard)
        self.valid = jax.device_put(part["valid"], shard)

        def _step(src, dst, valid, state):
            # each shard sees (1, E_max) slabs — drop the leading axis
            new_state = self.edge_update(src[0], dst[0], valid[0], state)
            if isinstance(self.combine, str):
                new_state = jax.tree.map(
                    lambda orig, new: combine_fn(self.combine, axis)(new - orig) + orig
                    if self.combine == "add"
                    else combine_fn(self.combine, axis)(new),
                    state,
                    new_state,
                )
            else:
                out = {}
                for k, v in new_state.items():
                    kind = self.combine.get(k, "add")
                    if kind == "add":
                        out[k] = combine_fn("add", axis)(v - state[k]) + state[k]
                    else:
                        out[k] = combine_fn(kind, axis)(v)
                new_state = out
            return new_state

        self._step = jax.jit(
            shard_map(
                _step,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
                out_specs=P(),
            )
        )

    def step(self, state: Any) -> Any:
        return self._step(self.src, self.dst, self.valid, state)
