"""Graph containers, generators and I/O for PGAbB-JAX.

Host-side (numpy) graph representation.  The paper's I/O handler reads
ASCII edge lists in parallel (PIGO) and caches a custom binary format; we
mirror that with a numpy-based edge-list reader and an ``.npz`` binary
cache that is ~2 orders of magnitude faster to re-load.

All graphs are stored as CSR over ``int32`` vertex ids.  PGAbB's
preprocessing (paper §5.1) is reproduced: symmetrize (make undirected),
remove duplicate edges and self loops.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Graph",
    "from_edges",
    "read_edge_list",
    "load_binary",
    "save_binary",
    "rmat",
    "erdos_renyi",
    "grid_road",
    "star_skew",
    "degree_order",
    "csr_prefix",
]


@dataclass(frozen=True)
class Graph:
    """CSR graph.  ``indptr``/``indices`` follow scipy conventions."""

    indptr: np.ndarray      # (n+1,) int64
    indices: np.ndarray     # (m,)  int32, sorted within each row
    n: int
    directed: bool = False
    name: str = "graph"
    # cached degree array (out-degree == degree for undirected graphs)
    _degrees: np.ndarray | None = field(default=None, repr=False, compare=False)

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    @property
    def num_edges_undirected(self) -> int:
        return self.m // (1 if self.directed else 2)

    @property
    def degrees(self) -> np.ndarray:
        d = np.diff(self.indptr).astype(np.int64)
        return d

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def coo(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (src, dst) arrays of all stored edges."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.indices.astype(np.int32)

    def checksum(self) -> str:
        h = hashlib.sha1()
        h.update(self.indptr.tobytes())
        h.update(self.indices.tobytes())
        return h.hexdigest()[:12]


# ---------------------------------------------------------------------------
# construction


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n: int | None = None,
    *,
    symmetrize: bool = True,
    name: str = "graph",
) -> Graph:
    """Build a CSR graph from an edge list.

    Reproduces the paper's preprocessing: optional symmetrization,
    duplicate-edge and self-loop removal, sorted adjacency.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1) if src.size else 0
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    # dedup via linearized sort
    lin = src * np.int64(n) + dst
    lin = np.unique(lin)
    src = (lin // n).astype(np.int64)
    dst = (lin % n).astype(np.int32)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return Graph(indptr=indptr, indices=dst, n=n, directed=not symmetrize, name=name)


def read_edge_list(path: str, *, symmetrize: bool = True, comments: str = "#%") -> Graph:
    """PIGO-style ASCII edge-list reader (whitespace separated ``u v`` lines).

    Vectorized: ``np.loadtxt`` parses the whole file in one pass (blank
    lines skipped, any of the ``comments`` characters starts a comment,
    trailing columns such as edge weights ignored).  Falls back to a
    line-by-line parse only for ragged files loadtxt rejects.
    """
    name = os.path.splitext(os.path.basename(path))[0]
    try:
        arr = np.loadtxt(path, dtype=np.int64, comments=list(comments),
                         usecols=(0, 1), ndmin=2)
    except (ValueError, IndexError):
        with open(path, "rb") as f:
            text = f.read().decode("utf-8", errors="ignore")
        rows = [
            tuple(map(int, ln.split()[:2]))
            for ln in text.splitlines()
            if ln.strip() and ln.lstrip()[0] not in comments
        ]
        arr = np.asarray(rows, dtype=np.int64).reshape(-1, 2)
    return from_edges(arr[:, 0], arr[:, 1], symmetrize=symmetrize, name=name)


def save_binary(g: Graph, path: str) -> None:
    """Custom binary cache (paper §4.2): one mmap-able npz.

    Written atomically: savez always appends ``.npz`` to a name without
    it, so write to a deterministic ``<path>.tmp.npz`` and always
    ``os.replace`` onto the destination (no stale temp files, no
    missed rename).
    """
    tmp = path + ".tmp"
    np.savez(tmp, indptr=g.indptr, indices=g.indices, n=np.int64(g.n),
             directed=np.int8(g.directed))
    os.replace(tmp + ".npz", path)


def load_binary(path: str, name: str = "graph") -> Graph:
    z = np.load(path)
    return Graph(indptr=z["indptr"], indices=z["indices"], n=int(z["n"]),
                 directed=bool(z["directed"]), name=name)


def csr_prefix(indptr: np.ndarray, indices: np.ndarray,
               k: int) -> tuple[np.ndarray, np.ndarray]:
    """First-``k``-neighbors CSR: a vertex-proportional adjacency sample.

    Returns ``(prefix_indptr, prefix_indices)`` where
    ``prefix_indptr[u] = u * k`` and ``prefix_indices[u*k + r]`` is the
    ``r``-th neighbor of ``u`` for ``r < degree(u)`` (zero-filled past
    the degree — callers must keep the ``r < degree`` guard they already
    need for the global CSR).  The streaming executor substitutes this
    for the full adjacency during ``edge_free_iterations`` (e.g.
    Afforest's neighbor-sampling rounds), so those rounds cost
    ``n * k`` staged entries instead of keeping all ``m`` device-resident.
    """
    indptr = np.asarray(indptr, dtype=np.int64)
    n = indptr.shape[0] - 1
    k = int(k)
    if k <= 0 or n <= 0:
        return np.zeros(max(n + 1, 1), np.int64), np.zeros(0, np.int32)
    prefix_indptr = np.arange(n + 1, dtype=np.int64) * k
    m = int(indices.shape[0])
    pos = indptr[:-1, None] + np.arange(k, dtype=np.int64)[None, :]
    valid = np.arange(k, dtype=np.int64)[None, :] < np.diff(indptr)[:, None]
    if m:
        vals = np.asarray(indices)[np.clip(pos, 0, m - 1)]
    else:
        vals = np.zeros((n, k), np.int32)
    prefix_indices = np.where(valid, vals, 0).astype(np.int32).ravel()
    return prefix_indptr, prefix_indices


# ---------------------------------------------------------------------------
# synthetic generators (benchmark suite stand-ins for the paper's 44 graphs)


def rmat(scale: int, edge_factor: int = 16, *, a=0.57, b=0.19, c=0.19,
         seed: int = 0, name: str | None = None) -> Graph:
    """R-MAT / Kronecker generator (kron21-style skewed synthetic graph)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        src_bit = r >= ab
        # conditional column probability within chosen row half
        r2 = rng.random(m)
        dst_bit = np.where(src_bit, r2 >= (c / max(1e-12, 1.0 - ab)), r2 >= (b / max(1e-12, ab)))
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    # random vertex permutation to avoid locality artifacts
    perm = rng.permutation(n)
    return from_edges(perm[src], perm[dst], n=n, name=name or f"rmat{scale}")


def erdos_renyi(n: int, avg_degree: float = 8.0, *, seed: int = 0,
                name: str | None = None) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n=n, name=name or f"er{n}")


def grid_road(side: int, *, name: str | None = None) -> Graph:
    """2-D grid — a road-network (eu_osm-like) stand-in: huge diameter, degree≤4."""
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).astype(np.int64)
    right = np.stack([vid[:, :-1].ravel(), vid[:, 1:].ravel()], 1)
    down = np.stack([vid[:-1, :].ravel(), vid[1:, :].ravel()], 1)
    e = np.concatenate([right, down])
    return from_edges(e[:, 0], e[:, 1], n=n, name=name or f"road{side}x{side}")


def star_skew(n: int, hubs: int = 4, *, seed: int = 0, name: str | None = None) -> Graph:
    """Extreme-skew graph (twitter7-like): a few hubs connected to everyone."""
    rng = np.random.default_rng(seed)
    hub_ids = rng.choice(n, hubs, replace=False)
    src = np.repeat(hub_ids, n // hubs)
    dst = rng.integers(0, n, src.shape[0])
    extra_s = rng.integers(0, n, n)
    extra_d = rng.integers(0, n, n)
    return from_edges(np.concatenate([src, extra_s]), np.concatenate([dst, extra_d]),
                      n=n, name=name or f"star{n}")


def degree_order(g: Graph, *, ascending: bool = True) -> tuple[Graph, np.ndarray]:
    """Relabel vertices by degree (paper §5.4 enables degree ordering for TC).

    Returns the relabeled graph and the permutation ``perm`` with
    ``new_id = perm[old_id]``.
    """
    order = np.argsort(g.degrees, kind="stable")
    if not ascending:
        order = order[::-1]
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    src, dst = g.coo()
    return from_edges(perm[src], perm[dst], n=g.n, symmetrize=not g.directed,
                      name=g.name + "+deg"), perm
