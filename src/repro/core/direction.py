"""Per-iteration push/pull direction optimization (GraphBLAST/GraphIt
style direction switching over PGAbB's block kernels).

Frontier algorithms default to a *push* step: every active vertex
scatters along its out-edges.  On scale-free graphs the frontier
quickly covers a large fraction of the vertices, and a *pull* step —
every still-undecided vertex gathers from its in-neighbors and stops at
the first hit — touches far fewer edges.  An algorithm opts in by
declaring both kernel variants plus a ``metadata["direction"]``
capability::

    BlockAlgorithm(
        ...,
        kernel_sparse=push_scatter,
        kernel_sparse_pull=pull_gather,         # same signature/contract
        kernel_dense=push_tiles,                # optional; if present,
        kernel_dense_pull=pull_tiles,           # the pull twin is required
        metadata=dict(
            ...,
            direction=dict(frontier="nf", beta=24.0),
        ),
    )

``frontier`` names the state leaf the executor reads to judge frontier
density (a bool mask, a scalar active-count, or a batched count
vector); ``beta`` is the Beamer-style cost ratio.  The contract every
pull variant must honor: **bit-identical results to the push variant
for integer/bool attributes from the same iteration-start state**, on
any sub-partition of the edges (waves, mesh shards, the host lane) —
the executor freely substitutes one for the other per iteration, never
mixing directions within an iteration.

Decision rule (:class:`DirectionController`, deterministic, host-side,
hysteresis band like the hetero split / tail rebalancer):

* in push, switch to pull when ``count * beta > population``;
* in pull, switch back when ``count * beta < population * hysteresis``
  (default 0.75);
* inside the band, hold the current direction — a frontier hovering at
  the threshold cannot flap.

``REPRO_DIRECTION_BETA`` / ``REPRO_DIRECTION_HYSTERESIS`` override the
knobs; every decision lands in ``schedule_stats["direction"]`` and each
flip increments the ``stream.direction_switches`` counter and drops an
instant on the ``direction`` tracer lane.  See
``docs/performance.md`` ("Direction optimization") for tuning and
``docs/writing-algorithms.md`` for the authoring contract.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import numpy as np

from .. import obs
from .knobs import env_float as _env_float

if TYPE_CHECKING:  # pragma: no cover — typing only, avoids an import cycle
    from .functors import BlockAlgorithm

__all__ = [
    "DIRECTIONS", "BETA_DEFAULT", "HYSTERESIS_DEFAULT",
    "direction_spec", "resolve_direction", "kernels_for",
    "workspace_kernels", "DirectionController",
]

#: Valid ``compile_plan(..., direction=...)`` values.  ``None`` keeps
#: the pre-direction behavior (plain push, single compiled step).
DIRECTIONS = ("push", "pull", "auto")

#: Beamer-style cost ratio: pull wins once the frontier holds more than
#: ``population / beta`` active vertices (direction-optimizing BFS uses
#: edge counts with alpha≈14; at PGAbB's block granularity a vertex
#: ratio with beta≈24 lands the switch in the same place on R-MAT).
BETA_DEFAULT = 24.0

#: Re-arm fraction of the switch threshold: once in pull, the frontier
#: must shrink below ``hysteresis`` × the threshold before the
#: controller returns to push.  The band keeps a frontier hovering at
#: the threshold from flapping (and re-tracing nothing — both variants
#: are compiled — but flip-flopping decision logs and caches).
HYSTERESIS_DEFAULT = 0.75


def direction_spec(alg: "BlockAlgorithm") -> dict | None:
    """Validated ``metadata["direction"]`` capability, or ``None``.

    A capable algorithm must name the frontier leaf and ship a pull
    twin for every declared push kernel — otherwise an auto/pull run
    would silently skip the work the missing variant covers.
    """
    spec = alg.metadata.get("direction")
    if spec is None:
        return None
    if not isinstance(spec, dict) or not spec.get("frontier"):
        raise ValueError(
            f"{alg.name}: metadata['direction'] must be a dict naming the "
            f"frontier state leaf, e.g. dict(frontier='nf', beta=24.0); "
            f"got {spec!r}"
        )
    if alg.kernel_sparse is not None and alg.kernel_sparse_pull is None:
        raise ValueError(
            f"{alg.name}: metadata['direction'] is declared but "
            f"kernel_sparse has no kernel_sparse_pull twin — a pull "
            f"iteration would drop the sparse path's work"
        )
    if alg.kernel_dense is not None and alg.kernel_dense_pull is None:
        raise ValueError(
            f"{alg.name}: metadata['direction'] is declared but "
            f"kernel_dense has no kernel_dense_pull twin — a pull "
            f"iteration would leave the dense-routed edges unprocessed"
        )
    return spec


def resolve_direction(alg: "BlockAlgorithm",
                      direction: str | None) -> str:
    """Validate a ``compile_plan`` direction request against ``alg``.

    ``None`` → ``"push"`` (the pre-direction default; only the push
    step is built and traced).  ``"pull"``/``"auto"`` require the
    algorithm to declare the capability.
    """
    if direction is None:
        return "push"
    if direction not in DIRECTIONS:
        raise ValueError(
            f"direction must be one of {DIRECTIONS} (or None); "
            f"got {direction!r}"
        )
    if direction != "push" and direction_spec(alg) is None:
        raise ValueError(
            f"{alg.name} declares no metadata['direction'] capability; "
            f"direction={direction!r} requires push and pull kernel "
            f"variants (see docs/writing-algorithms.md)"
        )
    return direction


def kernels_for(alg: "BlockAlgorithm", direction: str):
    """The (sparse, dense) kernel pair for one direction."""
    if direction == "pull":
        return alg.kernel_sparse_pull, alg.kernel_dense_pull
    return alg.kernel_sparse, alg.kernel_dense


def workspace_kernels(alg: "BlockAlgorithm",
                      direction: str | None) -> "str | tuple | None":
    """Workspace-estimator name(s) to price a plan's dense scratch.

    Fixed directions price their own variant
    (``metadata["workspace_kernel"]`` for push,
    ``metadata["workspace_kernel_pull"]`` for pull); ``"auto"`` prices
    the max over both, so a mid-stream switch can never exceed a budget
    the planner already verified.
    """
    push = alg.metadata.get("workspace_kernel")
    if direction in (None, "push"):
        return push
    pull = alg.metadata.get("workspace_kernel_pull", push)
    if direction == "pull":
        return pull
    names = tuple(dict.fromkeys(k for k in (push, pull) if k is not None))
    if not names:
        return None
    return names[0] if len(names) == 1 else names


def frontier_count(state, leaf: str, n: int) -> tuple[float, float]:
    """(active count, population) read from the frontier leaf.

    Bool leaves are per-vertex masks: count = popcount, population =
    the mask size.  Numeric leaves are active-vertex counts (scalar, or
    a batched per-query vector): count = their sum, population = ``n``
    per query.  Either way ``count/population`` is the frontier density
    the decision rule compares against ``1/beta``.
    """
    if leaf not in state:
        raise KeyError(
            f"direction frontier leaf {leaf!r} is missing from the state "
            f"(have {sorted(state)})"
        )
    a = np.asarray(jax.device_get(state[leaf]))
    if a.dtype == np.bool_:
        return float(a.sum()), float(max(a.size, 1))
    return float(a.sum()), float(n * max(a.size, 1))


class DirectionController:
    """Deterministic per-iteration push/pull decisions with hysteresis.

    One instance per ``run()`` — decisions and the switch count reset
    with the run, never leak across runs of a shared plan.  The
    decision depends only on the frontier-density trace (and the two
    knobs), so replaying a trace replays the decisions exactly — the
    property the Hypothesis harness pins down.
    """

    def __init__(self, alg: "BlockAlgorithm", mode: str, n: int) -> None:
        spec = direction_spec(alg) if mode != "push" else None
        spec = spec or {}
        self.mode = mode
        self.frontier = spec.get("frontier")
        self.beta = _env_float("REPRO_DIRECTION_BETA",
                               float(spec.get("beta", BETA_DEFAULT)))
        self.hysteresis = _env_float("REPRO_DIRECTION_HYSTERESIS",
                                     float(spec.get("hysteresis",
                                                    HYSTERESIS_DEFAULT)))
        if self.beta <= 0:
            raise ValueError(f"direction beta must be > 0; got {self.beta}")
        if not 0 < self.hysteresis <= 1:
            raise ValueError(
                f"direction hysteresis must be in (0, 1]; "
                f"got {self.hysteresis}"
            )
        self.n = int(n)
        self.current = "push"
        self.switches = 0
        self.decisions: list[str] = []
        self.densities: list[float] = []

    def decide_density(self, count: float, population: float) -> str:
        """Pure decision rule (also the unit-test surface): density
        above ``1/beta`` → pull; below ``hysteresis/beta`` → push;
        in between → hold."""
        if self.mode in ("push", "pull"):
            return self.mode
        score = count * self.beta
        if self.current == "push":
            return "pull" if score > population else "push"
        return "push" if score < population * self.hysteresis else "pull"

    def decide(self, state, it: int) -> str:
        """Decide iteration ``it``'s direction from iteration-start
        state; records the decision, density, and any switch."""
        if self.mode in ("push", "pull"):
            d, density = self.mode, float("nan")
        else:
            cnt, pop = frontier_count(state, self.frontier, self.n)
            d = self.decide_density(cnt, pop)
            density = cnt / pop if pop else 0.0
        if self.decisions and d != self.current:
            self.switches += 1
            obs.metrics.counter("stream.direction_switches").inc()
            obs.instant("direction_switch", lane="direction",
                        it=it, to=d, density=density)
        self.current = d
        self.decisions.append(d)
        self.densities.append(density)
        return d

    def stats(self) -> dict:
        """The ``schedule_stats["direction"]`` block."""
        return dict(
            mode=self.mode,
            beta=self.beta,
            hysteresis=self.hysteresis,
            decisions=list(self.decisions),
            switches=self.switches,
            pull_iterations=sum(d == "pull" for d in self.decisions),
            densities=list(self.densities),
        )
