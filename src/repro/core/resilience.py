"""Graceful-degradation policies for the streaming executor.

The executor's recovery contract rests on the ``metadata["combine"]``
idempotence guarantee: every iteration folds partials from the
iteration-start state, so an iteration that dies anywhere can be
re-run wholesale without double-counting.  :class:`RetryPolicy` bounds
how many times and decides the *ladder* each failure class climbs:

* **generic fault** (injected or transient) → retry the iteration;
* **device OOM** → retry under an exponentially shrunk effective
  budget (re-packing waves via ``membudget.repack_waves`` — the
  per-task bound is never relaxed), then demote the offending wave's
  tasks to the host lane;
* **staging-worker death** → fail over to synchronous assembly
  (``pipeline_depth=0`` semantics) for the retried iteration, then
  permanently if the worker keeps dying;
* **host-lane failure** → retry, then run device-only
  (``host_fraction=0``).

Every action increments a counter in :class:`ResilienceStats`, which
renders the ``schedule_stats["resilience"]`` block (emitted only when
faults/checkpointing are configured or a recovery actually fired, so
existing callers see unchanged keys).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .faults import InjectedFault, InjectedOOM

__all__ = [
    "RetryPolicy", "ResilienceStats", "HostTaskError", "WorkerDeath",
    "is_oom",
]


class HostTaskError(RuntimeError):
    """A host-lane task failed; carries unit/task/iteration context so
    the failure surfaces with its blame attached instead of as a bare
    future exception reaped at fold time."""

    def __init__(self, unit: int, tasks, it: int, cause: BaseException):
        super().__init__(
            f"host-lane unit {unit} (tasks {list(tasks)[:8]}"
            f"{'...' if len(tasks) > 8 else ''}, iteration {it}) failed: "
            f"{type(cause).__name__}: {cause}")
        self.unit = unit
        self.it = it


class WorkerDeath(RuntimeError):
    """The staging worker thread died; wraps its stored exception."""

    def __init__(self, cause: BaseException):
        super().__init__(
            f"staging worker died: {type(cause).__name__}: {cause}")
        self.cause = cause


def is_oom(exc: BaseException) -> bool:
    """Does ``exc`` look like device memory exhaustion?

    Covers injected OOMs, host ``MemoryError``, and XLA's
    RESOURCE_EXHAUSTED / out-of-memory runtime errors (matched by
    message so no jaxlib-version-specific exception import is needed).
    """
    if isinstance(exc, (InjectedOOM, MemoryError)):
        return True
    msg = str(exc).lower()
    if "resource_exhausted" in msg or "resource exhausted" in msg:
        return True
    return "out of memory" in msg and type(exc).__name__ in (
        "XlaRuntimeError", "RuntimeError", "InternalError")


@dataclass(frozen=True)
class RetryPolicy:
    """Bounds and shape of the recovery ladder.

    ``max_retries`` caps recovery attempts per iteration;
    ``backoff`` is the per-OOM effective-budget shrink factor
    (attempt *i* packs waves under ``budget × backoff**i``);
    ``demote_after`` OOMs on one iteration demote the offending wave
    to the host lane; ``failover_after`` staging-worker deaths make
    synchronous assembly permanent.
    """

    max_retries: int = 3
    backoff: float = 0.5
    demote_after: int = 2
    failover_after: int = 2

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0 < self.backoff < 1:
            raise ValueError(
                f"backoff must be in (0, 1); got {self.backoff}")


@dataclass
class ResilienceStats:
    """Counters behind ``schedule_stats["resilience"]``."""

    injected: int = 0
    detected: int = 0
    retries: int = 0
    demotions: int = 0
    failovers: int = 0
    host_failovers: int = 0
    oom_repacks: int = 0
    checkpoints: int = 0
    actions: list = field(default_factory=list)

    @property
    def fired(self) -> bool:
        return self.detected > 0 or self.checkpoints > 0

    def record(self, action: str, **ctx) -> None:
        self.actions.append(dict(action=action, **ctx))

    def snapshot(self, faults=None) -> dict:
        out = dict(
            injected=(faults.injected if faults is not None
                      else self.injected),
            detected=self.detected,
            retries=self.retries,
            demotions=self.demotions,
            failovers=self.failovers,
            host_failovers=self.host_failovers,
            oom_repacks=self.oom_repacks,
            checkpoints=self.checkpoints,
            actions=list(self.actions),
        )
        if faults is not None:
            out["fault_rules"] = faults.stats()["rules"]
        return out


def classify(exc: BaseException) -> str:
    """Failure class for the ladder: ``oom`` | ``worker`` | ``host`` |
    ``fault`` (anything else retryable)."""
    if is_oom(exc):
        return "oom"
    if isinstance(exc, WorkerDeath):
        return "worker"
    if isinstance(exc, HostTaskError):
        return "host"
    if isinstance(exc, InjectedFault):
        return "fault"
    return "fault"
