"""The six-functor algorithm specification (paper §3, Listing 1).

A ``BlockAlgorithm`` is PGAbB's user contract translated to JAX:

=============== =================================================
paper functor    PGAbB-JAX field
=============== =================================================
``K_H``          ``kernel_sparse(ctx, state, it) -> state``  (VPU path)
``K_D``          ``kernel_dense(ctx, state, it) -> state``   (MXU path)
``P_C``/``P_G``  ``make_blocklists(store) -> np.ndarray``  /
                 ``blocklist_predicate(store, blocklist) -> bool``
``I_B``          ``before(host, state, it) -> state``   (host side)
``I_A``          ``after(host, state, it) -> (state, bool)`` — iterate while True
``E``            ``estimate(store, blocklist) -> float``
=============== =================================================

At least one kernel must be provided (paper: "One of them has to be
written").  ``state`` is a pytree of global/vertex/edge attributes
(paper: A_G / A_V / A_E) — jnp arrays inside the jitted step, numpy at
the host boundary.  ``mode`` declares the paper's execution-mode
classification and drives block-list composition defaults.

Kernels receive a typed :class:`~repro.core.context.Context` (device
arrays, static scalars, and the algorithm's ``prepare`` outputs under
``ctx.extras``); the host hooks ``before``/``after`` receive a
:class:`~repro.core.context.HostCtx` (store, schedule, scalars) — host
objects never enter the jitted step.

Iteration contract (enforced by :meth:`repro.core.engine.Plan.run`):
``I_B`` → step → ``I_A``, repeated.  When ``after`` is provided, the
loop continues while it returns ``True``, bounded by
``max_iterations``.  When ``after`` is *absent*, the loop runs exactly
``max_iterations`` iterations (default 1) — it is NOT cut short at one.

``metadata`` keys the framework reads (full contract in
``docs/writing-algorithms.md``):

``params``
    trace-affecting factory parameters — the compiled-step cache keys
    on ``(name, params, backend)``.
``combine``
    per-leaf fold kind (``add``/``min``/``max``) for streamed per-wave
    partials; required for any leaf the kernels modify when running
    under ``memory_budget``.
``csr``
    ``"slice"`` (wave-staged conformal CSR row slices) | ``"none"``
    (kernels never read ``ctx.indices``) | ``"resident"`` (default:
    full CSR stays on device — unbounded by the budget).
``workspace_kernel``
    registry kernel naming the dense path's scratch estimator.
``edge_free_iterations``
    first ``k`` iterations read at most each vertex's first ``k``
    neighbors — streamed against the prefix CSR.
``mesh``
    ``"shard"`` opts in to mesh-cooperative streaming
    (``compile_plan(..., memory_budget=..., mesh=...)``): the kernels
    must be decomposable over any partition of a wave's tasks judged
    from iteration-start state (the same property per-wave folding
    relies on), and ``prepare`` must be restrictable to a device-local
    view of the wave.  Absent (the default), passing a mesh raises —
    a custom algorithm must not silently run under collectives whose
    semantics it never declared.  See ``docs/distributed.md``.
``host``
    host-lane capability for heterogeneous co-scheduling
    (``compile_plan(..., host_fraction=...)``): ``"auto"`` (default —
    eligible when ``kernel_sparse`` exists and every name in
    ``host_kernels`` is registry-certified host-executable) or
    ``"never"`` (tasks are never peeled to the CPU; an explicit
    nonzero ``host_fraction`` then raises).
``host_kernels``
    registry kernel names the sparse kernel dispatches to — each must
    pass :func:`repro.kernels.registry.host_executable` for the host
    lane to engage.  Pure-``jnp`` sparse kernels (every shipped
    algorithm) leave it empty.  See ``docs/heterogeneous.md``.
``direction``
    push/pull capability: ``dict(frontier=<state leaf>, beta=...)``
    together with the ``kernel_sparse_pull``/``kernel_dense_pull``
    twins enables ``compile_plan(..., direction="pull" | "auto")`` —
    per-iteration direction optimization (:mod:`repro.core.direction`).
``workspace_kernel_pull``
    workspace estimator for the pull dense path when it differs from
    the push one; ``"auto"`` plans price the max over both variants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

__all__ = ["BlockAlgorithm", "Mode", "default_estimate"]


class Mode:
    BULK = "single_block_bulk_synchronous"
    ACTIVATION = "activation_based"
    PATTERN = "multi_block_pattern_based"


def default_estimate(store, blocklist: np.ndarray) -> float:
    """Paper default E: total number of edges within the block-list."""
    bl = np.atleast_1d(np.asarray(blocklist, dtype=np.int64))
    return float(
        np.sum(store.block_ptr[bl + 1] - store.block_ptr[bl])
    )


@dataclass
class BlockAlgorithm:
    name: str
    mode: str = Mode.BULK
    # kernels — at least one required
    kernel_sparse: Callable[..., Any] | None = None   # K_H analog
    kernel_dense: Callable[..., Any] | None = None    # K_D analog
    # pull-direction twins (same signature/contract), read only when
    # metadata["direction"] declares the capability: a pull variant must
    # produce bit-identical int/bool results to its push twin from the
    # same iteration-start state on any edge sub-partition — the
    # executor substitutes one for the other per iteration (see
    # repro.core.direction and docs/writing-algorithms.md)
    kernel_sparse_pull: Callable[..., Any] | None = None
    kernel_dense_pull: Callable[..., Any] | None = None
    # block-list composition — P_C (explicit) or P_G (predicate)
    make_blocklists: Callable[..., np.ndarray] | None = None
    blocklist_predicate: Callable[..., bool] | None = None
    blocklist_size: int = 1
    # iteration control
    before: Callable[..., Any] | None = None          # I_B
    after: Callable[..., Any] | None = None           # I_A (required for iterative)
    max_iterations: int = 1
    # scheduling
    estimate: Callable[..., float] = default_estimate  # E
    # post-path combine, runs inside the jitted step after both kernels
    # (e.g. PageRank applies damping once both paths accumulated)
    post: Callable[..., Any] | None = None
    # one-time extras preparation: (store, schedule) -> dict placed on
    # Context.extras (bucketed item arrays, tile index maps, ...).
    # jax/numpy array leaves are traced; everything else stays static.
    # When ``stage_plan`` is set, prepare is called with a third
    # positional argument: the plan-wide staging plan (see below).
    prepare: Callable[..., dict] | None = None
    # optional cross-wave staging plan: (store, schedule) -> Any, called
    # ONCE per *streaming* plan with the FULL store and schedule,
    # before any (wave- or device-restricted) ``prepare``.  Its result
    # is handed to every prepare call so shape-driving decisions — TC's
    # dp/steps bucket ladder — are made once for the whole plan instead
    # of per wave, keeping every wave's extras structurally identical
    # (one jit trace per distinct bucket shape, not one per wave).  The
    # in-core Plan passes ``plan=None`` instead: a single context needs
    # no shape stabilization, so prepare keeps its unpadded form there.
    stage_plan: Callable[..., Any] | None = None
    # mesh-cooperative streaming only: pack the per-device ``prepare``
    # outputs of one wave into a single extras tree whose array leaves
    # carry a leading device axis (sharded over the mesh; the leading
    # axis is stripped inside each shard) and whose non-array leaves
    # are device-invariant.  Required when per-device prepare outputs
    # differ in *structure* (TC's data-dependent bucket ladder); when
    # None, the executor stacks structurally identical outputs itself.
    # Padding must be neutral for the kernels — the framework cannot
    # know which sentinel is harmless.
    mesh_pack: Callable[..., dict] | None = None
    # initial attribute state factory: (store) -> pytree
    init_state: Callable[..., Any] | None = None
    # extract final result: (store, state) -> anything
    finalize: Callable[..., Any] | None = None
    # free-form; factories record trace-affecting parameters under
    # metadata["params"] so compiled steps are cached per (name, params)
    metadata: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kernel_sparse is None and self.kernel_dense is None:
            raise ValueError(
                f"{self.name}: at least one of kernel_sparse/kernel_dense is required"
            )

    def run_prepare(self, store, sched, plan: Any = None) -> dict:
        """Invoke ``prepare`` with the staging plan when one is declared.

        Algorithms without ``stage_plan`` keep the two-argument prepare
        contract unchanged; algorithms with one always receive the plan
        (``None`` only when a caller skipped :attr:`stage_plan` — e.g.
        ad-hoc use outside an executor)."""
        if self.prepare is None:
            return {}
        if self.stage_plan is not None:
            return self.prepare(store, sched, plan)
        return self.prepare(store, sched)

    def compose_blocklists(self, store) -> np.ndarray:
        """Run P_C, or enumerate + filter with P_G (paper §3)."""
        if self.make_blocklists is not None:
            bls = np.asarray(self.make_blocklists(store))
        else:
            nb = store.layout.num_blocks
            if self.blocklist_size == 1:
                cand = np.arange(nb, dtype=np.int64)[:, None]
            else:
                grids = np.meshgrid(
                    *[np.arange(nb, dtype=np.int64)] * self.blocklist_size,
                    indexing="ij",
                )
                cand = np.stack([x.ravel() for x in grids], axis=1)
            if self.blocklist_predicate is not None:
                keep = np.fromiter(
                    (self.blocklist_predicate(store, row) for row in cand),
                    dtype=bool,
                    count=cand.shape[0],
                )
                cand = cand[keep]
            bls = cand
        if bls.ndim == 1:
            bls = bls[:, None]
        return bls.astype(np.int64)
