"""Validated ``REPRO_*`` environment knobs — one loud front door.

Every runtime tuning knob the framework reads from the environment goes
through this module.  Two properties the scattered ``os.environ.get``
reads it replaces did not have:

* **Malformed values fail loudly.**  ``REPRO_DIRECTION_BETA=fast``
  raises ``ValueError: REPRO_DIRECTION_BETA='fast' is not a valid
  float`` at the read site instead of silently falling back to a
  default (or crashing later with a bare ``float()`` traceback that
  never names the knob).
* **Unknown names fail at the call site.**  Reading a knob that is not
  in the :data:`KNOWN` registry is a programming error — it means a new
  knob was added without documenting it here, defeating the point of
  centralizing.  The registry doubles as the single inventory a reader
  (or ``docs/resilience.md``) can consult.

The helpers deliberately import nothing beyond ``os`` so benchmarks can
defer importing :mod:`repro.core` (which pulls in jax) until after
``XLA_FLAGS`` is set — callers that need that ordering import this
module lazily inside function bodies.
"""
from __future__ import annotations

import os

__all__ = ["KNOWN", "env_float", "env_int", "env_flag", "env_str"]

#: Every environment knob the framework reads, with a one-line meaning.
#: Reading an undeclared name raises — add the knob here (and to the
#: docs) before using it.
KNOWN: dict[str, str] = {
    "REPRO_FAULTS": "fault-injection spec string (see repro.core.faults)",
    "REPRO_CHAOS_WALL_RATIO":
        "chaos smoke gate: faulted wall / fault-free wall upper bound",
    "REPRO_DIRECTION_BETA": "direction-switch cost ratio override",
    "REPRO_DIRECTION_HYSTERESIS": "direction re-arm band override",
    "REPRO_HETERO_NOISE_FLOOR_S":
        "hetero split refresh noise floor (seconds)",
    "REPRO_HETERO_HOST_RATIO": "device/host throughput ratio prior",
    "REPRO_SMOKE_OVERLAP_FLOOR": "perf smoke: staging overlap floor",
    "REPRO_HETERO_WALL_RATIO": "hetero smoke: wall-ratio gate",
    "REPRO_DIRECTION_WALL_RATIO": "direction smoke: wall-ratio gate",
    "REPRO_SMOKE_OVERHEAD_RATIO": "serve smoke: batching overhead gate",
    "REPRO_TRACE": "tracer sink path (enables span/instant capture)",
    "REPRO_TRACE_JAX": "mirror jax profiler annotations onto spans",
}


def _raw(name: str) -> str | None:
    if name not in KNOWN:
        raise KeyError(
            f"unknown knob {name!r}: declare it in repro.core.knobs.KNOWN "
            "(and document it) before reading it")
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return None
    return raw.strip()


def env_float(name: str, default: float) -> float:
    """``float(os.environ[name])`` with loud validation."""
    raw = _raw(name)
    if raw is None:
        return float(default)
    try:
        return float(raw)
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not a valid float") from None


def env_int(name: str, default: int) -> int:
    raw = _raw(name)
    if raw is None:
        return int(default)
    try:
        return int(raw)
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not a valid integer") from None


def env_flag(name: str, default: bool = False) -> bool:
    """Boolean knob: 1/true/yes/on vs 0/false/no/off (case-insensitive)."""
    raw = _raw(name)
    if raw is None:
        return bool(default)
    low = raw.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(
        f"{name}={raw!r} is not a valid flag "
        "(use 1/true/yes/on or 0/false/no/off)")


def env_str(name: str, default: str | None = None) -> str | None:
    raw = _raw(name)
    return default if raw is None else raw
