"""PGAbB-JAX core: blocks, block-lists, functors, scheduler, engine.

This package is the paper's primary contribution rebuilt in JAX:
the block-based programming model (graph → conformal 2-D blocks →
block-lists → tasks), the six-functor user API, and the
heterogeneity-aware scheduler (dense/MXU vs sparse/VPU paths, LPT
device packing).
"""
from .graph import (
    Graph,
    from_edges,
    read_edge_list,
    load_binary,
    save_binary,
    rmat,
    erdos_renyi,
    grid_road,
    star_skew,
    degree_order,
)
from .partition import Layout, partition_1d, partition_symmetric_2d, make_layout
from .blocks import BlockStore, build_block_store
from .functors import BlockAlgorithm, Mode, default_estimate
from .scheduler import Schedule, build_schedule, lpt_assign
from .engine import Engine, run

__all__ = [
    "Graph", "from_edges", "read_edge_list", "load_binary", "save_binary",
    "rmat", "erdos_renyi", "grid_road", "star_skew", "degree_order",
    "Layout", "partition_1d", "partition_symmetric_2d", "make_layout",
    "BlockStore", "build_block_store",
    "BlockAlgorithm", "Mode", "default_estimate",
    "Schedule", "build_schedule", "lpt_assign",
    "Engine", "run",
]
