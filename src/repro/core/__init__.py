"""PGAbB-JAX core: blocks, block-lists, functors, scheduler, plans.

This package is the paper's primary contribution rebuilt in JAX: the
block-based programming model (graph → conformal 2-D blocks →
block-lists → tasks), the six-functor user API, and the
heterogeneity-aware scheduler (dense/MXU vs sparse/VPU paths, LPT
device packing).

The execution API separates **build/compile** from **execute**::

    from repro.core import rmat, build_block_store, compile_plan
    from repro.algorithms import pagerank_algorithm

    store = build_block_store(rmat(12, 8, seed=7), 4)
    plan = compile_plan(pagerank_algorithm(), store, backend="xla")
    ranks = plan.run().result          # execute (reusable)
    plan.schedule.stats                # the schedule is inspectable
    plan.run(other_store)              # same shapes → no recompilation

:func:`compile_plan` composes block-lists, estimates and sorts tasks,
splits the dense/sparse paths, packs devices, runs the algorithm's
``prepare``, and jit-compiles the per-iteration step against a typed
:class:`~repro.core.context.Context` (device arrays + static scalars;
host objects live in :class:`~repro.core.context.HostCtx` and never
cross the jit boundary).  Kernel implementations are selected per
kernel from the backend registry (``"reference" | "xla" | "pallas"``).
The legacy :class:`~repro.core.engine.Engine` remains as a deprecated
shim over ``compile_plan``.
"""
from .graph import (
    Graph,
    from_edges,
    read_edge_list,
    load_binary,
    save_binary,
    rmat,
    erdos_renyi,
    grid_road,
    star_skew,
    degree_order,
    csr_prefix,
)
from .partition import (
    Layout, partition_1d, partition_symmetric_2d, make_layout, choose_p,
)
from .blocks import BlockStore, build_block_store
from .functors import BlockAlgorithm, Mode, default_estimate
from .scheduler import Schedule, build_schedule, lpt_assign
from .context import Context, HostCtx, build_context, build_host_ctx
from .direction import (
    DIRECTIONS, DirectionController, direction_spec, resolve_direction,
)
from .engine import (
    Plan, compile_plan, RunResult, Engine, run, batch_states, unbatch_state,
)
from .membudget import (
    MemoryBudget, PIPELINE_DEPTH, arena_model_bytes, task_footprints,
    task_csr_edge_counts, build_waves, repack_waves, TenantLedger,
    batch_state_bytes,
)
from .stream import StreamingPlan, compile_streaming_plan
from .distributed import (
    DistributedEngine, combine_fn, make_device_edge_partition,
)
from .faults import FaultPlan, InjectedFault, InjectedOOM
from .resilience import (
    HostTaskError, ResilienceStats, RetryPolicy, WorkerDeath,
)
from .knobs import env_flag, env_float, env_int, env_str

__all__ = [
    "Graph", "from_edges", "read_edge_list", "load_binary", "save_binary",
    "rmat", "erdos_renyi", "grid_road", "star_skew", "degree_order",
    "csr_prefix",
    "Layout", "partition_1d", "partition_symmetric_2d", "make_layout",
    "choose_p",
    "BlockStore", "build_block_store",
    "BlockAlgorithm", "Mode", "default_estimate",
    "Schedule", "build_schedule", "lpt_assign",
    "Context", "HostCtx", "build_context", "build_host_ctx",
    "DIRECTIONS", "DirectionController", "direction_spec",
    "resolve_direction",
    "Plan", "compile_plan", "RunResult", "batch_states", "unbatch_state",
    "MemoryBudget", "PIPELINE_DEPTH", "arena_model_bytes",
    "task_footprints", "task_csr_edge_counts",
    "build_waves", "repack_waves", "TenantLedger", "batch_state_bytes",
    "StreamingPlan", "compile_streaming_plan",
    "DistributedEngine", "combine_fn", "make_device_edge_partition",
    "FaultPlan", "InjectedFault", "InjectedOOM",
    "HostTaskError", "ResilienceStats", "RetryPolicy", "WorkerDeath",
    "env_flag", "env_float", "env_int", "env_str",
    "Engine", "run",
]
