"""Device-memory footprint model + budget-sized wave packing (paper §4.3/§4.4).

PGAbB's headline claim is that a task only ever needs the blocks of ONE
block-list resident on the throughput device, so graphs that fit host
DRAM but not accelerator memory still run.  This module is the pricing
half of that subsystem: it puts a byte cost on every schedule task and
packs the LPT-ordered tasks into *waves* whose staged working set fits
an explicit ``memory_budget``.  The execution half (double-buffered
staging, partial-result combination) lives in :mod:`repro.core.stream`.

Footprint model
---------------
A task's streamed working set prices three components:

* **COO slice** — the segmented-COO slab entries of every block in the
  task's block-list: ``src``/``dst``/``edge_block`` (int32) plus the two
  edge routing masks (bool) → :data:`COO_EDGE_BYTES` per edge.
* **Dense tiles** — for MXU-path tasks, one ``tile_dim × tile_dim``
  float32 bitmap per distinct block, plus the two int64 tile-origin
  scalars (:func:`tile_bytes`).  Tiles shared by several tasks of one
  wave are staged once; the per-task price is therefore an upper bound
  and the wave builder re-prices the union.
* **Kernel workspace** — per-kernel scratch estimates from the backend
  registry (:func:`repro.kernels.registry.workspace_bytes`), e.g. the
  gathered ``xs``/``ys`` slices of ``spmv_tiles``.

Vertex-level attribute arrays (state pytree, ``degrees``, ``indptr``,
``row_block_ptr``) and — for now — the global CSR ``indices`` stay
*resident* across waves; :func:`resident_bytes` prices them so callers
can see the full device picture.  Streaming the CSR row slices as well
is an open item (see ROADMAP).

Wave packing pads every wave's edge slab to one of a few fixed bucket
shapes (:func:`bucket_size`, a power-of-two ladder) so a single jitted
step serves all waves without retracing.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from .blocks import BlockStore
from .scheduler import Schedule

__all__ = [
    "MemoryBudget", "parse_bytes", "COO_EDGE_BYTES", "TILE_HEADER_BYTES",
    "bucket_size", "task_edge_counts", "task_footprints", "tile_bytes",
    "resident_bytes", "tree_array_bytes", "Wave", "build_waves",
]

# src + dst + edge_block (int32) + sparse/dense edge masks (bool).
COO_EDGE_BYTES = 4 + 4 + 4 + 1 + 1
# per-tile origin scalars: tile_row_start + tile_col_start (int64).
TILE_HEADER_BYTES = 8 + 8

_UNITS = {"b": 1, "kb": 10**3, "mb": 10**6, "gb": 10**9,
          "kib": 2**10, "mib": 2**20, "gib": 2**30}


def parse_bytes(budget: int | float | str) -> int:
    """``8_000_000``, ``"64MB"``, ``"512KiB"`` → bytes (int)."""
    if isinstance(budget, (int, float, np.integer, np.floating)):
        return int(budget)
    m = re.fullmatch(r"\s*([0-9.]+)\s*([kKmMgG]i?[bB]|[bB])?\s*", str(budget))
    if not m:
        raise ValueError(f"cannot parse memory budget {budget!r}")
    scale = _UNITS[(m.group(2) or "b").lower()]
    return int(float(m.group(1)) * scale)


@dataclass(frozen=True)
class MemoryBudget:
    """An explicit device-memory budget for streamed task working sets."""

    total_bytes: int

    def __post_init__(self) -> None:
        if self.total_bytes <= 0:
            raise ValueError("memory budget must be positive")

    @classmethod
    def of(cls, budget: "int | str | MemoryBudget") -> "MemoryBudget":
        if isinstance(budget, MemoryBudget):
            return budget
        return cls(parse_bytes(budget))


def bucket_size(k: int, *, minimum: int = 8) -> int:
    """Smallest power-of-two ≥ ``k`` — the fixed bucket ladder that keeps
    the number of distinct wave-slab shapes (and therefore jit retraces)
    logarithmic in the largest wave."""
    k = max(int(k), minimum)
    return 1 << int(np.ceil(np.log2(k)))


def tile_bytes(tile_dim: int) -> int:
    """Staged bytes for one dense bitmap tile."""
    return tile_dim * tile_dim * 4 + TILE_HEADER_BYTES


def task_edge_counts(store: BlockStore, schedule: Schedule) -> np.ndarray:
    """(t,) edges across every block of each task's block-list."""
    bls = schedule.blocklists
    seg = np.diff(store.block_ptr)
    return seg[bls].sum(axis=1).astype(np.int64)


def task_footprints(store: BlockStore, schedule: Schedule, *,
                    workspace_kernel: str | None = None) -> np.ndarray:
    """(t,) bytes: the streamed working set of each task, per the model.

    COO slab + (dense tasks) bitmap tiles per distinct block + kernel
    workspace.  ``workspace_kernel`` names the registry kernel whose
    workspace estimator prices the dense path (algorithms declare it in
    ``metadata["workspace_kernel"]``); when unknown, the *maximum* over
    all registered estimators is charged — conservative by design.
    This is the scheduler-facing *estimate*; the wave builder verifies
    the assembled slabs against the budget and splits waves whose
    actual bytes (e.g. pattern-mode ``prepare`` items) exceed it.
    """
    from ..kernels.registry import (
        max_workspace_bytes, registered_workspaces, workspace_bytes,
    )

    if (workspace_kernel is not None
            and workspace_kernel not in registered_workspaces()):
        raise ValueError(
            f"workspace_kernel {workspace_kernel!r} has no registered "
            f"estimator (known: {sorted(registered_workspaces())}); a "
            f"typo here would silently under-price dense tasks"
        )
    edges = task_edge_counts(store, schedule)
    out = edges * COO_EDGE_BYTES
    if schedule.dense_task_mask.any():
        per_tile = tile_bytes(schedule.tile_dim)
        for t in np.nonzero(schedule.dense_task_mask)[0]:
            blocks = np.unique(schedule.blocklists[t])
            nd = int(blocks.size)
            out[t] += nd * per_tile
            if workspace_kernel is not None:
                out[t] += workspace_bytes(workspace_kernel, nd=nd,
                                          tile_dim=schedule.tile_dim)
            else:
                out[t] += max_workspace_bytes(nd=nd,
                                              tile_dim=schedule.tile_dim)
    return out.astype(np.int64)


def tree_array_bytes(tree) -> int:
    """Total bytes of the array leaves of a pytree (host or device);
    static leaves (ints, strings, ...) cost nothing."""
    import jax

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total




def resident_bytes(store: BlockStore, state=None) -> int:
    """Bytes that stay on device across every wave: vertex-level arrays,
    the conformal row map, the CSR adjacency (not yet streamed — see
    module docstring), and optionally the state pytree."""
    total = (
        store.indptr.nbytes
        + store.indices.nbytes
        + store.degrees.nbytes
        + store.row_block_ptr.nbytes
        + store.layout.cuts.nbytes
    )
    if state is not None:
        total += tree_array_bytes(state)
    return int(total)


# ----------------------------------------------------------------------
@dataclass
class Wave:
    """One budget-sized unit of streamed work.

    ``task_ids`` are indices into the schedule's task list, sorted by
    leading block id so the COO gather coalesces into few contiguous
    segments.  ``est_bytes`` is the model estimate used for packing;
    the staged slab's actual (bucket-padded) bytes are measured by the
    stream binder and recorded in ``schedule_stats``.
    """

    task_ids: np.ndarray
    est_bytes: int


def build_waves(store: BlockStore, schedule: Schedule,
                budget: MemoryBudget,
                footprints: np.ndarray | None = None) -> list[Wave]:
    """Greedily pack LPT-ordered tasks into waves under ``budget``.

    Walking tasks heaviest-first (the schedule's LPT order) keeps each
    wave's load balanced the same way device packing does; a wave closes
    when the next task would push its estimate past the budget.  Inside
    a wave, tasks are re-sorted by leading block id so their segmented
    COO slices coalesce.  A single task whose model footprint exceeds
    the budget is unrunnable — raise rather than silently oversubscribe.
    """
    if footprints is None:
        footprints = task_footprints(store, schedule)
    waves: list[Wave] = []
    cur: list[int] = []
    cur_bytes = 0
    for t in schedule.order:
        b = int(footprints[t])
        if b > budget.total_bytes:
            raise ValueError(
                f"task {int(t)} needs {b} bytes > budget "
                f"{budget.total_bytes}; raise memory_budget or shrink "
                f"tile_dim/blocks (p)"
            )
        if cur and cur_bytes + b > budget.total_bytes:
            waves.append(_close_wave(cur, cur_bytes, schedule))
            cur, cur_bytes = [], 0
        cur.append(int(t))
        cur_bytes += b
    if cur:
        waves.append(_close_wave(cur, cur_bytes, schedule))
    return waves


def _close_wave(task_ids: list[int], est_bytes: int,
                schedule: Schedule) -> Wave:
    ids = np.asarray(task_ids, dtype=np.int64)
    lead = schedule.blocklists[ids, 0]
    return Wave(task_ids=ids[np.argsort(lead, kind="stable")],
                est_bytes=int(est_bytes))


def split_wave(wave: Wave, schedule: Schedule,
               footprints: np.ndarray) -> tuple[Wave, Wave]:
    """Split a wave whose *assembled* slab overflowed the budget (the
    model under-priced algorithm-specific ``prepare`` outputs, or
    bucket padding pushed it over)."""
    ids = wave.task_ids
    if ids.size < 2:
        raise ValueError(
            "a single task's staged bytes (bucket-padded slab + prepare "
            "extras) exceed the memory budget even though its model "
            "footprint fits; raise memory_budget"
        )
    half = ids.size // 2
    a, b = ids[:half], ids[half:]
    return (
        Wave(task_ids=a, est_bytes=int(footprints[a].sum())),
        Wave(task_ids=b, est_bytes=int(footprints[b].sum())),
    )
