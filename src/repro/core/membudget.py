"""Device-memory footprint model + budget-sized wave packing (paper §4.3/§4.4).

PGAbB's headline claim is that a task only ever needs the blocks of ONE
block-list resident on the throughput device, so graphs that fit host
DRAM but not accelerator memory still run.  This module is the pricing
half of that subsystem: it puts a byte cost on every schedule task and
packs the LPT-ordered tasks into *waves* whose staged working set fits
an explicit ``memory_budget``.  The execution half (double-buffered
staging, partial-result combination) lives in :mod:`repro.core.stream`.

Footprint model
---------------
A task's streamed working set prices three components:

* **COO slice** — the segmented-COO slab entries of every block in the
  task's block-list: ``src``/``dst``/``edge_block`` (int32) plus the two
  edge routing masks (bool) → :data:`COO_EDGE_BYTES` per edge.
* **Dense tiles** — for MXU-path tasks, one ``tile_dim × tile_dim``
  float32 bitmap per distinct block, plus the two int64 tile-origin
  scalars (:func:`tile_bytes`).  Tiles shared by several tasks of one
  wave are staged once; the per-task price is therefore an upper bound
  and the wave builder re-prices the union.
* **CSR row slices** — when the algorithm declares
  ``metadata["csr"] == "slice"``, each task additionally prices the
  conformal CSR row ranges of its blocks
  (:data:`CSR_INDEX_BYTES` per edge, deduplicated per distinct block;
  routed through the registry's ``"csr_slice"`` workspace estimator).
  The executor stages exactly those slices per wave
  (:meth:`repro.core.blocks.BlockStore.csr_slices`), so *no*
  edge-proportional array stays device-resident.
* **Kernel workspace** — per-kernel scratch estimates from the backend
  registry (:func:`repro.kernels.registry.workspace_bytes`), e.g. the
  gathered ``xs``/``ys`` slices of ``spmv_tiles``.

Vertex-level attribute arrays (state pytree, ``degrees``, ``indptr``,
``row_block_ptr``) stay *resident* across waves; :func:`resident_bytes`
prices them so callers can see the full device picture.  The global CSR
``indices`` is resident only for algorithms that declare
``metadata["csr"] == "resident"`` (the compatibility default for custom
algorithms; every shipped algorithm declares ``"slice"`` or ``"none"``
— see :mod:`repro.core.stream`).

Wave packing pads every wave's edge slab to one of a few fixed bucket
shapes (:func:`bucket_size`, a power-of-two ladder) so a single jitted
step serves all waves without retracing.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from .. import obs
from .blocks import BlockStore
from .scheduler import Schedule

__all__ = [
    "MemoryBudget", "parse_bytes", "COO_EDGE_BYTES", "CSR_INDEX_BYTES",
    "TILE_HEADER_BYTES", "PIPELINE_DEPTH", "STATE_COPIES",
    "arena_model_bytes",
    "bucket_size", "task_edge_counts",
    "task_csr_edge_counts", "task_footprints", "tile_bytes",
    "dense_extra_bytes", "single_task_bytes",
    "resident_bytes", "tree_array_bytes", "batch_state_bytes",
    "TenantLedger", "Wave", "build_waves",
    "repack_waves",
    "HOST_RATIO_DEFAULT", "HETERO_HIDE_FACTOR",
    "peel_host_tasks", "hetero_split_diverged",
]

# src + dst + edge_block (int32) + sparse/dense edge masks (bool).
COO_EDGE_BYTES = 4 + 4 + 4 + 1 + 1
# default staging-pipeline depth: how many waves ahead the background
# staging worker may assemble (repro.core.stream._StagePipeline).
PIPELINE_DEPTH = 2
# one staged CSR adjacency entry (int32) — see BlockStore.csr_slices.
CSR_INDEX_BYTES = 4
# per-tile origin scalars: tile_row_start + tile_col_start (int64).
TILE_HEADER_BYTES = 8 + 8
# batch-axis pricing: device copies of each query's state a batched
# step holds live at once — the iteration-start state plus the step's
# written/accumulator copy (post rebuilds every leaf).
STATE_COPIES = 2

_UNITS = {"b": 1, "kb": 10**3, "mb": 10**6, "gb": 10**9,
          "kib": 2**10, "mib": 2**20, "gib": 2**30}


def parse_bytes(budget: int | float | str) -> int:
    """``8_000_000``, ``"64MB"``, ``"512KiB"`` → bytes (int)."""
    if isinstance(budget, (int, float, np.integer, np.floating)):
        return int(budget)
    m = re.fullmatch(r"\s*([0-9.]+)\s*([kKmMgG]i?[bB]|[bB])?\s*", str(budget))
    if not m:
        raise ValueError(f"cannot parse memory budget {budget!r}")
    scale = _UNITS[(m.group(2) or "b").lower()]
    return int(float(m.group(1)) * scale)


@dataclass(frozen=True)
class MemoryBudget:
    """An explicit device-memory budget for streamed task working sets."""

    total_bytes: int

    def __post_init__(self) -> None:
        if self.total_bytes <= 0:
            raise ValueError("memory budget must be positive")

    @classmethod
    def of(cls, budget: "int | str | MemoryBudget") -> "MemoryBudget":
        if isinstance(budget, MemoryBudget):
            return budget
        return cls(parse_bytes(budget))

    def scaled(self, factor: float) -> "MemoryBudget":
        """A shrunk *effective* budget for OOM-backoff re-packing
        (clamped to ≥ 1 byte).  Only the packing capacity shrinks — the
        per-task staged-bytes bound is always verified against the
        original budget and is never relaxed."""
        return MemoryBudget(max(int(self.total_bytes * float(factor)), 1))


def bucket_size(k: int, *, minimum: int = 8) -> int:
    """Smallest power-of-two ≥ ``k`` — the fixed bucket ladder that keeps
    the number of distinct wave-slab shapes (and therefore jit retraces)
    logarithmic in the largest wave."""
    k = max(int(k), minimum)
    return 1 << int(np.ceil(np.log2(k)))


def tile_bytes(tile_dim: int) -> int:
    """Staged bytes for one dense bitmap tile."""
    return tile_dim * tile_dim * 4 + TILE_HEADER_BYTES


def task_edge_counts(store: BlockStore, schedule: Schedule) -> np.ndarray:
    """(t,) edges across every block of each task's block-list."""
    bls = schedule.blocklists
    seg = np.diff(store.block_ptr)
    return seg[bls].sum(axis=1).astype(np.int64)


def task_csr_edge_counts(store: BlockStore, schedule: Schedule) -> np.ndarray:
    """(t,) CSR entries each task's conformal row slices stage.

    A block's conformal CSR content has exactly as many entries as the
    block has edges, so this is the per-task edge count with duplicate
    blocks inside one block-list (pattern mode) counted once.
    """
    bls = np.sort(schedule.blocklists, axis=1)
    seg = np.diff(store.block_ptr)
    first = np.ones(bls.shape, dtype=bool)
    if bls.shape[1] > 1:
        first[:, 1:] = bls[:, 1:] != bls[:, :-1]
    return (seg[bls] * first).sum(axis=1).astype(np.int64)


def task_footprints(store: BlockStore, schedule: Schedule, *,
                    workspace_kernel: "str | tuple | None" = None,
                    stage_csr: bool = False) -> np.ndarray:
    """(t,) bytes: the streamed working set of each task, per the model.

    COO slab + (dense tasks) bitmap tiles per distinct block + kernel
    workspace + (``stage_csr=True``) the task's conformal CSR row
    slices.  ``workspace_kernel`` names the registry kernel whose
    workspace estimator prices the dense path (algorithms declare it in
    ``metadata["workspace_kernel"]``) — or a tuple of names, charged at
    the max over them (how ``direction="auto"`` plans price both the
    push and pull dense variants); when unknown, the *maximum* over
    all registered estimators is charged — conservative by design.
    ``stage_csr`` mirrors the algorithm's ``metadata["csr"] == "slice"``
    declaration: per-wave sliced ``indices`` are staged device memory
    and must be priced like the COO slab.
    This is the scheduler-facing *estimate*; the wave builder verifies
    the assembled slabs against the budget and splits waves whose
    actual bytes (e.g. pattern-mode ``prepare`` items) exceed it.
    """
    from ..kernels.registry import registered_workspaces, workspace_bytes

    for wk in _workspace_names(workspace_kernel):
        if wk not in registered_workspaces():
            raise ValueError(
                f"workspace_kernel {wk!r} has no registered "
                f"estimator (known: {sorted(registered_workspaces())}); a "
                f"typo here would silently under-price dense tasks"
            )
    edges = task_edge_counts(store, schedule)
    out = edges * COO_EDGE_BYTES
    if stage_csr:
        # one registry call fetches the per-edge rate; the estimator is
        # linear, so the per-task bytes vectorize
        per_edge = workspace_bytes("csr_slice", csr_edges=1)
        out = out + task_csr_edge_counts(store, schedule) * per_edge
    if schedule.dense_task_mask.any():
        for t in np.nonzero(schedule.dense_task_mask)[0]:
            nd = int(np.unique(schedule.blocklists[t]).size)
            out[t] += dense_extra_bytes(nd, schedule.tile_dim,
                                        workspace_kernel)
    return out.astype(np.int64)


def _workspace_names(workspace_kernel) -> tuple:
    """Normalize a workspace declaration (name | tuple of variant
    names | None) to a tuple for validation and pricing loops."""
    if workspace_kernel is None:
        return ()
    if isinstance(workspace_kernel, str):
        return (workspace_kernel,)
    return tuple(workspace_kernel)


def dense_extra_bytes(nd: int, tile_dim: int,
                      workspace_kernel: "str | tuple | None" = None) -> int:
    """Dense-path surcharge for one task: ``nd`` staged bitmap tiles
    plus the kernel workspace estimate (worst case over the registry
    when the algorithm names no kernel; max over the named variants
    when a direction-capable algorithm names several).

    Deliberately *not* mesh-aware: a task is atomic on one device, so
    its footprint never shrinks with mesh size.  Per-device pricing of
    a whole wave's spread-out tiles goes through the registry
    estimators' ``devices`` hint instead (the mesh assembler prices the
    per-device padded tile count directly)."""
    from ..kernels.registry import max_workspace_bytes, workspace_bytes

    extra = nd * tile_bytes(tile_dim)
    names = _workspace_names(workspace_kernel)
    extra += (workspace_bytes(names, nd=nd, tile_dim=tile_dim)
              if names
              else max_workspace_bytes(nd=nd, tile_dim=tile_dim))
    return int(extra)


def single_task_bytes(store: BlockStore, blocklist, *, tile_dim: int = 0,
                      workspace_kernel: "str | tuple | None" = None,
                      stage_csr: bool = False, dense: bool = False) -> int:
    """Model bytes for one task's staged working set — the canonical
    single-task pricing shared by :func:`task_footprints` (vectorized
    over a schedule) and the scheduler's budget demotion check.

    COO prices the raw block-list (duplicates and all, matching
    :func:`task_edge_counts`); CSR slices and tiles stage each distinct
    block once."""
    from ..kernels.registry import workspace_bytes

    bl = np.atleast_1d(np.asarray(blocklist, dtype=np.int64))
    seg = np.diff(store.block_ptr)
    blocks = np.unique(bl)
    total = int(seg[bl].sum()) * COO_EDGE_BYTES
    if stage_csr:
        total += int(seg[blocks].sum()) * workspace_bytes("csr_slice",
                                                          csr_edges=1)
    if dense:
        total += dense_extra_bytes(int(blocks.size), tile_dim,
                                   workspace_kernel)
    return total


def tree_array_bytes(tree) -> int:
    """Total bytes of the array leaves of a pytree (host or device);
    static leaves (ints, strings, ...) cost nothing."""
    import jax

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total




def batch_state_bytes(per_query_bytes: int, batch: int, *,
                      copies: int = STATE_COPIES) -> int:
    """Priced device bytes of ``batch`` query-state rows.

    ``per_query_bytes`` is one query's state pytree
    (:func:`tree_array_bytes` of its ``init_state``); a padded batch
    prices every row of the bucket — padding rows occupy real device
    memory even though their results are discarded.  ``copies`` models
    how many live copies of the state the batched step holds at once
    (:data:`STATE_COPIES`).  This is the admission controller's unit
    price: resident plan bytes + Σ batch_state_bytes of everything
    in flight must stay under the serving budget.
    """
    if batch < 0:
        raise ValueError("batch must be non-negative")
    return int(per_query_bytes) * int(batch) * int(copies)


class TenantLedger:
    """Per-tenant byte accounting for admitted serving work.

    Each tenant has an optional byte cap (``budgets`` per tenant, or
    ``default_budget`` for everyone unnamed; ``None`` means uncapped).
    The serving admission controller charges a query's priced footprint
    to its tenant while the query is queued-for-batch or running, and
    releases it on completion — so one tenant's burst queues behind its
    own cap instead of starving the others.
    """

    def __init__(self, budgets: dict | None = None,
                 default_budget: "int | str | None" = None) -> None:
        self._budgets = {
            str(k): parse_bytes(v) for k, v in (budgets or {}).items()
        }
        self._default = (
            parse_bytes(default_budget) if default_budget is not None else None
        )
        self._held: dict[str, int] = {}

    def budget(self, tenant: str) -> int | None:
        return self._budgets.get(str(tenant), self._default)

    def held(self, tenant: str) -> int:
        return self._held.get(str(tenant), 0)

    def fits(self, tenant: str, nbytes: int) -> bool:
        """Could ``nbytes`` EVER be admitted for this tenant (alone)?"""
        b = self.budget(tenant)
        return b is None or int(nbytes) <= b

    def can_charge(self, tenant: str, nbytes: int) -> bool:
        b = self.budget(tenant)
        return b is None or self.held(tenant) + int(nbytes) <= b

    def charge(self, tenant: str, nbytes: int) -> None:
        if not self.can_charge(tenant, nbytes):
            raise ValueError(
                f"tenant {tenant!r} over budget: holds {self.held(tenant)} "
                f"+ {int(nbytes)} > {self.budget(tenant)}"
            )
        self._held[str(tenant)] = self.held(tenant) + int(nbytes)
        obs.metrics.gauge("membudget.tenant_held_high_water_bytes").set_max(
            sum(self._held.values()))

    def release(self, tenant: str, nbytes: int) -> None:
        self._held[str(tenant)] = max(0, self.held(tenant) - int(nbytes))


def arena_model_bytes(slab_bytes, depth: int = PIPELINE_DEPTH,
                      devices: int = 1) -> int:
    """Model bytes of the staging arena for a plan's wave slabs.

    The pipelined stager holds up to ``depth`` assembled host slabs in
    its queue plus the one whose ``device_put`` is in flight, all drawn
    from pooled per-(bucket shape, dtype) buffers — so the arena's
    steady-state residency is bounded by ``(depth + 1)`` copies of the
    *largest* slab (priced through the registry's ``stage_arena``
    estimator, which also understands the per-device mesh split).  Host
    memory: the device-side bound stays "each staged slab ≤ budget".
    """
    from ..kernels.registry import workspace_bytes

    worst = max((int(b) for b in slab_bytes), default=0)
    return workspace_bytes("stage_arena", slab_bytes=worst, depth=depth,
                           devices=devices)


def resident_bytes(store: BlockStore, state=None, *,
                   include_csr: bool = True) -> int:
    """Bytes that stay on device across every wave: vertex-level arrays,
    the conformal row map, optionally the state pytree, and — only for
    ``metadata["csr"] == "resident"`` algorithms (``include_csr``) — the
    global CSR adjacency.  ``"slice"``/``"none"`` algorithms keep no
    edge-proportional array resident (the sliced ``indices`` are priced
    per wave instead)."""
    total = (
        store.indptr.nbytes
        + store.degrees.nbytes
        + store.row_block_ptr.nbytes
        + store.layout.cuts.nbytes
    )
    if include_csr:
        total += store.indices.nbytes
    if state is not None:
        total += tree_array_bytes(state)
    return int(total)


# ----------------------------------------------------------------------
#: Assumed host-vs-device slowdown per unit task weight when the host
#: lane has not been measured yet (``REPRO_HETERO_HOST_RATIO`` env var
#: overrides; the streaming executor replaces it with the observed
#: ratio after the first heterogeneous iteration).
HOST_RATIO_DEFAULT = 4.0
#: The ``"auto"`` split only peels a task to the host while the host
#: queue's predicted time stays under this fraction of the remaining
#: device time — host work must hide behind the device wave, with a
#: margin, so co-scheduling can only shorten the wave.
HETERO_HIDE_FACTOR = 0.9


@dataclass
class Wave:
    """One budget-sized unit of streamed work.

    ``task_ids`` are indices into the schedule's task list, sorted by
    leading block id so the COO gather coalesces into few contiguous
    segments.  ``est_bytes`` is the model estimate used for packing;
    the staged slab's actual (bucket-padded) bytes are measured by the
    stream binder and recorded in ``schedule_stats``.
    ``host_task_ids`` is the wave's host partition — tasks peeled off
    by :func:`peel_host_tasks` that run on the host CPU and never count
    against ``est_bytes`` (they are never staged).
    """

    task_ids: np.ndarray
    est_bytes: int
    host_task_ids: np.ndarray = field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))


def peel_host_tasks(schedule: Schedule, waves: list[Wave],
                    host_fraction: "float | str", *,
                    task_times: np.ndarray | None = None,
                    host_ratio: float = HOST_RATIO_DEFAULT,
                    footprints: np.ndarray | None = None,
                    min_tasks: int = 0) -> list[Wave]:
    """Split each wave into a device partition and a host partition.

    Candidates leave the device side lightest/sparsest first — sparse
    tasks before dense ones, then by per-task time (the schedule's LPT
    weights when no measured ``task_times`` are given), so the
    irregular long tail is what moves to the CPU while the dense tiles
    keep the accelerator.  A wave's device side is never emptied unless
    ``host_fraction >= 1``.

    Policies:

    * numeric ``f`` in ``(0, 1)`` — peel tasks until the host partition
      carries at least ``f`` of the wave's time (any positive ``f``
      peels at least one task from every multi-task wave);
    * ``f >= 1`` — everything runs on the host;
    * ``"auto"`` — greedy hide-behind-device rule: accept a candidate
      only while ``host_time × host_ratio`` stays under
      :data:`HETERO_HIDE_FACTOR` of the device time left in the wave.
      With no measured ``task_times`` the auto split stays at zero
      (nothing is known yet); ``min_tasks`` forces that many probe
      tasks per multi-task wave so the executor can measure the host
      throughput it needs to calibrate the ratio.

    Device ``est_bytes`` is re-priced from ``footprints`` (host tasks
    are never staged), so peeling can only shrink the staged slab —
    the per-wave byte budget is preserved by construction.
    """
    auto = isinstance(host_fraction, str)
    if auto and host_fraction != "auto":
        raise ValueError(f"host_fraction must be a number or 'auto', "
                         f"got {host_fraction!r}")
    if auto and task_times is None:
        # nothing measured yet — the auto split starts device-only and
        # only activates once the executor feeds calibrated task times
        return list(waves)
    times = np.asarray(task_times if task_times is not None
                       else schedule.weights, dtype=np.float64)
    dense = schedule.dense_task_mask
    out: list[Wave] = []
    for wave in waves:
        ids = np.concatenate([wave.task_ids, wave.host_task_ids]).astype(
            np.int64)
        if ids.size == 0:
            continue
        if not auto and float(host_fraction) >= 1.0:
            out.append(Wave(task_ids=np.zeros(0, np.int64), est_bytes=0,
                            host_task_ids=np.sort(ids)))
            continue
        # lightest / sparsest first: sparse tasks peel before dense,
        # then by time, ties by id for determinism
        cand = ids[np.lexsort((ids, times[ids], dense[ids]))]
        total_t = float(times[ids].sum())
        host: list[int] = []
        host_t = 0.0
        if auto:
            dev_t = total_t
            for t in cand[:-1]:             # never empty the device side
                tt = float(times[t])
                forced = len(host) < min_tasks
                hides = ((host_t + tt) * float(host_ratio)
                         <= HETERO_HIDE_FACTOR * (dev_t - tt))
                if not (forced or hides):
                    break
                host.append(int(t))
                host_t += tt
                dev_t -= tt
        elif float(host_fraction) > 0.0:
            target = float(host_fraction) * total_t
            for t in cand[:-1]:
                if host_t >= target:
                    break
                host.append(int(t))
                host_t += float(times[t])
        host_ids = np.asarray(sorted(host), dtype=np.int64)
        dev_ids = np.setdiff1d(ids, host_ids)
        lead = schedule.blocklists[dev_ids, 0]
        dev_ids = dev_ids[np.argsort(lead, kind="stable")]
        est = (int(footprints[dev_ids].sum()) if footprints is not None
               else wave.est_bytes)
        out.append(Wave(task_ids=dev_ids, est_bytes=est,
                        host_task_ids=host_ids))
    return out


def hetero_split_diverged(current: float, proposed: float, *,
                          rel: float = 0.25, abs_tol: float = 0.05) -> bool:
    """Hysteresis for the auto host/device split: re-plan only when the
    proposed host share moved by more than ``abs_tol`` absolute or
    ``rel`` relative to the current share — small drifts in measured
    task times must not thrash the wave plan every iteration."""
    return abs(float(proposed) - float(current)) > max(
        abs_tol, rel * abs(float(current)))


def build_waves(store: BlockStore, schedule: Schedule,
                budget: MemoryBudget,
                footprints: np.ndarray | None = None, *,
                devices: int = 1,
                host_fraction: "float | str" = 0.0,
                task_times: np.ndarray | None = None,
                host_ratio: float = HOST_RATIO_DEFAULT) -> list[Wave]:
    """Greedily pack LPT-ordered tasks into waves under ``budget``.

    Walking tasks heaviest-first (the schedule's LPT order) keeps each
    wave's load balanced the same way device packing does; a wave closes
    when the next task would push its estimate past the wave capacity.
    Inside a wave, tasks are re-sorted by leading block id so their
    segmented COO slices coalesce.

    ``budget`` is *per device*; with ``devices`` > 1 (mesh-cooperative
    streaming) one wave is processed cooperatively by the whole mesh, so
    the wave capacity is ``devices × budget`` — but a single task is
    atomic on one device, so any task whose model footprint exceeds the
    per-device budget is unrunnable regardless of mesh size: raise
    rather than silently oversubscribe.  The stream binder re-verifies
    the assembled per-device slabs and splits waves whose actual bytes
    overflow.

    ``host_fraction`` (with optional measured ``task_times`` and the
    host/device throughput ``host_ratio``) additionally peels each
    wave's lightest tasks into a host partition via
    :func:`peel_host_tasks` — heterogeneous co-scheduling where the
    host CPU runs the sparse long tail while the device runs the rest.
    """
    if footprints is None:
        footprints = task_footprints(store, schedule)
    capacity = budget.total_bytes * max(int(devices), 1)
    waves: list[Wave] = []
    cur: list[int] = []
    cur_bytes = 0
    for t in schedule.order:
        b = int(footprints[t])
        if b > budget.total_bytes:
            raise ValueError(
                f"task {int(t)} needs {b} bytes > per-device budget "
                f"{budget.total_bytes}; raise memory_budget or shrink "
                f"tile_dim/blocks (p)"
            )
        if cur and cur_bytes + b > capacity:
            waves.append(_close_wave(cur, cur_bytes, schedule))
            cur, cur_bytes = [], 0
        cur.append(int(t))
        cur_bytes += b
    if cur:
        waves.append(_close_wave(cur, cur_bytes, schedule))
    if (isinstance(host_fraction, str)
            or float(host_fraction) > 0.0):
        waves = peel_host_tasks(schedule, waves, host_fraction,
                                task_times=task_times,
                                host_ratio=host_ratio,
                                footprints=footprints)
    obs.metrics.counter("membudget.wave_builds").inc()
    obs.metrics.counter("membudget.waves_packed").inc(len(waves))
    return waves


def _close_wave(task_ids: list[int], est_bytes: int,
                schedule: Schedule) -> Wave:
    ids = np.asarray(task_ids, dtype=np.int64)
    lead = schedule.blocklists[ids, 0]
    return Wave(task_ids=ids[np.argsort(lead, kind="stable")],
                est_bytes=int(est_bytes))


def repack_waves(schedule: Schedule, budget: MemoryBudget,
                 footprints: np.ndarray, task_times: np.ndarray, *,
                 slack: float = 0.2, devices: int = 1) -> list[Wave]:
    """Re-pack every task into waves against *observed* per-task times.

    The paper's dynamic work queue, adapted to wave granularity: once
    the streaming executor has measured real per-wave compute times
    (and attributed them to tasks), the static LPT-by-estimate packing
    is replaced by LPT over the measured times.  A wave closes when the
    next task would push its byte estimate past the budget *or* its
    time load past the balanced target (total time over the bytes-only
    wave-count floor, stretched by ``slack``) — so one dominated tail
    wave gets its heavy tasks spread instead of serialized.

    As in :func:`build_waves`, ``budget`` is per device and the wave
    byte capacity is ``devices × budget``.
    """
    capacity = budget.total_bytes * max(int(devices), 1)
    t = np.asarray(task_times, dtype=np.float64)
    order = np.argsort(-t, kind="stable")
    # bytes-only greedy pass fixes the wave-count floor the time target
    # balances against (fewer waves than this cannot fit the budget)
    floor_waves, acc = 1, 0
    for i in order:
        b = int(footprints[i])
        if acc and acc + b > capacity:
            floor_waves += 1
            acc = 0
        acc += b
    total_t = float(t.sum())
    target = (
        (total_t / floor_waves) * (1.0 + slack) if total_t > 0 else np.inf
    )
    waves: list[Wave] = []
    cur: list[int] = []
    cur_bytes, cur_t = 0, 0.0
    for i in order:
        b = int(footprints[i])
        if cur and (cur_bytes + b > capacity
                    or cur_t + float(t[i]) > target):
            waves.append(_close_wave(cur, cur_bytes, schedule))
            cur, cur_bytes, cur_t = [], 0, 0.0
        cur.append(int(i))
        cur_bytes += b
        cur_t += float(t[i])
    if cur:
        waves.append(_close_wave(cur, cur_bytes, schedule))
    return waves


def split_wave(wave: Wave, schedule: Schedule,
               footprints: np.ndarray) -> tuple[Wave, Wave]:
    """Split a wave whose *assembled* slab overflowed the budget (the
    model under-priced algorithm-specific ``prepare`` outputs, or
    bucket padding pushed it over)."""
    ids = wave.task_ids
    if ids.size < 2:
        raise ValueError(
            "a single task's staged bytes (bucket-padded slab + prepare "
            "extras) exceed the memory budget even though its model "
            "footprint fits; raise memory_budget"
        )
    half = ids.size // 2
    a, b = ids[:half], ids[half:]
    return (
        Wave(task_ids=a, est_bytes=int(footprints[a].sum())),
        Wave(task_ids=b, est_bytes=int(footprints[b].sum())),
    )
