"""Workload-estimation based scheduler (paper §4.4), adapted to a TPU mesh.

The paper's scheduler (i) estimates each task's weight with the user's
``E`` functor (default: edges in the block-list), (ii) sorts tasks in
decreasing weight to expose bottleneck tasks, (iii) sends heavy tasks to
the throughput device (GPU) and light ones to CPUs, with an optional
cut-off that CPUs never cross, and (iv) overlaps copies with compute via
four CUDA streams.

On a TPU mesh the same decisions appear at two levels:

* **Path split (K_D vs K_H analog).**  Heavy *and dense* tasks go to the
  MXU path (dense bitmap tiles, Pallas matmul kernels); everything else
  goes to the VPU path (segmented-COO gather/scatter).  The paper's
  cut-off becomes two knobs: ``dense_density`` (minimum block density)
  and ``dense_frac`` (the weight-ranked fraction the MXU path claims —
  CPUs "do not go past the cut-off").
* **Device packing.**  Tasks are LPT-packed (Longest Processing Time
  first — greedy on the sorted weights) onto the mesh's block-parallel
  devices, producing a *static* per-device task list.  This is the
  work-stealing queue of the paper frozen at trace time; LPT has the
  classical 4/3-OPT makespan bound, which is our straggler-mitigation
  story for skewed graphs.

Everything here is host-side numpy; the result feeds jitted kernels.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .blocks import BlockStore
from .functors import BlockAlgorithm

__all__ = ["Schedule", "build_schedule", "lpt_assign"]


@dataclass
class Schedule:
    blocklists: np.ndarray        # (t, s) block ids per block-list (task)
    weights: np.ndarray           # (t,) E estimates
    order: np.ndarray             # (t,) task indices sorted by decreasing weight
    dense_task_mask: np.ndarray   # (t,) True → MXU path
    dense_block_ids: np.ndarray   # unique block ids needing dense tiles
    tile_dim: int
    device_assignment: np.ndarray  # (t,) device slot per task (LPT)
    num_devices: int
    stats: dict = field(default_factory=dict)

    @property
    def num_tasks(self) -> int:
        return int(self.blocklists.shape[0])

    def restrict(self, task_ids: np.ndarray) -> "Schedule":
        """A sub-schedule over ``task_ids`` (wave-aware packing support).

        The streaming executor (:mod:`repro.core.stream`) binds one
        sub-schedule per wave so algorithm ``prepare`` hooks see exactly
        the wave's tasks — pattern-mode work items, dense-tile index
        maps, … are then wave-local by construction.  ``dense_block_ids``
        is recomputed from the restricted tasks; weights/assignment are
        sliced; ``order`` re-ranks within the subset.
        """
        ids = np.asarray(task_ids, dtype=np.int64)
        w = self.weights[ids]
        mask = self.dense_task_mask[ids]
        bls = self.blocklists[ids]
        dense_block_ids = (
            np.unique(bls[mask].ravel()).astype(np.int32)
            if mask.any() else np.zeros(0, np.int32)
        )
        return Schedule(
            blocklists=bls,
            weights=w,
            order=np.argsort(-w, kind="stable"),
            dense_task_mask=mask,
            dense_block_ids=dense_block_ids,
            tile_dim=self.tile_dim,
            device_assignment=self.device_assignment[ids],
            num_devices=self.num_devices,
            stats=dict(self.stats, restricted_from=self.num_tasks,
                       num_tasks=int(ids.size)),
        )

    def makespan_ratio(self) -> float:
        """LPT makespan / ideal (mean) load — straggler headroom metric."""
        loads = np.zeros(self.num_devices)
        np.add.at(loads, self.device_assignment, self.weights)
        ideal = self.weights.sum() / max(self.num_devices, 1)
        return float(loads.max() / max(ideal, 1e-12))

    def partition_tasks(self, num_devices: int) -> np.ndarray:
        """Fresh LPT device assignment over *this* schedule's tasks.

        The mesh-cooperative streaming executor calls this on each
        wave's restricted sub-schedule: the global ``device_assignment``
        balances the whole task list, but one wave holds an arbitrary
        subset of it, so re-packing wave-locally is what keeps every
        device of the mesh busy within the wave.  Returns a ``(t,)``
        device id per task of this schedule.
        """
        return lpt_assign(self.weights, max(int(num_devices), 1))

    def weight_share(self, task_ids) -> float:
        """Fraction of the schedule's total E-estimate weight carried by
        ``task_ids`` — how the heterogeneous executor reports its
        resolved host/device split ratio in ``schedule_stats``."""
        total = float(self.weights.sum())
        if total <= 0.0:
            return 0.0
        ids = np.asarray(task_ids, dtype=np.int64)
        return float(self.weights[ids].sum()) / total


def _demote_over_budget(alg: BlockAlgorithm, store: BlockStore,
                        bls: np.ndarray, fits: np.ndarray,
                        tile_dim: int, budget_bytes: int,
                        direction: str | None = None) -> int:
    """Clear ``fits`` for tasks whose dense-path staged working set
    cannot fit the budget; they run on the sparse path instead.

    Priced by :func:`repro.core.membudget.single_task_bytes` — the same
    model :func:`~repro.core.membudget.task_footprints` applies, so a
    task this check keeps is one the wave builder accepts.  Returns the
    number of demoted tasks (for ``stats``)."""
    from .direction import workspace_kernels
    from .membudget import single_task_bytes

    wk = workspace_kernels(alg, direction)
    stage_csr = alg.metadata.get("csr") == "slice"
    demoted = 0
    for i in np.nonzero(fits)[0]:
        cost = single_task_bytes(store, bls[i], tile_dim=tile_dim,
                                 workspace_kernel=wk, stage_csr=stage_csr,
                                 dense=True)
        if cost > budget_bytes:
            fits[i] = False
            demoted += 1
    return demoted


def lpt_assign(weights: np.ndarray, num_devices: int) -> np.ndarray:
    """Longest-Processing-Time-first greedy packing → device id per task."""
    order = np.argsort(-weights, kind="stable")
    loads = np.zeros(num_devices, dtype=np.float64)
    assign = np.zeros(weights.shape[0], dtype=np.int32)
    for t in order:
        d = int(np.argmin(loads))
        assign[t] = d
        loads[d] += float(weights[t])
    return assign


def _budget_tile_dim(alg: BlockAlgorithm, tile_dim: int,
                     budget_bytes: int,
                     direction: str | None = None) -> int:
    """Budget-aware tile cut-off: halve ``tile_dim`` until one staged
    bitmap tile plus its kernel workspace fits the budget.

    Tile working sets dominate wave bytes at large ``tile_dim``, so a
    planner that keeps the requested size would emit dense waves the
    wave builder must immediately split (or reject).  Blocks wider than
    the shrunken tile simply stay on the sparse path."""
    from ..kernels.registry import max_workspace_bytes, workspace_bytes
    from .direction import workspace_kernels
    from .membudget import tile_bytes

    wk = workspace_kernels(alg, direction)

    def cost(td: int) -> int:
        ws = (workspace_bytes(wk, nd=1, tile_dim=td) if wk is not None
              else max_workspace_bytes(nd=1, tile_dim=td))
        return tile_bytes(td) + ws

    while tile_dim > 64 and cost(tile_dim) > budget_bytes:
        tile_dim //= 2
    return tile_dim


def build_schedule(
    alg: BlockAlgorithm,
    store: BlockStore,
    *,
    num_devices: int = 1,
    dense_frac: float = 0.5,
    dense_density: float = 0.005,
    tile_dim: int = 512,
    mode: str = "hybrid",          # "hybrid" | "sparse_only" | "dense_only"
    memory_budget=None,            # int | str | MemoryBudget | None
    direction: str | None = None,  # push | pull | auto | None — pricing only
) -> Schedule:
    """Compose block-lists, estimate, sort, split paths, pack devices.

    With ``memory_budget`` set (the streaming executor forwards its
    budget here), the planner becomes budget-aware instead of leaving
    the budget to the wave packer alone: ``tile_dim`` shrinks until a
    single staged tile fits (:func:`_budget_tile_dim`), and a task is
    only routed to the dense path if its full staged working set — COO
    slab, bitmap tiles, kernel workspace, CSR slices when the algorithm
    declares ``metadata["csr"] == "slice"`` — fits the budget, so the
    planner stops producing dense waves that must immediately be split.
    ``direction`` feeds the workspace pricing only: ``"auto"`` charges
    the max over the push/pull dense variants' estimators
    (:func:`repro.core.direction.workspace_kernels`), so either variant
    the runtime later picks fits the budget it planned against.
    """
    budget_bytes = None
    if memory_budget is not None:
        from .membudget import MemoryBudget

        budget_bytes = MemoryBudget.of(memory_budget).total_bytes
        if mode != "sparse_only" and alg.kernel_dense is not None:
            tile_dim = _budget_tile_dim(alg, tile_dim, budget_bytes,
                                        direction)

    bls = alg.compose_blocklists(store)
    t = bls.shape[0]
    weights = np.asarray(
        [alg.estimate(store, bls[i]) for i in range(t)], dtype=np.float64
    )
    order = np.argsort(-weights, kind="stable")

    # ---- dense/sparse path split -------------------------------------
    dense_task_mask = np.zeros(t, dtype=bool)
    dense_demoted = 0
    if mode != "sparse_only" and alg.kernel_dense is not None and t:
        # a task is MXU-eligible iff every block in its block-list fits a
        # tile and the *first* (edge) block clears the density cut-off
        fits = np.zeros(t, dtype=bool)
        for i in range(t):
            ranges_ok = all(
                max(store.block_range(int(b))) <= tile_dim for b in bls[i]
            )
            dens_ok = store.block_density(int(bls[i][0])) >= dense_density
            fits[i] = ranges_ok and (dens_ok or mode == "dense_only")
        if budget_bytes is not None and alg.kernel_sparse is not None:
            dense_demoted = _demote_over_budget(
                alg, store, bls, fits, tile_dim, budget_bytes, direction
            )
        if mode == "dense_only":
            dense_task_mask = fits
        else:
            # heavy-first claim up to dense_frac of total weight (cut-off)
            budget = dense_frac * weights.sum()
            claimed = 0.0
            for tid in order:
                if not fits[tid]:
                    continue
                if claimed >= budget:
                    break
                dense_task_mask[tid] = True
                claimed += weights[tid]
    dense_block_ids = (
        np.unique(bls[dense_task_mask].ravel()).astype(np.int32)
        if dense_task_mask.any()
        else np.zeros(0, np.int32)
    )
    if dense_block_ids.size:
        store.materialize_tiles(dense_block_ids, tile_dim)

    assign = lpt_assign(weights, max(num_devices, 1))
    sched = Schedule(
        blocklists=bls,
        weights=weights,
        order=order,
        dense_task_mask=dense_task_mask,
        dense_block_ids=dense_block_ids,
        tile_dim=tile_dim,
        device_assignment=assign,
        num_devices=max(num_devices, 1),
    )
    w_dense = float(weights[dense_task_mask].sum())
    sched.stats = dict(
        num_tasks=t,
        total_weight=float(weights.sum()),
        dense_tasks=int(dense_task_mask.sum()),
        dense_weight_frac=w_dense / max(weights.sum(), 1e-12),
        makespan_ratio=sched.makespan_ratio(),
        mode=mode,
    )
    if budget_bytes is not None:
        sched.stats.update(
            budget_bytes=budget_bytes,
            tile_dim=tile_dim,            # post-shrink effective value
            dense_budget_demoted=dense_demoted,
        )
    return sched
