"""Pallas TPU kernel: flash-style fused attention (LM substrate hot spot).

Online-softmax attention with (bq, d) × (bk, d) MXU matmuls and running
(m, l, acc) statistics in VMEM scratch — no (S, S) materialization, so
the VMEM working set is bq·d + bk·d + bq·bk floats per step regardless
of sequence length.  Supports causal masking with suffix alignment
(q_offset = S_k − S_q) so the same kernel serves prefill and decode.

Grid: (B·H, S_q/bq, S_k/bk), kv-blocks innermost (sequential) so the
accumulator carries across kv steps of one q block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, nk):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale      # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (bq, bk)
    if causal:
        bq, bk = s.shape
        sq_total = pl.num_programs(1) * bq
        sk_total = nk * bk
        row = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = kk * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # suffix alignment: query i attends to keys ≤ i + (S_k - S_q)
        s = jnp.where(col <= row + (sk_total - sq_total), s, _NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]       # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(s > _NEG / 2, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[...] = alpha * l_prev + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _finish():
        o_ref[0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """(B,H,Sq,D),(B,H,Sk,D),(B,H,Sk,D) → (B,H,Sq,D) fused attention."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    scale = d ** -0.5
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    nk = sk // bk
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, nk=nk),
        grid=(b * h, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            # f32 running accumulators live in VMEM across kv steps
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
